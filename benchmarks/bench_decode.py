"""Continuous-batching decode: engine slots vs naive rebatch-per-step.

ISSUE 9's tentpole claim is that the :class:`~repro.serve.DecodeEngine`
turns steady-state autoregressive decode into the replay of ONE cached
``CommandGraph``: the batched decode state stays resident on the lane
(donated back into every launch), so a step's host traffic is exactly the
token/position I/O.  The naive baseline — rebatching per step, which
round-trips the whole KV cache through the host both ways every token —
is the SAME engine priced with ``resident=False``; both arms decode the
same staggered workload bit-identically, so the modeled tokens/s ratio
isolates residency, and CI gates it at >= 1.3x (deterministic: machine
model, never wall clock).

The roofline readout comes straight off the captured schedule
(:class:`~repro.serve.EngineRoofline`): bytes/step, the bandwidth-floor
step time, and how memory-bound the step is.

A traced arm replays the engine workload under a :class:`Tracer` on a
virtual clock and asserts ZERO modeled perturbation against an untraced
twin — per-step ``engine.generate`` spans are free.

Results append to ``BENCH_serve.json`` tagged ``bench="decode"``.
"""

import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params, model_spec
from repro.obs import Tracer
from repro.serve import DecodeEngine
from repro.train.serve import greedy_generate

from .history import append_entry

ARCH = "qwen2.5-3b"
SLOTS = 4
N_REQ = 8          # staggered: 2x oversubscribed so slots churn
PROMPT = 12
NEW = 6            # tokens per request (1 from prefill + NEW-1 decode steps)
MAX_LEN = 96       # serving-sized KV allocation (what the naive arm moves)
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _workload(eng, prompts):
    """Drain N_REQ staggered requests through the engine's slots."""
    state = eng.init_state()
    pending = list(range(len(prompts)))
    live = {}                                  # slot -> (req, remaining)
    outs = {}
    while pending or live:
        for slot in state.free_slots():
            if not pending:
                break
            r = pending.pop(0)
            pre = eng.prefill(None, prompts[r])
            state = eng.insert(pre, state, slot)
            live[slot] = (r, NEW - 1)
            outs[r] = [int(pre.token[0])]
        state, toks = eng.generate(None, state)
        for slot in list(live):
            r, rem = live[slot]
            outs[r].append(int(toks[slot]))
            if rem - 1 == 0:
                state = eng.release(state, slot)
                del live[slot]
            else:
                live[slot] = (r, rem - 1)
    return outs


def _arm(cfg, params, prompts, *, resident, tracer=None, clock=None):
    eng = DecodeEngine(cfg, params, num_slots=SLOTS,
                       max_len=MAX_LEN, resident=resident,
                       tracer=tracer,
                       clock=clock if clock is not None else time.perf_counter)
    outs = _workload(eng, prompts)             # warm: captures both graphs
    t0 = time.perf_counter()
    outs2 = _workload(eng, prompts)            # steady state: replay only
    wall = time.perf_counter() - t0
    assert outs == outs2, "decode is deterministic"
    assert eng.cache.misses == 2, eng.cache.stats()
    return eng, outs, wall


def run():
    print("=" * 76)
    print("Continuous-batching decode: resident slots vs rebatch-per-step")
    print(f"({ARCH} reduced, {N_REQ} staggered requests x {NEW} tokens on "
          f"{SLOTS} slots)")
    print("=" * 76)
    cfg = ARCHS[ARCH].reduced()
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (N_REQ, PROMPT)),
        jnp.int32)
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=NEW,
                                     max_len=PROMPT + NEW + 1))

    engine, outs_e, wall_e = _arm(cfg, params, prompts, resident=True)
    naive, outs_n, _ = _arm(cfg, params, prompts, resident=False)

    # honesty first: both arms must deliver the whole-batch greedy bits
    for r in range(N_REQ):
        assert outs_e[r] == list(ref[r]), (r, outs_e[r], list(ref[r]))
    assert outs_n == outs_e, "naive arm diverged from engine arm"

    tps_e = engine.tokens_per_s_modeled
    tps_n = naive.tokens_per_s_modeled
    ratio = tps_e / tps_n
    roof = engine.roofline()
    wall_tps = engine.n_tokens / 2 / wall_e    # stats span both workloads
    print(f"  engine (resident)   {tps_e:12.0f} tok/s modeled   "
          f"occupancy {engine.occupancy:.0%}")
    print(f"  naive rebatch/step  {tps_n:12.0f} tok/s modeled")
    print(f"  wall (steady state) {wall_tps:12.0f} tok/s")
    print(f"\n  resident decode is {ratio:.2f}x the rebatch-per-step "
          f"baseline (>= 1.3x CI gate)")
    print(f"  roofline: {roof.bytes_per_step:,.0f} B/step -> "
          f"{roof.min_step_s * 1e6:.1f} us bandwidth floor, "
          f"{roof.mem_bound_fraction:.0%} memory-bound")

    traced = _traced_arm(cfg, params, prompts)

    result = {
        "bench": "decode",
        "arch": ARCH,
        "slots": SLOTS,
        "n_requests": N_REQ,
        "tokens_per_request": NEW,
        "tokens_per_s_modeled": {"engine": tps_e, "naive_rebatch": tps_n},
        "resident_vs_rebatch_speedup": ratio,
        "wall_tokens_per_s": wall_tps,
        "occupancy": engine.occupancy,
        "roofline": {
            "bytes_per_step": roof.bytes_per_step,
            "min_step_s": roof.min_step_s,
            "mem_bound_fraction": roof.mem_bound_fraction,
            "modeled_step_s": roof.modeled_step_s,
        },
        "bit_identical_to_greedy": True,
        "cache_stats": engine.cache.stats(),
        "traced": traced,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


def _traced_arm(cfg, params, prompts):
    """Tracing must not perturb the modeled totals by one bit."""
    t = [0.0]
    tracer = Tracer()
    eng_t, outs_t, _ = _arm(cfg, params, prompts, resident=True,
                            tracer=tracer, clock=lambda: t[0])
    eng_u, outs_u, _ = _arm(cfg, params, prompts, resident=True,
                            clock=lambda: t[0])
    assert outs_t == outs_u, "tracing perturbed the decoded tokens"
    totals_t = (eng_t.n_steps, eng_t.n_tokens, eng_t.n_prefills,
                eng_t.prefill_modeled_s, eng_t.decode_modeled_s,
                eng_t.energy_j, eng_t.occupancy)
    totals_u = (eng_u.n_steps, eng_u.n_tokens, eng_u.n_prefills,
                eng_u.prefill_modeled_s, eng_u.decode_modeled_s,
                eng_u.energy_j, eng_u.occupancy)
    assert totals_t == totals_u, "tracing perturbed the modeled totals"
    n_gen = len([s for s in tracer.spans if s.name == "engine.generate"])
    assert n_gen == eng_t.n_steps, (n_gen, eng_t.n_steps)
    print(f"  traced arm: {n_gen} engine.generate spans, modeled totals "
          f"identical to untraced twin")
    return {"n_generate_spans": n_gen, "modeled_totals_equal": True}


if __name__ == "__main__":
    run()
