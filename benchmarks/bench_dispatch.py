"""Measured dispatch overhead of the TinyCL runtime (the ~25 us analogue).

The paper's scheduling overhead is the Tiny-OpenCL runtime distributing
work-items; the TPU-side analogue is the host-side dispatch cost of an
already-jitted kernel.  We measure it directly: wall time of enqueueing a
trivially small kernel vs a large one (amortized), matching the structural
claim — dispatch cost is CONSTANT in problem size, so its fraction becomes
negligible for big launches.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EGPU_16T, Context, CommandQueue, Device, NDRange
from repro.kernels.gemm.ops import make_kernel

SIZES = (32, 64, 128, 256, 512)
REPS = 20


def run():
    print("=" * 76)
    print("Tiny-OpenCL dispatch overhead (measured on this host)")
    print("=" * 76)
    ctx = Context(Device(EGPU_16T))
    q = CommandQueue(ctx, profile=False)
    kern = make_kernel(EGPU_16T)
    rng = np.random.default_rng(0)
    rows = []
    for s in SIZES:
        a = ctx.create_buffer(jnp.asarray(
            rng.standard_normal((s, s)), jnp.float32))
        b = ctx.create_buffer(jnp.asarray(
            rng.standard_normal((s, s)), jnp.float32))
        ndr = NDRange((s, s), (8, 8))
        q.enqueue_nd_range(kern, ndr, (a, b)).wait()      # compile
        t0 = time.perf_counter()
        for _ in range(REPS):
            ev = q.enqueue_nd_range(kern, ndr, (a, b))
        ev.wait()
        per = (time.perf_counter() - t0) / REPS
        rows.append({"size": s, "dispatch_us": per * 1e6})
        print(f"gemm {s:4d}x{s:<4d} end-to-end {per*1e6:9.1f} us/launch")
    # dispatch floor = smallest launch; it should NOT grow with size faster
    # than compute does (constant-overhead claim)
    floor = rows[0]["dispatch_us"]
    print(f"\ndispatch floor ≈ {floor:.0f} us "
          f"(constant; paper's Tiny-OpenCL scheduling ≈ 25 us @ 300 MHz)")
    return rows


if __name__ == "__main__":
    run()
