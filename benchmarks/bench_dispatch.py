"""Measured dispatch overhead of the TinyCL runtime (the ~25 us analogue).

The paper's scheduling overhead is the Tiny-OpenCL runtime distributing
work-items; the TPU-side analogue is the host-side dispatch cost of an
already-jitted kernel.  This bench measures the three TinyCL dispatch modes
side by side on a chain of small dependent GeMMs (x_{i+1} = x_i @ b), where
compute is negligible and overhead dominates:

* ``eager-sync``  — ``CommandQueue(blocking=True)``: one host<->device
  round-trip per kernel (the pre-ISSUE-1 behaviour);
* ``async``       — non-blocking in-order queue: enqueues overlap, a single
  ``finish()`` drains the chain;
* ``graph``       — ``queue.capture()`` once, then ``CommandGraph.launch``:
  the whole chain is ONE jitted XLA computation, so per-kernel dispatch
  collapses to dispatch/chain_len.

All modes are timed over the full queue drain (events are waited *inside*
the timed region — waiting only the last enqueue under-counts an async
queue).  Results are *appended* to ``BENCH_dispatch.json`` next to the repo
root — a timestamped list-of-runs trajectory (a legacy single-object file is
migrated on first write).  The reference (jnp) GeMM executor is used so the
numbers isolate host dispatch, not Pallas-interpret compute.
"""

import pathlib
import time

import jax.numpy as jnp
import numpy as np

from .history import append_entry

from repro.core import (EGPU_16T, CommandQueue, Context, Device, Kernel,
                        NDRange)
from repro.kernels.gemm.ref import gemm_ref

SIZE = 32          # small on purpose: dispatch floor, not compute
CHAIN = 8          # dependent kernels per rep (x = x @ b, 8 deep)
REPS = 30
TRIALS = 5         # best-of (min): robust to scheduler noise on shared hosts
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dispatch.json"


def _chain_inputs(ctx):
    rng = np.random.default_rng(0)
    x = ctx.create_buffer(jnp.asarray(
        rng.standard_normal((SIZE, SIZE)) * 0.1, jnp.float32))
    b = ctx.create_buffer(jnp.asarray(
        np.eye(SIZE) + 0.01 * rng.standard_normal((SIZE, SIZE)), jnp.float32))
    return x, b


def _bench_queue(ctx, kern, ndr, blocking):
    q = CommandQueue(ctx, profile=False, blocking=blocking)
    x, b = _chain_inputs(ctx)

    def chain():
        cur = x
        for _ in range(CHAIN):
            cur = q.enqueue_nd_range(kern, ndr, (cur, b)).outputs[0]
        q.finish()                       # drain INSIDE the timed region
                                         # (watermarked: waits only this
                                         # chain's events, not history)

    chain()                              # compile
    best = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for _ in range(REPS):
            chain()
        best = min(best, time.perf_counter() - t0)
    return best / (REPS * CHAIN)


def _bench_graph(ctx, kern, ndr):
    q = CommandQueue(ctx, profile=False)
    x, b = _chain_inputs(ctx)
    with q.capture() as graph:
        cur = x
        for _ in range(CHAIN):
            cur = q.enqueue_nd_range(kern, ndr, (cur, b)).outputs[0]

    graph.launch(queue_events=False)[0].data.block_until_ready()  # compile
    best = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for _ in range(REPS):
            outs = graph.launch(queue_events=False)
            for o in outs:
                o.data.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / (REPS * CHAIN)


def run():
    print("=" * 76)
    print("Tiny-OpenCL dispatch overhead: eager-sync vs async vs graph")
    print(f"(chain of {CHAIN} dependent {SIZE}x{SIZE} GeMMs, best of "
          f"{TRIALS}x{REPS} reps, full-queue drain timed)")
    print("=" * 76)
    ctx = Context(Device(EGPU_16T))
    kern = Kernel(name="gemm_small", executor=gemm_ref)
    ndr = NDRange((SIZE, SIZE), (8, 8))

    per_launch = {
        "eager-sync": _bench_queue(ctx, kern, ndr, blocking=True),
        "async": _bench_queue(ctx, kern, ndr, blocking=False),
        "graph": _bench_graph(ctx, kern, ndr),
    }
    for mode, per in per_launch.items():
        print(f"  {mode:11s} {per * 1e6:9.1f} us/kernel")

    ratio = per_launch["eager-sync"] / per_launch["graph"]
    print(f"\n  graph dispatch is {ratio:.1f}x cheaper per kernel than "
          f"eager-sync (paper's Tiny-OpenCL scheduling ≈ 25 us @ 300 MHz)")

    result = {
        "bench": "dispatch",
        "size": SIZE,
        "chain_len": CHAIN,
        "reps": REPS,
        "trials": TRIALS,
        "per_launch_us": {m: p * 1e6 for m, p in per_launch.items()},
        "graph_vs_eager_sync_speedup": ratio,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    run()
