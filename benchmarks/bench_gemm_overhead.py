"""Fig 3 — GeMM: transfer / scheduling / compute breakdown vs matrix size.

Runs the GeMM functionally (Pallas kernel, int32 fixed-point like the
paper's FPU-less e-GPU) AND reports the analytic phase breakdown whose
headline claims tests/test_paper_validation.py pins:
scheduling ≈ 25 us constant → < 1 % at 256x256; transfer ≈ 20 %+.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import EGPU_4T, EGPU_8T, EGPU_16T, egpu_time
from repro.core.scheduler import optimal_ndrange
from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import counts as gemm_counts, gemm_ref

SIZES = (32, 64, 128, 256)


def run():
    print("=" * 76)
    print("Fig 3 — GeMM Tiny-OpenCL overhead breakdown (modeled @ 300 MHz)")
    print("=" * 76)
    rng = np.random.default_rng(0)
    rows = []
    # functional check once per size (int32, like the FPU-less e-GPU)
    for s in SIZES:
        a = jnp.asarray(rng.integers(-64, 64, (s, s)), jnp.int32)
        b = jnp.asarray(rng.integers(-64, 64, (s, s)), jnp.int32)
        np.testing.assert_array_equal(gemm(a, b), gemm_ref(a, b))
    print(f"functional: int32 GeMM == oracle for {SIZES}\n")
    print(f"{'config':10s} {'size':>5s} {'total ms':>9s} {'sched %':>8s} "
          f"{'transfer %':>10s} {'compute %':>9s}")
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        for s in SIZES:
            t = egpu_time(cfg, gemm_counts(s, s, s),
                          optimal_ndrange(s * s, cfg))
            tot = t.total_cycles
            row = {"config": cfg.name, "size": s,
                   "total_ms": t.total_s * 1e3,
                   "sched_pct": 100 * t.scheduling_fraction,
                   "transfer_pct": 100 * t.transfer_fraction,
                   "compute_pct": 100 * t.compute / tot}
            rows.append(row)
            print(f"{cfg.name:10s} {s:5d} {row['total_ms']:9.3f} "
                  f"{row['sched_pct']:8.2f} {row['transfer_pct']:10.2f} "
                  f"{row['compute_pct']:9.2f}")
    s16 = [r for r in rows if r["config"] == "e-gpu-16t"]
    print(f"\nclaims: sched 256x256 = {s16[-1]['sched_pct']:.2f}% (<1%); "
          f"transfer 256x256 = {s16[-1]['transfer_pct']:.1f}% (~20%+)")
    return rows


if __name__ == "__main__":
    run()
