"""Out-of-order queues vs in-order chains on a branching pipeline (ISSUE 3).

The paper's TinyCL runtime models one in-order queue; real OpenCL workloads
fan out — a shared preprocessing stage feeding several independent branches
whose results are then combined (multi-head features, filter banks).  On an
in-order queue the machine model must serialize the branches; an
out-of-order capture records the true event-dependency DAG and
``fused_modeled()`` reports the critical path, where concurrent branches
overlap.

This bench captures the SAME fan-out/fan-in pipeline both ways and compares
the modeled fused latency (deterministic — it comes from the capture-time
machine model, not wall clock), plus the fused launch wall time for
reference.  Results are appended to ``BENCH_dispatch.json`` (tagged
``"bench": "multiqueue"``) so the dispatch-overhead trajectory carries the
ordering model alongside the dispatch floor.
"""

import pathlib
import time

import jax.numpy as jnp
import numpy as np

from .history import append_entry

from repro.core import (EGPU_16T, CommandQueue, Context, Device, Kernel,
                        NDRange, fuse_breakdowns)
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref

SIZE = 128         # big enough that per-branch work dominates startup
BRANCHES = 4       # independent branches between fan-out and fan-in
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dispatch.json"


def _kern(name):
    return Kernel(name=name, executor=gemm_ref,
                  counts=lambda **kw: gemm_counts(m=SIZE, n=SIZE, k=SIZE))


def _combine_kernel():
    def combine(*xs):
        out = xs[0]
        for x in xs[1:]:
            out = out + x
        return out
    return Kernel(name="combine", executor=combine,
                  counts=lambda **kw: gemm_counts(m=SIZE, n=SIZE, k=1))


def _capture(ctx, out_of_order):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((SIZE, SIZE)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((SIZE, SIZE)) * 0.1, jnp.float32)
    ndr = NDRange((SIZE, SIZE), (8, 8))
    q = CommandQueue(ctx, out_of_order=out_of_order)
    with q.capture() as graph:
        a, wb = ctx.create_buffer(x), ctx.create_buffer(w)
        pre = q.enqueue_nd_range(_kern("pre"), ndr, (a, wb))
        branches = [
            q.enqueue_nd_range(_kern(f"branch{i}"), ndr, pre.outputs + (wb,),
                               wait_events=[pre])
            for i in range(BRANCHES)
        ]
        q.enqueue_nd_range(_combine_kernel(), ndr,
                           tuple(b.outputs[0] for b in branches),
                           wait_events=branches)
    return graph


def _launch_wall(graph, reps=20):
    graph.launch(queue_events=False)[0].data.block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        graph.launch(queue_events=False)[0].data.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run():
    print("=" * 76)
    print("Out-of-order critical path vs in-order chain "
          f"(fan-out of {BRANCHES} {SIZE}x{SIZE} GeMM branches)")
    print("=" * 76)
    ctx = Context(Device(EGPU_16T))

    ooo = _capture(ctx, out_of_order=True)
    ino = _capture(ctx, out_of_order=False)
    dag, _ = ooo.fused_modeled()
    chain, _ = ino.fused_modeled()
    # sanity: the in-order capture's DAG mode equals the classic chain sum
    assert chain.total_s == fuse_breakdowns(ino.modeled_breakdowns()).total_s

    speedup = chain.total_s / dag.total_s
    wall = _launch_wall(ooo)
    print(f"  modeled in-order chain     {chain.total_s * 1e6:9.1f} us")
    print(f"  modeled critical path      {dag.total_s * 1e6:9.1f} us")
    print(f"  critical-path speedup      {speedup:9.2f}x "
          f"({BRANCHES} branches overlap)")
    print(f"  fused launch wall          {wall * 1e6:9.1f} us "
          "(XLA executes the dataflow either way)")

    result = {
        "bench": "multiqueue",
        "size": SIZE,
        "branches": BRANCHES,
        "modeled_chain_us": chain.total_s * 1e6,
        "modeled_critical_path_us": dag.total_s * 1e6,
        "critical_path_speedup": speedup,
        "fused_launch_wall_us": wall * 1e6,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    run()
