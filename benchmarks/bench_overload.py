"""Open-loop overload: goodput under deadlines, shedding, and lane faults.

ISSUE 6's acceptance harness.  A Poisson open-loop arrival process drives
the serving front door at 2x the fleet's modeled saturation rate — the
regime where a closed-loop benchmark cannot say anything, because a real
deployment does not politely wait for the previous request to finish.
Three arms over the SAME arrival trace and request payloads:

* **fifo** — no admission control, no deadline flushing: the historical
  queue-everything server.  Under 2x load its modeled backlog grows
  linearly and almost every request completes past its deadline;
* **shed** — modeled-capacity admission control + deadline-aware partial
  flushes: infeasible requests are refused at the door, accepted ones
  overwhelmingly complete in budget, and the backlog stays bounded near
  the deadline budget;
* **shed+faults** — same, under a seeded FaultPlan that blacks out one of
  the three lanes mid-run and sprinkles launch failures: the dispatcher
  reroutes/retries, the breaker quarantines the dead lane, and every
  accepted-and-served request must stay bit-identical to the fault-free
  eager path;
* **shed+faults, traced** — the faulted arm re-run under a
  :class:`repro.obs.Tracer` (ISSUE 7): every accepted rid must grow a
  complete span tree ending in exactly one terminal (``result`` or a named
  ``shed``), the Chrome-trace export must schema-validate, and — the
  zero-perturbation gate — goodput, shed/violation/retry counts and every
  served result must match the untraced faulted arm exactly.  ``--trace
  PATH`` (or ``run(trace_path=...)``) writes the Perfetto-loadable JSON.

All timing is *modeled* virtual time (an injected clock + each lane's
``modeled_busy_until`` machine-model timeline), so goodput — in-deadline
requests per modeled second — is deterministic and CI can gate it on
shared runners: goodput(shed) and goodput(shed+faults) must be
>= 1.3x goodput(fifo).  Results append to ``BENCH_serve.json`` tagged
``bench=overload``.
"""

import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core import APU, EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.obs import Tracer, validate_chrome_trace
from repro.serve import AdmissionError, Blackout, FaultPlan, Server, env_seed

from .history import append_entry

D = 8              # feature width of the GeMM chain
CHAIN = 4          # dependent stages per request
BUCKET = 16        # single pad bucket (requests are 3..16 rows)
MAX_BATCH = 4
N_LANES = 3
MAX_PENDING = 12
N_REQUESTS = 480
OFFERED_X = 2.0    # offered load vs modeled saturation
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"


class VClock:
    """Injected virtual clock: the bench owns time."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _stages():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((D, D)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=D, n=D, k=D))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(CHAIN)]


def _requests(n, seed):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(
        (int(rng.integers(3, BUCKET + 1)), D)), jnp.float32)
        for _ in range(n)]


def _profile_spr(stages):
    """Modeled seconds-per-request of one lane on this pipeline (a separate
    throwaway server, so the measured arms start cold and unpolluted)."""
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(BUCKET,),
                 max_batch=MAX_BATCH)
    for x in _requests(MAX_BATCH, seed=99):
        srv.submit(x)
    srv.flush()
    spr = srv.dispatcher.workers[0].modeled_s_per_request()
    assert spr is not None and spr > 0
    return spr


def _run_arm(stages, xs, arrivals, budget, admission, fault_plan=None,
             tracer=None):
    clk = VClock()
    srv = Server(stages, workers=(EGPU_16T,) * N_LANES,
                 bucket_sizes=(BUCKET,), max_batch=MAX_BATCH,
                 max_pending=MAX_PENDING, admission=admission,
                 deadline_flush=admission, fault_plan=fault_plan,
                 breaker_threshold=2, breaker_cooldown=4, clock=clk,
                 tracer=tracer)
    accepted = []
    max_backlog = 0.0
    max_pending_depth = 0
    for i, (x, t_arr) in enumerate(zip(xs, arrivals)):
        clk.t = t_arr
        srv.tick()                       # deadline pump between arrivals
        backlog = min(max(0.0, w.modeled_busy_until - clk.t)
                      for w in srv.dispatcher.workers)
        max_backlog = max(max_backlog, backlog)
        try:
            accepted.append((i, srv.submit(x, deadline=budget)))
        except AdmissionError:
            pass
        max_pending_depth = max(max_pending_depth, srv.batcher.n_pending)
    srv.flush()
    return srv, accepted, max_backlog, max_pending_depth


def run(trace_path=None):
    print("=" * 76)
    print(f"Open-loop overload: Poisson arrivals at {OFFERED_X:.1f}x modeled "
          f"saturation, {N_LANES} lanes")
    print(f"({N_REQUESTS} requests, chain of {CHAIN} {D}x{D} GeMM stages, "
          f"bucket {BUCKET}, batch {MAX_BATCH}; modeled virtual time)")
    print("=" * 76)
    stages = _stages()
    spr = _profile_spr(stages)
    batch_s = spr * MAX_BATCH            # one micro-batch's service time
    budget = 4.0 * batch_s               # per-request deadline budget
    sat_rate = N_LANES / spr             # fleet saturation, requests/s
    rng = np.random.default_rng(7)       # arrival process (fixed, all arms)
    arrivals = np.cumsum(rng.exponential(
        1.0 / (OFFERED_X * sat_rate), N_REQUESTS))
    xs = _requests(N_REQUESTS, seed=21)
    print(f"  modeled {spr * 1e6:8.2f} us/request -> saturation "
          f"{sat_rate:,.0f} req/s, deadline budget {budget * 1e6:.1f} us")

    def _fault_plan():
        # a fresh plan per arm (draws are pure functions of the seed, so
        # the arms see identical faults; per-plan injection counters stay
        # per-arm)
        return FaultPlan(
            seed=env_seed(42), p_launch_fail=0.05,
            blackouts=(Blackout("0:e-gpu-16t", start=5, length=7),))

    fault_plan = _fault_plan()
    tracer = Tracer()
    arms = {
        "fifo": _run_arm(stages, xs, arrivals, budget, admission=False),
        "shed": _run_arm(stages, xs, arrivals, budget, admission=True),
        "shed_faulted": _run_arm(stages, xs, arrivals, budget,
                                 admission=True, fault_plan=fault_plan),
        "shed_faulted_traced": _run_arm(stages, xs, arrivals, budget,
                                        admission=True,
                                        fault_plan=_fault_plan(),
                                        tracer=tracer),
    }

    # bit-identity of every served request in the FAULTED arm (the one
    # whose batches were rerouted/retried) against the eager path
    apu = APU(EGPU_16T)
    refs = {}
    srv_f, accepted_f, _, _ = arms["shed_faulted"]
    n_checked = 0
    bit_identical = True
    for i, rid in accepted_f:
        try:
            (got,) = srv_f.result(rid)
        except AdmissionError:
            continue                     # shed after acceptance: loud, fine
        if i not in refs:
            outs, _ = apu.offload(stages, (xs[i],), mode="eager")
            refs[i] = np.asarray(outs[0].data)
        bit_identical &= bool(np.array_equal(np.asarray(got), refs[i]))
        n_checked += 1
    assert bit_identical, "faulted-arm results diverged from eager path"
    assert n_checked > 0

    # ISSUE 7: the traced arm accounts for EVERY accepted request — one
    # complete span tree per rid, ending in exactly one terminal — and its
    # served results stay bit-identical to the eager refs
    srv_t, accepted_t, _, _ = arms["shed_faulted_traced"]
    assert tracer.request_rids() == sorted(rid for _, rid in accepted_t)
    tree_errors = tracer.validate_request_trees()
    assert not tree_errors, tree_errors
    n_traced_served = 0
    for i, rid in accepted_t:
        try:
            (got,) = srv_t.result(rid)
        except AdmissionError:
            continue
        if i not in refs:
            outs, _ = apu.offload(stages, (xs[i],), mode="eager")
            refs[i] = np.asarray(outs[0].data)
        assert np.array_equal(np.asarray(got), refs[i]), (
            "traced-arm result diverged from eager path")
        n_traced_served += 1

    goodput = {}
    rows = {}
    for name, (srv, accepted, max_backlog, max_depth) in arms.items():
        rep = srv.report()
        goodput[name] = rep.goodput_per_s_modeled
        rows[name] = dict(
            accepted=len(accepted), shed=rep.n_shed,
            violations=rep.n_deadline_violations,
            deadline_flushes=rep.deadline_flushes,
            retries=rep.n_retries, quarantines=rep.n_quarantines,
            dispatch_failures=rep.n_dispatch_failures,
            max_backlog_s=max_backlog, max_pending_depth=max_depth)
        print(f"  {name:12s} goodput {rep.goodput_per_s_modeled:10,.0f} "
              f"req/s modeled  {len(accepted):3d} accepted  "
              f"{rep.n_shed:3d} shed  {rep.n_deadline_violations:3d} late  "
              f"backlog <= {max_backlog * 1e6:8.1f} us")

    # zero-perturbation gate: tracing must not move a single modeled number
    assert goodput["shed_faulted_traced"] == goodput["shed_faulted"], (
        "tracing perturbed modeled goodput")
    assert rows["shed_faulted_traced"] == rows["shed_faulted"], (
        "tracing perturbed the modeled serving outcome")

    trace_doc = tracer.to_chrome_json(trace_path)
    schema_errors = validate_chrome_trace(trace_doc)
    assert not schema_errors, schema_errors
    if trace_path is not None:
        print(f"  traced arm: {len(tracer.spans)} spans over "
              f"{len(tracer.request_rids())} request trees -> {trace_path}")

    fifo = max(goodput["fifo"], 1e-12)
    speedup = goodput["shed"] / fifo
    speedup_faulted = goodput["shed_faulted"] / fifo
    print(f"\n  shedding goodput {speedup:.2f}x fifo; with a lane killed + "
          f"5% launch failures {speedup_faulted:.2f}x (>= 1.3x CI gate)")
    print(f"  faulted arm: {rows['shed_faulted']['retries']} retries, "
          f"{rows['shed_faulted']['quarantines']} quarantines, "
          f"{n_checked} served results bit-identical to eager")
    # bounded queues: shedding caps the modeled backlog near the deadline
    # budget while FIFO's grows with the run length
    for name in ("shed", "shed_faulted"):
        assert rows[name]["max_backlog_s"] <= 2.0 * budget, (
            name, rows[name]["max_backlog_s"], budget)
        assert rows[name]["max_pending_depth"] <= MAX_PENDING
    assert rows["fifo"]["max_backlog_s"] > 3.0 * budget

    result = {
        "bench": "overload",
        "offered_x": OFFERED_X,
        "n_requests": N_REQUESTS,
        "n_lanes": N_LANES,
        "chain_len": CHAIN,
        "bucket": BUCKET,
        "max_batch": MAX_BATCH,
        "max_pending": MAX_PENDING,
        "modeled_us_per_request": spr * 1e6,
        "deadline_budget_us": budget * 1e6,
        "fault_seed": fault_plan.seed,
        "goodput_modeled": goodput,
        "goodput_vs_fifo_speedup": speedup,
        "goodput_faulted_vs_fifo_speedup": speedup_faulted,
        "arms": rows,
        "bit_identical_under_faults": bit_identical,
        "n_bit_identity_checked": n_checked,
        "trace": {
            "n_spans": len(tracer.spans),
            "n_request_trees": len(tracer.request_rids()),
            "n_traced_served": n_traced_served,
            "request_trees_complete": not tree_errors,
            "schema_valid": not schema_errors,
            "path": None if trace_path is None else str(trace_path),
        },
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--trace", metavar="PATH", default=None,
                        help="write the traced arm's Chrome trace JSON here")
    run(trace_path=parser.parse_args().trace)
