"""Power-budget serving: goodput-per-watt under the paper's envelope.

ISSUE 8's acceptance harness.  One fleet, three DVFS operating points
(:data:`repro.core.OPERATING_POINTS`) on the same e-GPU silicon — a
``turbo`` lane (450 MHz @ 0.95 V: fastest, worst energy per request), the
paper's ``nominal`` anchor (300 MHz @ 0.8 V), and a ``low`` lane
(100 MHz @ 0.60 V: 3x slower, ~2.5x more efficient).  Two arms over the
SAME request payloads:

* **uncapped** — the latency-greedy baseline: depth-based routing spreads
  micro-batches evenly across all three lanes, happily burning the turbo
  lane's ~2.1x dynamic power for a marginal latency win;
* **capped** — the same fleet under ``PowerBudget(lane_mw=28, fleet_mw=35)``
  (the paper's <= 28 mW envelope per lane): the dispatcher prices every
  candidate lane's window-average power, throttles the turbo lane (its
  draw can never fit 28 mW), and routes the remaining lanes by
  requests-per-joule — so traffic lands on the efficient silicon and the
  envelope holds by construction.

Everything is modeled virtual time + machine-model energy, so the gated
ratio is deterministic: **capped goodput-per-watt >= 1.2x uncapped** (CI
gate), with zero booked budget violations and a non-zero throttle count
proving the budget actually bit.  Results append to ``BENCH_serve.json``
tagged ``bench=power``.
"""

import pathlib

import jax.numpy as jnp
import numpy as np

from repro.core import EGPU_16T, OPERATING_POINTS, Kernel, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import PowerBudget, Server

from .history import append_entry

D = 8              # feature width of the GeMM chain
CHAIN = 4          # dependent stages per request
BUCKET = 16        # single pad bucket (requests are 3..16 rows)
MAX_BATCH = 4
N_REQUESTS = 96
LANE_MW = 28.0     # the paper's per-lane envelope
FLEET_MW = 35.0    # nominal + low lanes flat out + turbo's leakage floor
GATE_X = 1.2       # CI gate: capped goodput-per-watt vs uncapped
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

#: the three DVFS lanes: same silicon, different operating points
LANE_POINTS = ("turbo", "nominal", "low")


class VClock:
    """Injected virtual clock: the bench owns time."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _stages():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((D, D)) * 0.2, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=D, n=D, k=D))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(CHAIN)]


def _requests(n, seed):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(
        (int(rng.integers(3, BUCKET + 1)), D)), jnp.float32)
        for _ in range(n)]


def _fleet():
    return tuple(EGPU_16T.at(OPERATING_POINTS[p]) for p in LANE_POINTS)


def _run_arm(stages, xs, budget):
    clk = VClock()
    srv = Server(stages, workers=_fleet(), bucket_sizes=(BUCKET,),
                 max_batch=MAX_BATCH, clock=clk, power_budget=budget)
    rids = [srv.submit(x) for x in xs]
    srv.flush()
    outs = [srv.result(rid) for rid in rids]
    return srv, srv.report(), outs


def run():
    print("=" * 76)
    print(f"Power-budget serving: {len(LANE_POINTS)} DVFS lanes "
          f"({'/'.join(LANE_POINTS)}), capped vs latency-greedy")
    print(f"({N_REQUESTS} requests, chain of {CHAIN} {D}x{D} GeMM stages, "
          f"bucket {BUCKET}, batch {MAX_BATCH}; modeled virtual time)")
    print("=" * 76)
    stages = _stages()
    xs = _requests(N_REQUESTS, seed=21)
    budget = PowerBudget(lane_mw=LANE_MW, fleet_mw=FLEET_MW)

    _, rep_free, outs_free = _run_arm(stages, xs, budget=None)
    _, rep_cap, outs_cap = _run_arm(stages, xs, budget=budget)

    for name, rep in (("uncapped", rep_free), ("capped", rep_cap)):
        print(f"  {name:9s} gpw {rep.goodput_per_s_per_watt:12,.0f} "
              f"req/J  avg {rep.avg_fleet_power_w * 1e3:6.2f} mW  "
              f"energy {rep.fleet_energy_j * 1e6:8.1f} uJ "
              f"(idle {rep.fleet_idle_energy_j * 1e6:6.1f})  "
              f"{rep.n_power_throttled:3d} throttled  "
              f"{rep.n_power_shed:3d} shed")
        for qs, point in zip(rep.queues, LANE_POINTS):
            print(f"      lane {point:8s} {qs.batches:3d} batches "
                  f"{qs.requests:3d} reqs  {qs.energy_j * 1e6:8.1f} uJ")

    # both arms complete every request, on identical payloads — the caps
    # reroute work, they never drop it (no deadline, ample headroom)
    assert rep_free.n_requests == N_REQUESTS, rep_free.n_requests
    assert rep_cap.n_requests == N_REQUESTS, rep_cap.n_requests
    assert rep_cap.n_power_shed == 0 and rep_cap.n_shed == 0

    # budget semantics: the turbo lane cannot fit 28 mW, so the capped arm
    # must throttle it (non-zero) and route it zero batches, with ZERO
    # booked violations (the launch-time audit) and a bounded peak draw
    assert rep_cap.n_power_throttled > 0
    assert rep_cap.queues[LANE_POINTS.index("turbo")].batches == 0
    assert rep_cap.n_budget_violations == 0, rep_cap.n_budget_violations
    assert rep_cap.peak_fleet_power_w <= FLEET_MW * 1e-3 + 1e-12
    assert rep_free.n_power_throttled == 0  # uncapped arm never prices

    # DVFS never changes MATH: both arms produce bit-identical outputs
    for (a,), (b,) in zip(outs_free, outs_cap):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            "capped arm outputs diverged from uncapped")

    gpw_free = max(rep_free.goodput_per_s_per_watt, 1e-12)
    ratio = rep_cap.goodput_per_s_per_watt / gpw_free
    print(f"\n  capped goodput-per-watt {ratio:.2f}x uncapped "
          f"(>= {GATE_X:.1f}x CI gate), envelope lane<={LANE_MW:g} mW "
          f"fleet<={FLEET_MW:g} mW held with "
          f"{rep_cap.n_budget_violations} violations")
    assert ratio >= GATE_X, (
        f"goodput-per-watt {ratio:.3f}x under the {GATE_X:.1f}x gate")

    result = {
        "bench": "power",
        "n_requests": N_REQUESTS,
        "lanes": list(LANE_POINTS),
        "chain_len": CHAIN,
        "bucket": BUCKET,
        "max_batch": MAX_BATCH,
        "lane_mw": LANE_MW,
        "fleet_mw": FLEET_MW,
        "goodput_per_s_per_watt": {
            "uncapped": rep_free.goodput_per_s_per_watt,
            "capped": rep_cap.goodput_per_s_per_watt,
        },
        "goodput_per_watt_speedup": ratio,
        "avg_fleet_power_mw": {
            "uncapped": rep_free.avg_fleet_power_w * 1e3,
            "capped": rep_cap.avg_fleet_power_w * 1e3,
        },
        "peak_fleet_power_mw": rep_cap.peak_fleet_power_w * 1e3,
        "fleet_energy_uj": {
            "uncapped": rep_free.fleet_energy_j * 1e6,
            "capped": rep_cap.fleet_energy_j * 1e6,
        },
        "idle_energy_uj": {
            "uncapped": rep_free.fleet_idle_energy_j * 1e6,
            "capped": rep_cap.fleet_idle_energy_j * 1e6,
        },
        "n_power_throttled": rep_cap.n_power_throttled,
        "n_power_shed": rep_cap.n_power_shed,
        "n_budget_violations": rep_cap.n_budget_violations,
        "bit_identical_across_arms": True,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    run()
