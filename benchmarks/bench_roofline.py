"""Roofline table — reads artifacts/dryrun/*.json (never recompiles).

Per (arch x shape x mesh): the three terms in seconds

    compute    = HLO_FLOPs / peak_FLOP/s        (197 TF/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw             (819 GB/s)
    collective = link_bytes / ICI_bw            (50 GB/s/link)

(all per-device post-SPMD, scan-aware — see repro.launch.hlo_cost), the
dominant term, MODEL_FLOPS/HLO_FLOPs (compute usefulness), and the roofline
fraction (compute term / binding term).  CPU-backend caveat: bf16 dots are
upcast to f32 on this host, so memory terms are ~2x upper bounds vs TPU.
"""

import glob
import json
import os

DEFAULT_DIR = "artifacts/dryrun"


def load_records(dry_dir: str = DEFAULT_DIR, mesh: str = "pod"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh and "__opt" not in r["cell"]:
            recs.append(r)
    return recs


def run(dry_dir: str = DEFAULT_DIR):
    print("=" * 100)
    print("Roofline — per (arch x shape), single-pod 16x16 mesh "
          "(terms in s/step; dominant term capitalized)")
    print("=" * 100)
    recs = load_records(dry_dir)
    if not recs:
        print(f"no dry-run artifacts in {dry_dir}; run "
              "PYTHONPATH=src python -m repro.launch.dryrun first")
        return []
    print(f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'collect':>10s} {'useful':>7s} {'RF':>6s}  note")
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        terms = {"compute": rf["t_compute_s"], "memory": rf["t_memory_s"],
                 "collective": rf["t_collective_s"]}
        def fmt(k):
            v = terms[k]
            s = f"{v:10.3e}"
            return s.upper() if k == rf["dominant"] else s
        note = ""
        mem_gib = (r["memory"]["argument_bytes"]
                   + r["memory"]["temp_bytes"]) / 2**30
        if mem_gib > 16:
            note = f"mem {mem_gib:.0f} GiB"
        print(f"{r['arch']:22s} {r['shape']:12s} {fmt('compute')} "
              f"{fmt('memory')} {fmt('collective')} "
              f"{rf['model_flops_ratio']:7.2f} {rf['roofline_fraction']:6.3f}"
              f"  {note}")
        rows.append(r)
    doms = [r["roofline"]["dominant"] for r in rows]
    print(f"\n{len(rows)} cells | dominant: "
          + ", ".join(f"{d}={doms.count(d)}" for d in set(doms)))
    best = max(rows, key=lambda r: r["roofline"]["roofline_fraction"])
    worst = min(rows, key=lambda r: r["roofline"]["roofline_fraction"])
    print(f"best RF {best['roofline']['roofline_fraction']:.3f} "
          f"({best['cell']}); worst {worst['roofline']['roofline_fraction']:.3f} "
          f"({worst['cell']})")
    return rows


if __name__ == "__main__":
    run()
