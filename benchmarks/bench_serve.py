"""Serving-path throughput: cached CommandGraphs vs per-call re-capture.

ISSUE 2's tentpole claim is that the ``repro.serve.GraphCache`` turns the
steady-state offload path into a pure replay: without it every
``APU.offload(mode="graph")`` re-captures the chain and re-jits the fused
computation; with it the same call is a dictionary lookup + ``launch``.
This bench measures both on a chain of small dependent GeMMs (dispatch-bound
on purpose, like ``bench_dispatch``) and reports the per-offload speedup —
CI gates conservatively at >= 2x (dev hosts measure far higher; the slack
absorbs shared-runner noise).

Results are appended to ``BENCH_serve.json`` (timestamped list-of-runs, same
trajectory format as ``BENCH_dispatch.json``).
"""

import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core import APU, EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import GraphCache

from .history import append_entry

SIZE = 32
CHAIN = 6          # dependent GeMM stages per offload
REPS = 12          # offloads per trial
TRIALS = 3         # best-of (min)
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _stages():
    kern = Kernel(name="gemm_chain", executor=gemm_ref)
    w = jnp.asarray(np.eye(SIZE, dtype=np.float32)
                    + 0.01 * np.random.default_rng(0).standard_normal(
                        (SIZE, SIZE)).astype(np.float32))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(CHAIN)]


def _bench_offload(apu, stages, x):
    def one():
        outs, _ = apu.offload(stages, (x,))
        outs[0].data.block_until_ready()

    one()                                 # compile / first capture
    best = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for _ in range(REPS):
            one()
        best = min(best, time.perf_counter() - t0)
    return best / REPS


def run():
    print("=" * 76)
    print("Serving path: cached CommandGraph vs per-offload re-capture")
    print(f"(chain of {CHAIN} dependent {SIZE}x{SIZE} GeMM stages, best of "
          f"{TRIALS}x{REPS} offloads)")
    print("=" * 76)
    stages = _stages()
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (SIZE, SIZE)).astype(np.float32))

    recapture = _bench_offload(APU(EGPU_16T), stages, x)
    cache = GraphCache(capacity=8)
    cached = _bench_offload(APU(EGPU_16T, graph_cache=cache), stages, x)

    ratio = recapture / cached
    print(f"  re-capture  {recapture * 1e6:9.1f} us/offload")
    print(f"  cached      {cached * 1e6:9.1f} us/offload   "
          f"(cache: {cache.hits} hits / {cache.misses} miss)")
    print(f"\n  cached offload is {ratio:.1f}x faster than re-capture "
          f"(>= 2x CI gate)")
    assert cache.misses == 1, "steady-state offloads must never re-capture"

    result = {
        "bench": "serve",
        "size": SIZE,
        "chain_len": CHAIN,
        "reps": REPS,
        "trials": TRIALS,
        "per_offload_us": {"recapture": recapture * 1e6,
                           "cached": cached * 1e6},
        "cached_vs_recapture_speedup": ratio,
        "cache_stats": cache.stats(),
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    run()
