"""Serving-path throughput: cached CommandGraphs vs per-call re-capture.

ISSUE 2's tentpole claim is that the ``repro.serve.GraphCache`` turns the
steady-state offload path into a pure replay: without it every
``APU.offload(mode="graph")`` re-captures the chain and re-jits the fused
computation; with it the same call is a dictionary lookup + ``launch``.
This bench measures both on a chain of small dependent GeMMs (dispatch-bound
on purpose, like ``bench_dispatch``) and reports the per-offload speedup —
CI gates conservatively at >= 2x (dev hosts measure far higher; the slack
absorbs shared-runner noise).

A traced arm (ISSUE 7) replays the same pipeline through a small
``Server(tracer=...)`` session on a virtual clock: every request must grow
a complete span tree, the Chrome-trace export must schema-validate, the
traced session's report must match an untraced twin exactly, and results
stay bit-identical to the cached offload path.  ``--trace PATH`` writes
the Perfetto-loadable JSON.

Results are appended to ``BENCH_serve.json`` (timestamped list-of-runs, same
trajectory format as ``BENCH_dispatch.json``).
"""

import pathlib
import time

import jax.numpy as jnp
import numpy as np

from repro.core import APU, EGPU_16T, Kernel, Stage
from repro.kernels.gemm.ref import gemm_ref
from repro.obs import Tracer, validate_chrome_trace
from repro.serve import GraphCache, Server

from .history import append_entry

SIZE = 32
CHAIN = 6          # dependent GeMM stages per offload
REPS = 12          # offloads per trial
TRIALS = 3         # best-of (min)
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _stages():
    kern = Kernel(name="gemm_chain", executor=gemm_ref)
    w = jnp.asarray(np.eye(SIZE, dtype=np.float32)
                    + 0.01 * np.random.default_rng(0).standard_normal(
                        (SIZE, SIZE)).astype(np.float32))
    return [Stage(kern, consts=(w,), n_inputs=1) for _ in range(CHAIN)]


def _bench_offload(apu, stages, x):
    def one():
        outs, _ = apu.offload(stages, (x,))
        outs[0].data.block_until_ready()

    one()                                 # compile / first capture
    best = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for _ in range(REPS):
            one()
        best = min(best, time.perf_counter() - t0)
    return best / REPS


def _traced_session(stages, xs, tracer=None):
    """A small serve session on a virtual clock (traced when asked)."""
    t = [0.0]
    srv = Server(stages, workers=(EGPU_16T,), bucket_sizes=(SIZE,),
                 max_batch=2, clock=lambda: t[0], tracer=tracer)
    rids = []
    for i, x in enumerate(xs):
        t[0] = 1e-4 * i
        rids.append(srv.submit(x))
    t[0] = 1e-4 * len(xs) + 1e-3
    srv.flush()
    return srv, rids


def _traced_arm(stages, x, trace_path):
    """ISSUE 7 observability gate on the serve path (see module docstring)."""
    xs = [x] * 8
    tracer = Tracer()
    srv_t, rids_t = _traced_session(stages, xs, tracer=tracer)
    srv_u, rids_u = _traced_session(stages, xs, tracer=None)
    assert tracer.request_rids() == sorted(rids_t)
    tree_errors = tracer.validate_request_trees()
    assert not tree_errors, tree_errors
    rep_t, rep_u = srv_t.report(), srv_u.report()
    assert (rep_t.n_requests, rep_t.n_batches, rep_t.modeled_latency_s,
            rep_t.goodput_per_s_modeled) == (
        rep_u.n_requests, rep_u.n_batches, rep_u.modeled_latency_s,
        rep_u.goodput_per_s_modeled), "tracing perturbed the modeled report"
    ref, _ = APU(EGPU_16T).offload(stages, (x,))
    ref = np.asarray(ref[0].data)
    for rid_t, rid_u in zip(rids_t, rids_u):
        (got_t,), (got_u,) = srv_t.result(rid_t), srv_u.result(rid_u)
        assert np.array_equal(np.asarray(got_t), ref)
        assert np.array_equal(np.asarray(got_u), ref)
    doc = tracer.to_chrome_json(trace_path)
    schema_errors = validate_chrome_trace(doc)
    assert not schema_errors, schema_errors
    print(f"  traced arm: {len(tracer.spans)} spans over "
          f"{len(rids_t)} request trees, schema valid, report unperturbed"
          + ("" if trace_path is None else f" -> {trace_path}"))
    return {
        "n_spans": len(tracer.spans),
        "n_request_trees": len(rids_t),
        "request_trees_complete": not tree_errors,
        "schema_valid": not schema_errors,
        "path": None if trace_path is None else str(trace_path),
    }


def run(trace_path=None):
    print("=" * 76)
    print("Serving path: cached CommandGraph vs per-offload re-capture")
    print(f"(chain of {CHAIN} dependent {SIZE}x{SIZE} GeMM stages, best of "
          f"{TRIALS}x{REPS} offloads)")
    print("=" * 76)
    stages = _stages()
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (SIZE, SIZE)).astype(np.float32))

    recapture = _bench_offload(APU(EGPU_16T), stages, x)
    cache = GraphCache(capacity=8)
    cached = _bench_offload(APU(EGPU_16T, graph_cache=cache), stages, x)

    ratio = recapture / cached
    print(f"  re-capture  {recapture * 1e6:9.1f} us/offload")
    print(f"  cached      {cached * 1e6:9.1f} us/offload   "
          f"(cache: {cache.hits} hits / {cache.misses} miss)")
    print(f"\n  cached offload is {ratio:.1f}x faster than re-capture "
          f"(>= 2x CI gate)")
    assert cache.misses == 1, "steady-state offloads must never re-capture"

    trace = _traced_arm(stages, x, trace_path)

    result = {
        "bench": "serve",
        "size": SIZE,
        "chain_len": CHAIN,
        "reps": REPS,
        "trials": TRIALS,
        "per_offload_us": {"recapture": recapture * 1e6,
                           "cached": cached * 1e6},
        "cached_vs_recapture_speedup": ratio,
        "cache_stats": cache.stats(),
        "trace": trace,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--trace", metavar="PATH", default=None,
                        help="write the traced arm's Chrome trace JSON here")
    run(trace_path=parser.parse_args().trace)
