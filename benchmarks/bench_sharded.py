"""Sharded serving: a 2-shard data-parallel lane vs the single-worker path.

ISSUE 5's tentpole claim is that a ``repro.serve.ShardedWorker`` spanning a
2-device data mesh serves a compute-bound bucket with ~2x the modeled
requests/s of a plain single-device ``QueueWorker`` (each mesh slice runs
half the micro-batch; startup + scheduling are still paid once per launch,
so the ratio lands below 2 exactly by the dispatch fraction).  Like the
multiqueue and transfer benches, the CI gate sits on the **deterministic
machine-model** ratio (>= 1.3x): wall-clock speedup from 2 fake host
devices depends entirely on how many cores the runner has left over after
XLA's intra-op parallelism, so it is reported but not gated (a 2-core dev
host measures ~1.1-1.2x; a wider host approaches the modeled ratio).

The bench also pins the tentpole's correctness claim: the paper's TinyBio
pipeline served through the sharded lane must be **bit-identical** to the
single-device graph path, with zero key collisions in a shared GraphCache.

Everything runs in a SUBPROCESS with ``--xla_force_host_platform_device_
count=2`` (the device count must be set before jax initializes, and the
parent bench process must keep whatever device layout it started with);
results are appended to ``BENCH_serve.json`` tagged ``bench=sharded``.
"""

import json
import os
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_serve.json"

SIZE = 64          # GeMM operand side (compute-bound on the machine model)
CHAIN = 6          # dependent stages per pipeline
BATCH = 8          # micro-batch capacity (divisible by the 2 data shards)
N_REQ = 64         # timed requests per path
GATE = 1.3


def _child() -> None:
    """Measure inside the 2-device subprocess; print one JSON line."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.apps.tinybio import synth_signal, tinybio_stages
    from repro.core import EGPU_16T, Kernel, Stage
    from repro.kernels.gemm.ref import counts as gemm_counts
    from repro.kernels.gemm.ref import gemm_ref
    from repro.serve import (GraphCache, QueueWorker, Server, ShardedWorker,
                             data_mesh)

    assert len(jax.devices()) >= 2, jax.devices()
    mesh = data_mesh(2)

    def log(msg: str) -> None:
        print(msg, file=sys.stderr, flush=True)

    # -- compute-bound GeMM chain: modeled + measured requests/s ------------
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((SIZE, SIZE)) * 0.05, jnp.float32)

    def mlp(x, w):
        return jnp.maximum(gemm_ref(x, w), 0.0)

    kern = Kernel("mlp", executor=mlp,
                  counts=lambda **kw: gemm_counts(m=SIZE, n=SIZE, k=SIZE))
    stages = [Stage(kern, consts=(w,), n_inputs=1) for _ in range(CHAIN)]

    xs = [jnp.asarray(rng.standard_normal((SIZE, SIZE)), jnp.float32)
          for _ in range(N_REQ)]

    def serve_all(worker):
        srv = Server(stages, workers=(worker,), bucket_sizes=(SIZE,),
                     max_batch=BATCH, max_in_flight=2)
        x0 = jnp.zeros((SIZE, SIZE), jnp.float32)
        srv.warmup(x0)
        for x in xs[:BATCH]:             # prime: first launch jit-compiles
            srv.submit(x)
        srv.flush()
        t0 = time.perf_counter()
        rids = [srv.submit(x) for x in xs]
        srv.flush()
        wall = time.perf_counter() - t0
        outs = [np.asarray(srv.result(r)[0]) for r in rids]
        qs = srv.report().queues[0]
        assert srv.cache.misses == 1, srv.cache.stats()
        # modeled seconds for the timed traffic only (prime round excluded)
        modeled = qs.modeled_s * N_REQ / qs.requests
        return wall, modeled, outs

    log(f"[sharded] GeMM chain {CHAIN}x{SIZE}x{SIZE}, batch {BATCH}, "
        f"{N_REQ} requests per path")
    wall_1, modeled_1, outs_1 = serve_all(
        QueueWorker(EGPU_16T, name="single"))
    wall_2, modeled_2, outs_2 = serve_all(
        ShardedWorker(EGPU_16T, mesh, name="data2"))
    for a, b in zip(outs_1, outs_2):
        assert np.array_equal(a, b), "sharded GeMM chain diverged"

    modeled_speedup = modeled_1 / modeled_2
    measured_speedup = wall_1 / wall_2
    log(f"[sharded] modeled  {N_REQ / modeled_1:12,.0f} req/s single   "
        f"{N_REQ / modeled_2:12,.0f} req/s sharded   {modeled_speedup:.2f}x")
    log(f"[sharded] measured {N_REQ / wall_1:12,.0f} req/s single   "
        f"{N_REQ / wall_2:12,.0f} req/s sharded   {measured_speedup:.2f}x "
        "(not gated: wall clock on fake host devices is core-count-bound)")

    # -- TinyBio bit-identity through a shared cache ------------------------
    log("[sharded] TinyBio bucket: sharded vs single-device bit-identity")
    cache = GraphCache(capacity=8)
    bio_stages, _ = tinybio_stages(EGPU_16T)
    n = 65_536
    sigs = [jnp.asarray(synth_signal(n, seed=s)) for s in (3, 4)]

    def bio_results(worker):
        srv = Server(bio_stages, workers=(worker,), bucket_sizes=(n,),
                     max_batch=2)
        srv.cache = cache
        rids = [srv.submit(s) for s in sigs]
        srv.flush()
        return [tuple(np.asarray(o) for o in srv.result(r)) for r in rids]

    bio_1 = bio_results(QueueWorker(EGPU_16T, name="bio-single"))
    bio_2 = bio_results(ShardedWorker(EGPU_16T, mesh, name="bio-data2"))
    identical = all(
        len(a) == len(b) and all(np.array_equal(x, y) for x, y in zip(a, b))
        for a, b in zip(bio_1, bio_2))
    assert cache.misses == 2 and cache.evictions == 0, cache.stats()
    log(f"[sharded] TinyBio bit-identical: {identical}, cache "
        f"{cache.stats()['misses']} misses (zero collisions)")

    print(json.dumps({
        "bench": "sharded",
        "mesh": {"data": 2},
        "size": SIZE,
        "chain_len": CHAIN,
        "max_batch": BATCH,
        "n_requests": N_REQ,
        "shards": 2,
        "requests_per_s_modeled": {"single": N_REQ / modeled_1,
                                   "sharded": N_REQ / modeled_2},
        "requests_per_s_modeled_speedup": modeled_speedup,
        "requests_per_s_measured": {"single": N_REQ / wall_1,
                                    "sharded": N_REQ / wall_2},
        "requests_per_s_measured_speedup": measured_speedup,
        "tinybio_bit_identical": bool(identical),
        "tinybio_cache_stats": cache.stats(),
    }))


def run():
    print("=" * 76)
    print("Sharded serving: 2-shard data-parallel lane vs single worker")
    print(f"(chain of {CHAIN} dependent {SIZE}x{SIZE} GeMM stages, "
          f"micro-batch {BATCH}, subprocess with 2 forced host devices)")
    print("=" * 76)
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    flags.append("--xla_force_host_platform_device_count=2")
    env["XLA_FLAGS"] = " ".join(flags)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src")] + ([env["PYTHONPATH"]]
                               if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=1800)
    sys.stderr.write(proc.stderr)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded bench subprocess failed (rc {proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    result = json.loads(proc.stdout.strip().splitlines()[-1])

    ratio = result["requests_per_s_modeled_speedup"]
    print(f"  modeled  requests/s speedup {ratio:.2f}x (>= {GATE}x CI gate)")
    print(f"  measured requests/s speedup "
          f"{result['requests_per_s_measured_speedup']:.2f}x (reported, "
          "not gated)")
    print(f"  TinyBio sharded output bit-identical: "
          f"{result['tinybio_bit_identical']}")
    assert ratio >= GATE, (
        f"2-shard lane models only {ratio:.2f}x the single-worker "
        "requests/s — the data-parallel scaling (or its accounting) broke")
    assert result["tinybio_bit_identical"], \
        "sharded TinyBio output diverged from the single-device graph path"

    from .history import append_entry
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        run()
