"""Fig 2 — static characterization: area & leakage breakdown per config."""

from repro.core import EGPU_4T, EGPU_8T, EGPU_16T, HOST, characterize
from repro.core.power import egpu_active_power_mw, host_active_power_mw

PAPER = {
    "x-heep-host": dict(area=0.15, leak=29.50),
    "e-gpu-4t": dict(area=0.24, leak=130.13),
    "e-gpu-16t": dict(area=0.38, leak=305.32),
}


def run():
    print("=" * 76)
    print("Fig 2 — area / leakage breakdown (TSMC16 @ 300 MHz / 0.8 V)")
    print("=" * 76)
    header = (f"{'system':14s} {'area mm2':>9s} {'(x host)':>9s} "
              f"{'leak uW':>9s} {'(x host)':>9s} {'P mW':>7s} "
              f"{'paper area/leak':>17s}")
    print(header)
    rows = []
    for cfg in (HOST, EGPU_4T, EGPU_8T, EGPU_16T):
        ch = characterize(cfg)
        power = (host_active_power_mw() if cfg.name == HOST.name
                 else egpu_active_power_mw(cfg))
        p = PAPER.get(cfg.name)
        ref = f"{p['area']:.2f}/{p['leak']:.1f}" if p else "—"
        print(f"{cfg.name:14s} {ch.total_area_mm2:9.3f} "
              f"{ch.area_overhead:8.2f}x {ch.total_leak_uw:9.2f} "
              f"{ch.leak_overhead:8.1f}x {power:7.1f} {ref:>17s}")
        rows.append({"name": cfg.name, "area_mm2": ch.total_area_mm2,
                     "leak_uw": ch.total_leak_uw, "power_mw": power,
                     "area_overhead": ch.area_overhead,
                     "leak_overhead": ch.leak_overhead})
    print("breakdown (16T): ", end="")
    ch = characterize(EGPU_16T)
    print(f"host {ch.host_area_mm2:.3f} | I$ {ch.icache_area_mm2:.3f} | "
          f"D$ {ch.dcache_area_mm2:.3f} | CUs {ch.cu_area_mm2:.3f} mm2")
    return rows


if __name__ == "__main__":
    run()
