"""Fig 4 — TinyBio: per-stage speed-up & energy reduction vs the host.

Runs the real 4-stage pipeline (FIR → delineation → FFT features → SVM) on
the TinyCL runtime for each e-GPU config; the modeled comparison reproduces
the paper's Fig-4 bands (pinned by tests/test_paper_validation.py).
"""

from repro.apps.tinybio import run_tinybio
from repro.core import EGPU_4T, EGPU_8T, EGPU_16T

PAPER = {  # (4T, 16T) anchors from the paper
    "fir": (3.6, 15.1), "delineate_keep": (3.1, 13.1),
    "fft_features": (3.3, 14.0), "app": (3.4, 14.3),
}


def run():
    print("=" * 76)
    print("Fig 4 — TinyBio speed-up & energy vs X-HEEP host (modeled)")
    print("=" * 76)
    rows = []
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        decisions, rep = run_tinybio(cfg)
        per = {s.name: (s.speedup, s.energy_reduction) for s in rep.stages}
        per["app"] = (rep.overall_speedup, rep.overall_energy_reduction)
        rows.append({"config": cfg.name, **{k: v[0] for k, v in per.items()}})
        parts = " | ".join(f"{k.split('_')[0]} {v[0]:5.2f}x/E{v[1]:4.2f}"
                           for k, v in per.items())
        print(f"{cfg.name:10s} {parts}")
    print("\npaper bands:  fir 3.6–15.1x | delineation 3.1–13.1x | "
          "fft 3.3–14.0x | app 3.4–14.3x | energy 1.7–3.1x")
    return rows


if __name__ == "__main__":
    run()
