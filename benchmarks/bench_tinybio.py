"""Fig 4 — TinyBio: per-stage speed-up & energy reduction vs the host.

Runs the real 4-stage pipeline (FIR → delineation → FFT features → SVM) on
the TinyCL runtime for each e-GPU config; the modeled comparison reproduces
the paper's Fig-4 bands (pinned by tests/test_paper_validation.py).

Since ISSUE 1 the pipeline dispatches through a fused CommandGraph by
default; this bench runs eager and graph side by side (both warmed up, so
jit compilation is amortized out of both paths), checks the outputs are
numerically identical, and reports the wall clock of each per pipeline run
plus the fused (dispatch-once-per-chain) modeled speed-up.  On CPU the
walls sit close together — interpret-mode Pallas compute dominates both
paths; the per-kernel dispatch win itself is isolated by
``bench_dispatch.py``.
"""

import time

import numpy as np

from repro.apps.tinybio import tinybio_stages
from repro.core import APU, EGPU_4T, EGPU_8T, EGPU_16T, CommandQueue

PAPER = {  # (4T, 16T) anchors from the paper
    "fir": (3.6, 15.1), "delineate_keep": (3.1, 13.1),
    "fft_features": (3.3, 14.0), "app": (3.4, 14.3),
}
REPS = 5
TRIALS = 3         # best-of (min): robust to scheduler noise on shared hosts


def _best_of(once):
    once()                               # warm up (compile / trace caches)
    best = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for _ in range(REPS):
            out = once()
        best = min(best, (time.perf_counter() - t0) / REPS)
    return out, best


def _wall_eager(apu, stages, inputs):
    # ONE queue across reps: its jit cache keeps the non-pre-jitted stages
    # (delineate_keep, fft_features) warm, so reps measure dispatch, not
    # retracing; finish() only drains events new since the previous rep.
    q = CommandQueue(apu.egpu_ctx, profile=False)

    def once():
        bufs, _ = apu.wire_pipeline(q, stages, inputs)
        q.finish()
        return bufs

    return _best_of(once)


def _wall_graph(apu, stages, inputs):
    graph = apu.capture_pipeline(stages, inputs)

    def once():
        outs = graph.launch(queue_events=False)
        for o in outs:
            o.data.block_until_ready()
        return outs

    return _best_of(once)


def run():
    print("=" * 76)
    print("Fig 4 — TinyBio speed-up & energy vs X-HEEP host (modeled)")
    print("=" * 76)
    rows = []
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        # ONE APU + stage set per config: the report, the eager timing, and
        # the graph timing share kernels and jit caches instead of tracing
        # the 4-stage chain three separate times.
        apu = APU(cfg)
        stages, inputs = tinybio_stages(cfg)
        (dec_buf,), rep = apu.offload(stages, inputs, mode="graph")
        decisions = dec_buf.data
        (eager_out,), wall_eager = _wall_eager(apu, stages, inputs)
        (graph_out,), wall_graph = _wall_graph(apu, stages, inputs)
        np.testing.assert_allclose(np.asarray(graph_out.data),
                                   np.asarray(eager_out.data), atol=1e-5)
        np.testing.assert_allclose(np.asarray(graph_out.data),
                                   np.asarray(decisions), atol=1e-5)
        per = {s.name: (s.speedup, s.energy_reduction) for s in rep.stages}
        per["app"] = (rep.overall_speedup, rep.overall_energy_reduction)
        rows.append({"config": cfg.name,
                     **{k: v[0] for k, v in per.items()},
                     "fused_speedup": rep.fused_speedup,
                     "wall_eager_s": wall_eager,
                     "wall_graph_s": wall_graph})
        parts = " | ".join(f"{k.split('_')[0]} {v[0]:5.2f}x/E{v[1]:4.2f}"
                           for k, v in per.items())
        print(f"{cfg.name:10s} {parts}")
        print(f"{'':10s} fused-chain {rep.fused_speedup:5.2f}x | warm "
              f"pipeline wall: eager {wall_eager*1e3:7.1f} ms vs graph "
              f"{wall_graph*1e3:7.1f} ms (outputs identical)")
    print("\npaper bands:  fir 3.6–15.1x | delineation 3.1–13.1x | "
          "fft 3.3–14.0x | app 3.4–14.3x | energy 1.7–3.1x")
    return rows


if __name__ == "__main__":
    run()
