"""Explicit-transfer graphs vs naive per-stage round-trips (ISSUE 4).

Before the host API v2, host<->e-GPU traffic was a per-kernel heuristic
baked into ``egpu_time`` — invisible to the DAG scheduler, so every stage
paid its own (partially overlapped) round-trip and nothing could be hoisted
or overlapped across stages.  With explicit ``enqueue_write_buffer`` /
``enqueue_read_buffer`` commands, transfers are first-class DAG nodes: a
fan-out pipeline writes each operand ONCE, runs its branches resident, reads
the result once — and the critical-path model overlaps the sibling
branches' transfers with compute.

This bench captures the SAME fan-out/fan-in pipeline both ways:

* **naive**: an in-order chain where every stage round-trips — write its
  operands, compute, read its result back (the pre-v2 world view);
* **explicit**: an out-of-order capture with write-once / read-once
  transfer nodes and resident kernels, fused as a dependency DAG.

The modeled ratio is deterministic (capture-time machine model, not wall
clock).  Results append to ``BENCH_dispatch.json`` tagged
``"bench": "transfer"``; CI gates the ratio at >= 1.2x.
"""

import pathlib
import time

import jax.numpy as jnp
import numpy as np

from .history import append_entry

from repro.core import (EGPU_16T, Buffer, CommandQueue, Context, Device,
                        Kernel, NDRange)
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref

SIZE = 128         # per-branch GeMM operand size
BRANCHES = 4       # independent (write -> GeMM) branches
OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dispatch.json"


def _kern(name):
    return Kernel(name=name, executor=gemm_ref,
                  counts=lambda **kw: gemm_counts(m=SIZE, n=SIZE, k=SIZE))


def _combine_kernel():
    def combine(*xs):
        out = xs[0]
        for x in xs[1:]:
            out = out + x
        return out
    return Kernel(name="combine", executor=combine,
                  counts=lambda **kw: gemm_counts(m=SIZE, n=SIZE, k=1))


def _operands():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((SIZE, SIZE)) * 0.1, jnp.float32)
    ws = [jnp.asarray(rng.standard_normal((SIZE, SIZE)) * 0.1, jnp.float32)
          for _ in range(BRANCHES)]
    return x, ws


def _capture_explicit(ctx):
    """Out-of-order DAG: write each operand once, branches resident,
    one read at the end — transfers overlap compute across branches."""
    x, ws = _operands()
    ndr = NDRange((SIZE, SIZE), (8, 8))
    q = CommandQueue(ctx, out_of_order=True)
    with q.capture() as graph:
        bx = Buffer(jnp.zeros_like(x))
        q.enqueue_write_buffer(bx, x)
        branches = []
        for i, w in enumerate(ws):
            bw = Buffer(jnp.zeros_like(w))
            q.enqueue_write_buffer(bw, w)
            branches.append(q.enqueue_nd_range(
                _kern(f"branch{i}"), ndr, (bx, bw), _resident=True))
        out = q.enqueue_nd_range(_combine_kernel(), ndr,
                                 tuple(b.outputs[0] for b in branches),
                                 wait_events=branches, _resident=True)
        q.enqueue_read_buffer(out.outputs[0])
    return graph


def _capture_naive(ctx):
    """In-order chain where every stage round-trips its operands — the
    pre-v2 world: no transfer is shared, hoisted, or overlapped."""
    x, ws = _operands()
    ndr = NDRange((SIZE, SIZE), (8, 8))
    q = CommandQueue(ctx)
    with q.capture() as graph:
        partials = []
        for i, w in enumerate(ws):
            bx = Buffer(jnp.zeros_like(x))
            bw = Buffer(jnp.zeros_like(w))
            q.enqueue_write_buffer(bx, x)
            q.enqueue_write_buffer(bw, w)
            ev = q.enqueue_nd_range(_kern(f"branch{i}"), ndr, (bx, bw),
                                    _resident=True)
            partials.append(q.enqueue_read_buffer(ev.outputs[0]))
        combined = []
        for p in partials:                       # round-trip back in
            bp = Buffer(jnp.zeros((SIZE, SIZE), jnp.float32))
            q.enqueue_write_buffer(bp, p.outputs[0])
            combined.append(bp)
        out = q.enqueue_nd_range(_combine_kernel(), ndr, tuple(combined),
                                 _resident=True)
        q.enqueue_read_buffer(out.outputs[0])
    return graph


def _launch_wall(graph, reps=20):
    graph.launch(queue_events=False)[0].data.block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        graph.launch(queue_events=False)[0].data.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run():
    print("=" * 76)
    print("Explicit-transfer DAG vs naive per-stage round-trips "
          f"({BRANCHES} {SIZE}x{SIZE} GeMM branches)")
    print("=" * 76)
    ctx = Context(Device(EGPU_16T))

    explicit = _capture_explicit(ctx)
    naive = _capture_naive(ctx)
    n_xfer = sum(1 for n in explicit.nodes if n.is_transfer)
    n_naive_xfer = sum(1 for n in naive.nodes if n.is_transfer)
    dag, _ = explicit.fused_modeled()
    chain, _ = naive.fused_modeled()
    # both graphs compute the identical function
    a = explicit.launch(queue_events=False)[0].data
    b = naive.launch(queue_events=False)[0].data
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    speedup = chain.total_s / dag.total_s
    wall = _launch_wall(explicit)
    print(f"  naive round-trip chain     {chain.total_s * 1e6:9.1f} us "
          f"({n_naive_xfer} transfer nodes, serial)")
    print(f"  explicit-transfer DAG      {dag.total_s * 1e6:9.1f} us "
          f"({n_xfer} transfer nodes on the critical-path model)")
    print(f"  modeled speedup            {speedup:9.2f}x "
          "(write-once + overlap vs per-stage round-trips)")
    print(f"  exposed transfer cycles    {chain.transfer:9.0f} -> "
          f"{dag.transfer:.0f}")
    print(f"  fused launch wall          {wall * 1e6:9.1f} us")

    result = {
        "bench": "transfer",
        "size": SIZE,
        "branches": BRANCHES,
        "explicit_transfer_nodes": n_xfer,
        "naive_transfer_nodes": n_naive_xfer,
        "modeled_naive_roundtrip_us": chain.total_s * 1e6,
        "modeled_explicit_dag_us": dag.total_s * 1e6,
        "explicit_vs_naive_speedup": speedup,
        "fused_launch_wall_us": wall * 1e6,
    }
    history = append_entry(OUT_PATH, result)
    print(f"  appended to {OUT_PATH.name} (run #{len(history)})")
    return result


if __name__ == "__main__":
    run()
