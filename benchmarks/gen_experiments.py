"""Generate EXPERIMENTS.md from dry-run artifacts + the benchmark models.

    PYTHONPATH=src python -m benchmarks.gen_experiments > EXPERIMENTS.md
"""

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


def _load(mesh):
    recs = {}
    for f in sorted(glob.glob(os.path.join(REPO, "artifacts/dryrun/*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh:
            recs[(r["arch"], r["shape"])] = r
    return recs


def paper_validation():
    from repro.apps.tinybio import TINYBIO_WORKLOAD, run_tinybio
    from repro.core import (EGPU_4T, EGPU_16T, characterize,
                            egpu_active_power_mw, egpu_time)
    from repro.core.scheduler import optimal_ndrange
    from repro.kernels.gemm.ref import counts as gemm_counts

    out = []
    out.append("## §Paper-validation — the faithful reproduction\n")
    out.append("Analytic machine/power model (calibrated once on the "
               "TinyBio workload\n`" + str(TINYBIO_WORKLOAD) + "`) vs the "
               "paper's published claims.  All rows are\nasserted by "
               "`tests/test_paper_validation.py`.\n")
    out.append("| metric | paper | reproduced | Δ |")
    out.append("|---|---|---|---|")
    rows = []
    a4 = characterize(EGPU_4T); a16 = characterize(EGPU_16T)
    rows.append(("area 4T/16T (mm²)", "0.24 / 0.38",
                 f"{a4.total_area_mm2:.3f} / {a16.total_area_mm2:.3f}"))
    rows.append(("area overhead", "1.6x / 2.5x",
                 f"{a4.area_overhead:.2f}x / {a16.area_overhead:.2f}x"))
    rows.append(("leakage 4T/16T (µW)", "130.13 / 305.32",
                 f"{a4.total_leak_uw:.1f} / {a16.total_leak_uw:.1f}"))
    rows.append(("leakage overhead", "4.4x / 10.3x",
                 f"{a4.leak_overhead:.1f}x / {a16.leak_overhead:.1f}x"))
    rows.append(("power budget 16T", "<= 28 mW",
                 f"{egpu_active_power_mw(EGPU_16T):.1f} mW"))
    t = egpu_time(EGPU_16T, gemm_counts(256, 256, 256),
                  optimal_ndrange(256 * 256, EGPU_16T))
    sched_us = (t.startup + t.scheduling) / EGPU_16T.freq_hz * 1e6
    rows.append(("Tiny-OpenCL scheduling", "~25 µs constant",
                 f"{sched_us:.1f} µs constant (all sizes)"))
    rows.append(("scheduling @ GeMM 256²", "< 1 %",
                 f"{t.scheduling_fraction*100:.2f} %"))
    rows.append(("transfer @ GeMM 256² (16T)", "~20 %+",
                 f"{t.transfer_fraction*100:.1f} %"))
    stage_names = {"fir": "fir", "delineate_keep": "delineation",
                   "fft_features": "fft", }
    paper_bands = {"fir": "3.6–15.1x", "delineation": "3.1–13.1x",
                   "fft": "3.3–14.0x", "whole app": "3.4–14.3x",
                   "energy": "1.7–3.1x"}
    reps = {}
    for cfg in (EGPU_4T, EGPU_16T):
        _, rep = run_tinybio(cfg)
        for s in rep.stages:
            nm = stage_names.get(s.name)
            if nm:
                reps.setdefault(nm, []).append(s.speedup)
        reps.setdefault("whole app", []).append(rep.overall_speedup)
        reps.setdefault("energy", []).append(rep.overall_energy_reduction)
    for nm in ("fir", "delineation", "fft", "whole app", "energy"):
        lo, hi = reps[nm]
        rows.append((f"TinyBio {nm} (4T→16T)", paper_bands[nm],
                     f"{lo:.2f}–{hi:.2f}x"))
    for name, paper, got in rows:
        out.append(f"| {name} | {paper} | {got} | ±15% band |")
    return "\n".join(out)


def dryrun_section():
    pod = _load("pod")
    multi = _load("multipod")
    out = []
    out.append("\n## §Dry-run — 31 live cells x 2 meshes, all compiled\n")
    out.append("`lower().compile()` succeeds for every (arch x shape) on the "
               "single-pod `(data=16, model=16)` mesh AND the multi-pod "
               "`(pod=2, data=16, model=16)` mesh "
               f"({len(pod)} + {len(multi)} cells).  Per-cell regime and "
               "per-device memory budget (analytic, from the sharding "
               "rules — `memory_analysis()` on this CPU host additionally "
               "carries f32 shadows of bf16 buffers that do not exist on "
               "the TPU target; both are recorded in the artifact JSONs):\n")
    out.append("| arch | shape | regime | µb/remat | budget GiB (fits 16?) "
               "| compile s (pod/multi) |")
    out.append("|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(pod.items()):
        m = multi.get((arch, shape))
        tc = r.get("train_config") or {}
        reg = r["rules"]
        ub = (f"{tc.get('microbatches')}/{tc.get('remat')}"
              + ("/bf16" if tc.get("param_dtype") == "bfloat16" else "")
              if tc else "—")
        bud = r.get("memory_budget", {}).get("total_gib", float("nan"))
        fits = "yes" if bud <= 16 else "**NO**"
        cm = f"{r['compile_s']:.0f}/{m['compile_s']:.0f}" if m else "—"
        out.append(f"| {arch} | {shape} | {reg} | {ub} | "
                   f"{bud:.1f} ({fits}) | {cm} |")
    skipped = [
        ("long_500k", "deepseek/moonshot/paligemma/stablelm/mistral/"
         "minicpm/qwen", "pure full attention: O(S²) at 512k"),
        ("decode_32k + long_500k", "hubert-xlarge", "encoder-only"),
    ]
    out.append("\nSkipped cells (DESIGN.md §4): ")
    for sh, a, why in skipped:
        out.append(f"* `{sh}` for {a} — {why}")
    return "\n".join(out)


def roofline_section():
    pod = _load("pod")
    out = []
    out.append("\n## §Roofline — three terms per cell (single-pod)\n")
    out.append("TPU v5e constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s "
               "ICI/link.  FLOPs/bytes are per-device from the scan-aware "
               "HLO analyzer (`repro.launch.hlo_cost` — XLA's own "
               "`cost_analysis()` counts while-loop bodies once); "
               "collectives use ring accounting with per-op group sizes, "
               "so in-pod and cross-pod traffic separate.  `useful` = "
               "MODEL_FLOPS (6·N_active·D train / 2·N_active·D serve) / "
               "global HLO FLOPs.  CPU-backend caveat: bf16 dots are "
               "upcast to f32 on this host, inflating byte terms ~2x vs "
               "the TPU target; the XLA fallback attention also "
               "materializes score blocks the Pallas flash kernel keeps "
               "in VMEM.\n")
    out.append("| arch | shape | t_compute | t_memory | t_coll | dominant "
               "| useful | RF | next lever |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    levers = {
        "train": "overlap grad RS with next µb fwd; bf16-native dots",
        "prefill": "Pallas flash kernel keeps scores in VMEM",
        "decode": "batch growth amortizes the param read (memory-bound "
                  "by physics at B=128)",
    }
    for (arch, shape), r in sorted(pod.items()):
        rf = r["roofline"]
        lever = levers.get(r["kind"], "")
        out.append(
            f"| {arch} | {shape} | {rf['t_compute_s']:.2e} | "
            f"{rf['t_memory_s']:.2e} | {rf['t_collective_s']:.2e} | "
            f"{rf['dominant']} | {rf['model_flops_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.3f} | {lever} |")
    doms = [r["roofline"]["dominant"] for r in pod.values()]
    out.append(f"\nDominant terms: " + ", ".join(
        f"{d} x{doms.count(d)}" for d in sorted(set(doms))))
    return "\n".join(out)


def main():
    print("# EXPERIMENTS — e-GPU reproduction + datacenter-scale framework\n")
    print("Scope: (1) validate the faithful e-GPU/Tiny-OpenCL reproduction "
          "against the\npaper's own claims; (2) prove the 10-arch x 4-shape "
          "x 2-mesh distribution\nconfig compiles and fits; (3) derive the "
          "roofline and log the perf\niterations.  Artifacts: "
          "`artifacts/dryrun/*.json` (one per cell), regenerate\nwith "
          "`PYTHONPATH=src python -m repro.launch.dryrun --mesh both` then\n"
          "`PYTHONPATH=src python -m benchmarks.gen_experiments > "
          "EXPERIMENTS.md`.\n")
    print(paper_validation())
    print(dryrun_section())
    print(roofline_section())
    perf = os.path.join(REPO, "benchmarks", "PERF_LOG.md")
    if os.path.exists(perf):
        print("\n" + open(perf).read())


if __name__ == "__main__":
    main()
