"""Append-only benchmark trajectories (ROADMAP: BENCH_*.json are the seed of
the perf trajectory — runs must append comparable numbers, never silently
overwrite).

Format: a JSON *list* of run entries, oldest first; every entry carries a
``timestamp`` (UTC ISO-8601).  :func:`append_entry` migrates a legacy
single-object file (the PR-1 format) into ``[legacy, new]`` on first write.
"""

import datetime
import json
import pathlib
from typing import Any, Dict, List


def load_history(path: pathlib.Path) -> List[Dict[str, Any]]:
    """Existing runs at ``path`` (a legacy single dict becomes a 1-list)."""
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    if isinstance(data, dict):       # pre-trajectory format: one bare run
        return [data]
    return list(data)


def append_entry(path: pathlib.Path, entry: Dict[str, Any]
                 ) -> List[Dict[str, Any]]:
    """Stamp ``entry`` and append it to the trajectory at ``path``.

    Returns the full history (the new entry last) after writing.
    """
    history = load_history(path)
    stamped = dict(entry)
    stamped.setdefault("timestamp", datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds"))
    history.append(stamped)
    path.write_text(json.dumps(history, indent=2) + "\n")
    return history
