"""Benchmark orchestrator: one bench per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only static|gemm|tinybio|dispatch|multiqueue|serve|overload|roofline]
"""

import argparse
import time

from . import (bench_dispatch, bench_gemm_overhead, bench_multiqueue,
               bench_overload, bench_roofline, bench_serve, bench_sharded,
               bench_static, bench_tinybio, bench_transfer)

BENCHES = {
    "static": bench_static.run,        # paper Fig 2
    "gemm": bench_gemm_overhead.run,   # paper Fig 3
    "tinybio": bench_tinybio.run,      # paper Fig 4
    "dispatch": bench_dispatch.run,    # §VIII-B measured analogue
    "multiqueue": bench_multiqueue.run,  # ISSUE-3 out-of-order critical path
    "transfer": bench_transfer.run,    # ISSUE-4 explicit-transfer DAG
    "serve": bench_serve.run,          # ISSUE-2 cached-graph serving path
    "sharded": bench_sharded.run,      # ISSUE-5 mesh-sharded serving lane
    "overload": bench_overload.run,    # ISSUE-6 open-loop goodput under faults
    "roofline": bench_roofline.run,    # EXPERIMENTS §Roofline table
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    t0 = time.time()
    for name in names:
        BENCHES[name]()
        print()
    print(f"[benchmarks] {len(names)} suites in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
