"""Benchmark orchestrator: one bench per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only static|gemm|tinybio|dispatch|multiqueue|serve|overload|power|decode|roofline]
                                            [--trace PATH]

``--trace PATH`` exports each traced serve bench's Chrome trace JSON
(ISSUE 7): with one traced bench selected the file lands at PATH verbatim;
with several, each gets a ``PATH`` suffixed by the bench name before the
extension (``trace.json`` -> ``trace.serve.json`` / ``trace.overload.json``).
"""

import argparse
import pathlib
import time

from . import (bench_decode, bench_dispatch, bench_gemm_overhead,
               bench_multiqueue, bench_overload, bench_power, bench_roofline,
               bench_serve, bench_sharded, bench_static, bench_tinybio,
               bench_transfer)

BENCHES = {
    "static": bench_static.run,        # paper Fig 2
    "gemm": bench_gemm_overhead.run,   # paper Fig 3
    "tinybio": bench_tinybio.run,      # paper Fig 4
    "dispatch": bench_dispatch.run,    # §VIII-B measured analogue
    "multiqueue": bench_multiqueue.run,  # ISSUE-3 out-of-order critical path
    "transfer": bench_transfer.run,    # ISSUE-4 explicit-transfer DAG
    "serve": bench_serve.run,          # ISSUE-2 cached-graph serving path
    "sharded": bench_sharded.run,      # ISSUE-5 mesh-sharded serving lane
    "overload": bench_overload.run,    # ISSUE-6 open-loop goodput under faults
    "power": bench_power.run,          # ISSUE-8 goodput-per-watt under budget
    "decode": bench_decode.run,        # ISSUE-9 continuous-batching decode
    "roofline": bench_roofline.run,    # EXPERIMENTS §Roofline table
}

#: benches that accept run(trace_path=...) and export a Chrome trace
TRACED_BENCHES = ("serve", "overload")


def _trace_path_for(base, name, n_traced):
    """PATH verbatim for a single traced bench, name-suffixed for many."""
    if n_traced == 1:
        return base
    p = pathlib.Path(base)
    return str(p.with_name(f"{p.stem}.{name}{p.suffix or '.json'}"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export Chrome trace JSON from the traced serve "
                         "benches (serve, overload)")
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    n_traced = sum(1 for n in names if n in TRACED_BENCHES)
    t0 = time.time()
    for name in names:
        if args.trace is not None and name in TRACED_BENCHES:
            BENCHES[name](trace_path=_trace_path_for(args.trace, name,
                                                     n_traced))
        else:
            BENCHES[name]()
        print()
    print(f"[benchmarks] {len(names)} suites in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
