"""Quickstart — the e-GPU paper's workflow in five minutes, on one CPU.

1. configure an e-GPU (Table-II knobs),
2. run an OpenCL-style kernel through the Tiny-OpenCL (TinyCL) runtime,
3. read the paper-calibrated speed-up / energy report,
4. scale the SAME knob discipline up: one reduced LM arch, one train step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (APU, EGPU_16T, EGPU_4T, Program, Stage,
                        characterize, egpu_active_power_mw)

print("=" * 70)
print("1) configure an e-GPU (paper Table II/III)")
print("=" * 70)
for cfg in (EGPU_4T, EGPU_16T):
    ch = characterize(cfg)
    print(f"  {cfg.name}: {cfg.compute_units} CUs x {cfg.threads_per_cu} "
          f"threads x {cfg.warps_per_cu} warps | D$ {cfg.dcache_bytes//1024} "
          f"KiB/{cfg.dcache_banks} banks | {ch.total_area_mm2:.2f} mm2, "
          f"{egpu_active_power_mw(cfg):.1f} mW")

print()
print("=" * 70)
print("2) offload a GeMM through TinyCL and compare against the host")
print("=" * 70)
rng = np.random.default_rng(0)
a = jnp.asarray(rng.integers(-64, 64, (256, 256)), jnp.int32)   # int math:
b = jnp.asarray(rng.integers(-64, 64, (256, 256)), jnp.int32)   # no FPU!
apu = APU(EGPU_16T)
# Tiny-OpenCL host API v2: build the program once, create kernel objects
# from the registry (clCreateProgramWithBuiltInKernels / clCreateKernel)
program = Program.build(EGPU_16T)
print(f"  program kernels: {', '.join(program.kernel_names)}")
stage = Stage(program.create_kernel("gemm"),
              counts_params={"m": 256, "n": 256, "k": 256})
# default NDRange = the paper's §VIII-B trick (work-items == hw threads,
# each looping internally) — scheduling collapses to the constant ~25 us
(out,), report = apu.offload([stage], (a, b))
np.testing.assert_array_equal(out.data, np.asarray(a) @ np.asarray(b))
st = report.stages[0]
print(f"  C=A@B 256x256 int32 OK | modeled speed-up {st.speedup:.1f}x | "
      f"energy reduction {st.energy_reduction:.1f}x")
print(f"  phases: sched {st.egpu.scheduling_fraction*100:.1f}% | "
      f"transfer {st.egpu.transfer_fraction*100:.1f}%")

print()
print("=" * 70)
print("3) the same knob discipline at datacenter scale: one train step")
print("=" * 70)
from repro.configs import ARCHS
from repro.data import DataConfig, SyntheticLMData
from repro.models import init_params, model_spec
from repro.optim import adamw_init, constant_schedule
from repro.train.step import TrainConfig, make_train_step

cfg = ARCHS["qwen2.5-3b"].reduced()
step = jax.jit(make_train_step(cfg, TrainConfig(remat="full"),
                               constant_schedule(1e-3)))
params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
state = {"params": params, "opt": adamw_init(params)}
data = SyntheticLMData(DataConfig(4, 64, cfg.vocab), cfg)
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
state, metrics = step(state, batch)
print(f"  {cfg.name}: loss {float(metrics['loss']):.3f}, "
      f"grad-norm {float(metrics['grad_norm']):.2f} — same remat/sharding "
      "knobs the 398B dry-run uses")
print("\nquickstart OK")
