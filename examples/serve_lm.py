"""Serving example: autoregressive LM decode through the ISSUE 9
continuous-batching engine — ``Server(engine=DecodeEngine(...))`` with
per-request streaming, ending in a :class:`ServeReport` printout.

A reduced GQA transformer (plain KV cache) serves a staggered stream of
prompts over a handful of decode slots: each request is prefilled
batch-1, spliced into a free slot of the persistent batched decode state,
and advanced one token per step by the replay of ONE cached
``CommandGraph`` — freed slots admit the next waiting request
mid-generation, and ``Server.stream`` yields each request's tokens as its
steps land.  The example doubles as a living integration test: it asserts
that

* the warm engine performs ZERO re-captures (one prefill graph + one
  decode graph, every launch after that a GraphCache hit), and
* every streamed result is bit-identical to eager whole-batch
  ``greedy_generate`` — slot insertion never perturbs a neighbor.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params, model_spec
from repro.serve import DecodeEngine, Server
from repro.train.serve import greedy_generate

ARCH = "qwen2.5-3b"
SLOTS = 4
N_REQUESTS = 12      # 3x oversubscribed: slots churn mid-generation
PROMPT = 12
MAX_NEW = 8
MAX_LEN = PROMPT + MAX_NEW + 1


def main():
    cfg = ARCHS[ARCH].reduced()
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab,
                                          (N_REQUESTS, PROMPT)),
        jnp.int32)

    engine = DecodeEngine(cfg, params, num_slots=SLOTS, max_len=MAX_LEN)
    server = Server((), workers=(), engine=engine)

    # -- submit everything up front; stream one request while it decodes ----
    t0 = time.perf_counter()
    rids = [server.submit_decode(prompts[i], max_new=MAX_NEW)
            for i in range(N_REQUESTS)]
    streamed = list(server.stream(rids[0]))    # live per-step iterator
    server.flush()                             # drain the remaining slots
    wall = time.perf_counter() - t0

    # -- zero re-capture: ONE prefill graph + ONE decode graph --------------
    assert engine.cache.misses == 2, (
        f"engine re-captured a graph: {engine.cache.stats()}")

    # -- streamed == eager whole-batch greedy decode, bit for bit -----------
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=MAX_NEW,
                                     max_len=MAX_LEN))
    assert streamed == [int(t) for t in ref[0]], (
        "streamed tokens diverged from eager greedy decode")
    for i, rid in enumerate(rids):
        (got,) = server.result(rid)
        assert np.array_equal(np.asarray(got), ref[i]), (
            f"request {rid}: engine decode diverged from eager greedy")

    report = server.report()
    roof = engine.roofline()
    print("=" * 72)
    print(f"serve_lm: {N_REQUESTS} requests x {MAX_NEW} tokens ({ARCH} "
          f"reduced) on {SLOTS} decode slots")
    print("=" * 72)
    print(report.summary())
    print(f"\n{report.engine_tokens_per_s_modeled:,.0f} tok/s modeled "
          f"({N_REQUESTS * MAX_NEW / wall:,.0f} tok/s wall incl. capture), "
          f"occupancy {report.engine_slot_occupancy:.0%}, "
          f"{roof.bytes_per_step:,.0f} B/step "
          f"({roof.mem_bound_fraction:.0%} memory-bound)")
    print("\nserve_lm OK — warm engine re-captured nothing; streamed "
          "results bit-identical to eager greedy decode")
    return report


if __name__ == "__main__":
    main()
