"""Serving example: batched prefill + greedy decode with per-family caches.

Loads three reduced archs — a GQA transformer (qwen), the MLA+MoE family
(deepseek, compressed latent cache) and the attention-free rwkv6 (O(1)
state) — and generates continuations for a batch of prompts, demonstrating
that one serving API covers every cache kind in the zoo.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params, model_spec
from repro.train.serve import greedy_generate

BATCH, PROMPT, NEW = 4, 24, 8

for arch in ("qwen2.5-3b", "deepseek-v2-236b", "rwkv6-3b"):
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (BATCH, PROMPT)),
        jnp.int32)
    out = greedy_generate(params, cfg, prompts, max_new=NEW,
                          max_len=PROMPT + NEW + 1)
    assert out.shape == (BATCH, NEW)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))
    kinds = {"qwen2.5-3b": "KV cache", "deepseek-v2-236b":
             "MLA latent cache (576/token vs 32768 dense)",
             "rwkv6-3b": "O(1) recurrent state"}
    print(f"{arch:22s} -> generated {out.shape} via {kinds[arch]}")
    print(f"{'':22s}    first row: {np.asarray(out[0]).tolist()}")

print("\nserve_lm OK — one decode API, three cache families")
