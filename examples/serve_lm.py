"""Serving example: a synthetic LM-scoring request stream through
``repro.serve.Server`` — batched offload on cached CommandGraphs across two
e-GPU queues, ending in a :class:`ServeReport` printout.

The pipeline is a per-request token scorer built from the e-GPU kernel zoo
(embedding gather -> GeMM+ReLU -> logits GeMM); requests are token-id
sequences of ragged length, padded to shape buckets and coalesced into
micro-batches.  The example doubles as a living integration test: it
asserts that

* the warm server performs ZERO re-captures (every launch after the first
  per bucket x worker is a GraphCache hit), and
* every batched result is bit-identical to a per-request eager
  ``APU.offload``.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax.numpy as jnp
import numpy as np

from repro import tinycl
from repro.core import APU, EGPU_8T, EGPU_16T, Stage
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import Server

VOCAB, D, HIDDEN = 128, 32, 48
BUCKETS = (16, 32, 64)
MAX_BATCH = 4
N_REQUESTS = 48


# -- Tiny-OpenCL host API v2: the app registers its own kernel families -----
# (weights are NOT baked in — they flow through Stage consts, so one kernel
# object serves any checkpoint).  Registry kernels are memoized per
# (family, config, variant): every worker / rebuild reuses the same objects
# and the serve GraphCache keys on the registry identity.

@tinycl.kernel_family("lm.embed")
def _build_embed(config, *, s=BUCKETS[-1]):
    return tinycl.Kernel(
        "embed", executor=lambda ids, table: table[ids],
        counts=lambda **kw: gemm_counts(m=s, n=D, k=1))


@tinycl.kernel_family("lm.ffn")
def _build_ffn(config, *, s=BUCKETS[-1]):
    return tinycl.Kernel(
        "ffn", executor=lambda x, w: jnp.maximum(gemm_ref(x, w), 0.0),
        counts=lambda **kw: gemm_counts(m=s, n=HIDDEN, k=D))


@tinycl.kernel_family("lm.logits")
def _build_logits(config, *, s=BUCKETS[-1]):
    return tinycl.Kernel(
        "logits", executor=lambda x, w: gemm_ref(x, w),
        counts=lambda **kw: gemm_counts(m=s, n=VOCAB, k=HIDDEN))


def lm_stages(seed: int = 0):
    """Per-request LM scorer: ids (s,) -> logits (s, VOCAB)."""
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.standard_normal((VOCAB, D)) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((D, HIDDEN)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((HIDDEN, VOCAB)) * 0.1, jnp.float32)

    # counts at the largest bucket (upper-bound model); one program per
    # preset — the serve workers build their own for EGPU_8T
    program = tinycl.Program.build(EGPU_16T)
    return [
        Stage(program.create_kernel("lm.embed"), consts=(emb,)),
        Stage(program.create_kernel("lm.ffn"), consts=(w1,)),
        Stage(program.create_kernel("lm.logits"), consts=(w2,)),
    ]


def request_stream(n: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        length = int(rng.integers(4, BUCKETS[-1] + 1))
        yield jnp.asarray(rng.integers(0, VOCAB, (length,)), jnp.int32)


def main():
    stages = lm_stages()
    server = Server(stages, workers=(EGPU_16T, EGPU_8T),
                    bucket_sizes=BUCKETS, max_batch=MAX_BATCH,
                    max_in_flight=2)

    # -- warm-up: pre-capture every (bucket, worker) graph ------------------
    captured = server.warmup(jnp.zeros((1,), jnp.int32))
    assert captured == len(BUCKETS) * 2    # 3 buckets x 2 queues
    warm = [(server.submit(ids), ids) for ids in request_stream(N_REQUESTS)]
    server.flush()

    # -- steady state: warm server => ZERO re-captures ----------------------
    assert server.cache.misses == captured, "warm-up missed a combination"
    steady = [(server.submit(ids), ids)
              for ids in request_stream(N_REQUESTS, seed=2)]
    server.flush()
    assert server.cache.misses == captured, (
        "warm server re-captured a graph: "
        f"{server.cache.misses} != {captured}")

    # -- batched == per-request eager offload, bit for bit ------------------
    apu = APU(EGPU_16T)
    for rid, ids in (warm + steady)[:: N_REQUESTS // 6]:
        (got,) = server.result(rid)
        ref_outs, _ = apu.offload(stages, (ids,), mode="eager")
        assert got.shape == (ids.shape[0], VOCAB)
        assert np.array_equal(np.asarray(got),
                              np.asarray(ref_outs[0].data)), (
            f"request {rid}: batched result diverged from eager offload")

    report = server.report()
    print("=" * 72)
    print(f"serve_lm: {report.n_requests} LM-scoring requests, "
          f"{len(BUCKETS)} shape buckets, 2 e-GPU queues")
    print("=" * 72)
    print(report.summary())
    print("\nserve_lm OK — warm cache re-captured nothing; batched results "
          "bit-identical to eager offload")
    return report


if __name__ == "__main__":
    main()
