"""TinyBio — the paper's Fig-4 application, end to end.

Runs the 4-stage biosignal pipeline (FIR band-pass → peak/trough
delineation → Stockham-FFT spectral features → SVM cognitive-workload
decision) on every e-GPU configuration, printing the per-stage speed-up /
energy table the paper reports, plus the functional outputs.

Run:  PYTHONPATH=src python examples/tinybio_pipeline.py
"""

import numpy as np

from repro.apps.tinybio import TINYBIO_WORKLOAD, run_tinybio
from repro.core import EGPU_4T, EGPU_8T, EGPU_16T

print(f"workload: {TINYBIO_WORKLOAD}")
print()
header = f"{'config':10s} {'stage':15s} {'speed-up':>9s} {'energy x':>9s}"
for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
    decisions, report = run_tinybio(cfg)
    print(header)
    for st in report.stages:
        print(f"{cfg.name:10s} {st.name:15s} {st.speedup:8.2f}x "
              f"{st.energy_reduction:8.2f}x")
    print(f"{cfg.name:10s} {'WHOLE APP':15s} {report.overall_speedup:8.2f}x "
          f"{report.overall_energy_reduction:8.2f}x")
    pos = int((np.asarray(decisions) > 0).sum())
    print(f"  -> {pos}/{decisions.shape[0]} windows classified "
          f"high-workload\n")

print("paper Fig 4: fir 3.6-15.1x | delineation 3.1-13.1x | fft 3.3-14.0x "
      "| app 3.4-14.3x | energy 1.7-3.1x")
