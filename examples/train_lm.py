"""End-to-end driver: train a ~100M LM for a few hundred steps on CPU.

Uses the full production path — ModelConfig zoo, synthetic sharded data
pipeline, AdamW (bf16 moments) + WSD schedule, per-layer remat, async
rotating checkpoints — on a reduced-but-not-tiny qwen2.5 config (~100M
params).  Loss drops from ~log(V) toward the noisy-bigram entropy floor of
the synthetic stream.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import ARCHS
from repro.launch.train import train_loop
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    # ~100M-class: a 12-layer width-768 qwen-family model (~86M params;
    # ~2.5 s/step on one CPU core — a few hundred steps is a coffee break)
    cfg = dataclasses.replace(
        ARCHS["qwen2.5-3b"].reduced(),
        name="qwen2.5-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=2048, dtype="float32")
    from repro.models import model_spec, param_bytes
    print(f"model: {cfg.name} — "
          f"{param_bytes(model_spec(cfg)) // 4 / 1e6:.0f}M params")

    tcfg = TrainConfig(peak_lr=3e-3, total_steps=args.steps, remat="none")
    _, losses = train_loop(cfg, tcfg, steps=args.steps,
                           global_batch=args.batch, seq_len=args.seq,
                           ckpt_dir="artifacts/ckpt_train_lm",
                           ckpt_every=100, log_every=20)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(uniform = {__import__('math').log(cfg.vocab):.2f})")
    assert losses[-1] < losses[0] - 0.5, "training did not learn"
    print("train_lm OK")


if __name__ == "__main__":
    main()
