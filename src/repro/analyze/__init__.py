"""repro.analyze — static analysis for captured graphs and repo invariants.

Two passes, one package (ISSUE 10):

* :mod:`repro.analyze.graph` — a capture-time **graph sanitizer**:
  :func:`verify_graph` statically proves a captured
  :class:`~repro.core.runtime.CommandGraph` free of RAW/WAR/WAW races,
  use-after-donate hazards, buffer-flag violations, dependency cycles and
  dead nodes, with node-naming :class:`Finding` diagnostics.  Reached as
  ``CommandGraph.verify()`` (memoized per graph + donation, so warm serving
  pays a dict lookup), automatically at every
  :class:`~repro.serve.cache.GraphCache` miss, and — loudly, raising
  :class:`GraphVerifyError` — at every capture under ``REPRO_VERIFY=1``.

* :mod:`repro.analyze.lint` — an AST **invariant linter** enforcing the
  ROADMAP's structural rules over ``src/repro`` (no builtin ``hash()``, no
  wall clocks in modeled accounting, tracer guards on hot paths,
  registry-only kernel construction, history-only bench writes).  CLI:
  ``python -m repro.analyze src/repro`` (non-zero exit on findings; wired
  beside pyflakes in CI).

Worked example — the sanitizer catching a seeded race.  An out-of-order
capture normally records a dataflow edge from producer to reader; strip it
(exactly the bug a hand-rolled capture path could introduce) and
``verify()`` names both nodes::

    import dataclasses
    from repro import tinycl

    ctx = tinycl.Context(tinycl.Device())
    q = tinycl.CommandQueue(ctx, out_of_order=True)
    k = tinycl.Kernel("scale", executor=lambda x: (x * 2.0,))
    buf = ctx.create_buffer(jnp.ones((8,)))
    ndr = tinycl.NDRange((8,))

    with q.capture() as graph:
        ev = q.enqueue_nd_range(k, ndr, (buf,))          # producer
        q.enqueue_nd_range(k, ndr, ev.outputs)           # reader (edge 0->1)

    assert graph.verify() == ()                          # capture is clean

    # seed the race: drop the reader's dependency edge
    graph.nodes[1] = dataclasses.replace(graph.nodes[1], deps=())
    graph._verify_memo.clear()
    (f,) = graph.verify()
    assert f.code == "raw-race"      # "#1:scale reads slot 1 with no
                                     #  dependency path from its producer
                                     #  #0:scale ..."

Under ``REPRO_VERIFY=1`` the same finding raises :class:`GraphVerifyError`
straight from the capture's ``__exit__`` / the graph-cache miss, so the
whole test + benchmark suite doubles as a sanitizer sweep — and the
``verified`` / ``findings`` counters surface in ``GraphCache.stats()``,
:class:`~repro.serve.server.ServeReport` and the metrics registry
(``repro_graph_sanitizer_total``).
"""

from .graph import Finding, GraphVerifyError, verify_graph
from .lint import (KERNEL_CTOR_MODULES, MODELED_ACCOUNTING, LintFinding,
                   lint_file, lint_paths, lint_source)

__all__ = [
    "Finding", "GraphVerifyError", "verify_graph",
    "LintFinding", "lint_source", "lint_file", "lint_paths",
    "MODELED_ACCOUNTING", "KERNEL_CTOR_MODULES",
]
