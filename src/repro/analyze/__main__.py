"""CLI: ``python -m repro.analyze [path ...]`` — run the invariant linter.

Defaults to ``src/repro``.  Prints one line per finding
(``path:line: [rule] message``) and exits 1 when any rule fired, so it
slots directly beside pyflakes in CI.
"""

from __future__ import annotations

import sys
from typing import Optional, Sequence

from .lint import lint_paths


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    paths = args or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    if findings:
        print(f"repro.analyze: {len(findings)} finding(s) in "
              f"{', '.join(paths)}", file=sys.stderr)
        return 1
    print(f"repro.analyze: clean ({', '.join(paths)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
