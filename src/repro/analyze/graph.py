"""Capture-time CommandGraph sanitizer (ISSUE 10).

A captured :class:`~repro.core.runtime.CommandGraph` is the runtime's whole
correctness surface: once sealed it replays as one opaque jitted XLA
computation, so a capture-discipline bug (a missing ordering edge on an
out-of-order queue, a transfer writing a read-only buffer, a donated input
read off the ordered path) produces no crash — just silently wrong modeled
accounting or, under donation, wrong *data* on a later launch.  Real OpenCL
stacks meet this with host-side validation layers; this module is ours.

:func:`verify_graph` is a pure static pass over the captured node list.  It
re-derives every hazard the capture machinery is supposed to have ordered
and reports each violation as a :class:`Finding` with a stable ``code`` and
a node-naming message:

=====================  ====================================================
``raw-race``           a node reads a slot with no dependency path from the
                       slot's producer (read-after-write unordered)
``war-race``           a transfer overwrites a logical buffer without being
                       ordered after every reader of the old value
``waw-race``           two producers of one slot, or an overwrite unordered
                       against the previous producer
``use-after-donate``   a reader of a donated external slot whose work never
                       reaches the launch boundary (the graph outputs) — it
                       is unordered against the realize-then-drain point,
                       so a later launch may have reused its buffer
``double-donation``    one external position donated twice, or two donated
                       externals aliasing the same captured array
``flag-violation``     a kernel/read consuming a write-only slot, or a
                       write/copy landing in a read-only buffer
``dependency-cycle``   the dependency edges do not form a DAG
``dead-node``          a costed node whose outputs are never read/returned
                       and whose only ordering dead-ends in a sync sink
                       (modeled work that cannot matter)
=====================  ====================================================

The pass is duck-typed: it needs ``graph.nodes`` (each node carrying
``kernel.name`` / ``in_slots`` / ``out_slots`` / ``deps`` / ``kind`` /
``overwrites``) and optionally ``_ext_slots`` / ``_ext_values`` /
``_slot_flags`` / ``_output_slots()``, so tests can feed hand-built hazard
graphs without touching the runtime.  Entry points:

* ``CommandGraph.verify(donate=())`` — memoized per (graph, donation), so
  warm serving pays a dict lookup at most;
* ``REPRO_VERIFY=1`` — every capture is verified at seal time and every
  :class:`~repro.serve.cache.GraphCache` miss raises
  :class:`GraphVerifyError` on findings, turning a whole test/bench run
  into a sanitizer sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

__all__ = ["Finding", "GraphVerifyError", "verify_graph"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One sanitizer diagnostic: a stable code, the offending node indices,
    and a human message naming them."""

    code: str
    message: str
    nodes: Tuple[int, ...] = ()

    def __str__(self) -> str:
        return f"[{self.code}] {self.message}"


class GraphVerifyError(RuntimeError):
    """Raised (under ``REPRO_VERIFY=1``) when a capture carries findings."""

    def __init__(self, findings: Sequence[Finding]):
        self.findings = tuple(findings)
        lines = "\n".join(f"  {f}" for f in self.findings)
        super().__init__(
            f"graph sanitizer: {len(self.findings)} finding(s)\n{lines}")


def _name(nodes: Sequence[Any], i: int) -> str:
    n = nodes[i]
    kind = getattr(n, "kind", "kernel")
    return f"#{i}:{n.kernel.name}" + (f"({kind})" if kind != "kernel" else "")


def _find_cycle(nodes: Sequence[Any]) -> Tuple[int, ...]:
    """A node sequence forming a dependency cycle, or () when acyclic."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = [WHITE] * len(nodes)
    for root in range(len(nodes)):
        if color[root] != WHITE:
            continue
        stack: List[Tuple[int, int]] = [(root, 0)]
        path: List[int] = []
        while stack:
            i, di = stack.pop()
            if di == 0:
                color[i] = GRAY
                path.append(i)
            deps = nodes[i].deps
            if di < len(deps):
                stack.append((i, di + 1))
                d = deps[di]
                if not 0 <= d < len(nodes):
                    continue            # dangling edge; reported separately
                if color[d] == GRAY:
                    return tuple(path[path.index(d):]) + (d,)
                if color[d] == WHITE:
                    stack.append((d, 0))
            else:
                color[i] = BLACK
                path.pop()
    return ()


def _derived_output_slots(nodes: Sequence[Any]) -> Tuple[int, ...]:
    """Mirror of ``CommandGraph._output_slots`` for duck-typed graphs."""
    reads: List[Any] = []
    for node in reversed(nodes):
        if getattr(node, "kind", "kernel") == "read":
            reads.append(node)
        elif node.out_slots:
            break
    if reads:
        return tuple(s for n in reversed(reads) for s in n.out_slots)
    for node in reversed(nodes):
        if node.out_slots:
            return tuple(node.out_slots)
    return ()


def verify_graph(graph: Any, donate: Sequence[int] = ()) -> Tuple[Finding, ...]:
    """Statically verify one captured graph; returns all findings, () when
    clean.  ``donate`` lists donated external-input positions (capture
    order), enabling the use-after-donate / double-donation checks — the
    same tuple a ``launch(..., donate=...)`` would receive.

    Pure and read-only: no node executes, nothing on the graph mutates, so
    running it at every capture under ``REPRO_VERIFY=1`` cannot perturb
    modeled accounting or functional results.
    """
    nodes = list(graph.nodes)
    n = len(nodes)
    findings: List[Finding] = []
    if not n:
        return ()

    # -- structural maps, re-derived from scratch (never trust the capture's
    #    own indices: they are exactly what is under test) ------------------
    producers: Dict[int, List[int]] = {}
    readers: Dict[int, List[int]] = {}
    for i, node in enumerate(nodes):
        for s in node.in_slots:
            readers.setdefault(s, []).append(i)
        for s in node.out_slots:
            producers.setdefault(s, []).append(i)

    ext_slots = getattr(graph, "_ext_slots", None)
    if ext_slots is None:               # duck-typed graph: externals are the
        ext_slots = sorted(             # slots nobody produces
            s for s in readers if s not in producers)
    slot_flags: Dict[int, str] = getattr(graph, "_slot_flags", None) or {}

    def flags_of(slot: int) -> str:
        return slot_flags.get(slot, "rw")

    out_getter = getattr(graph, "_output_slots", None)
    if callable(out_getter):
        try:
            out_slots = tuple(out_getter())
        except StopIteration:       # sync-only capture: nothing to return
            out_slots = ()
    else:
        out_slots = tuple(_derived_output_slots(nodes))

    # -- dependency cycles (everything else needs a DAG) --------------------
    cycle = _find_cycle(nodes)
    if cycle:
        chain = " -> ".join(_name(nodes, i) for i in cycle)
        findings.append(Finding(
            "dependency-cycle",
            f"dependency edges form a cycle: {chain}",
            tuple(dict.fromkeys(cycle))))

    # -- ancestor sets (bitmasks); only meaningful on a DAG -----------------
    anc: List[int] = [0] * n
    if not cycle:
        # deps may point anywhere in hand-built graphs; process in topo
        # order (Kahn) — acyclicity was just proven.
        indeg = [0] * n
        dependents: Dict[int, List[int]] = {}
        for i, node in enumerate(nodes):
            for d in node.deps:
                if 0 <= d < n:
                    indeg[i] += 1
                    dependents.setdefault(d, []).append(i)
        ready = [i for i in range(n) if indeg[i] == 0]
        while ready:
            i = ready.pop()
            mask = 0
            for d in nodes[i].deps:
                if 0 <= d < n:
                    mask |= anc[d] | (1 << d)
            anc[i] = mask
            for j in dependents.get(i, ()):
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)

        def ordered_before(a: int, b: int) -> bool:
            return bool(anc[b] >> a & 1)

        # -- RAW: every read must be ordered after its slot's producer ------
        for i, node in enumerate(nodes):
            for s in node.in_slots:
                for p in producers.get(s, ()):
                    if p != i and not ordered_before(p, i):
                        findings.append(Finding(
                            "raw-race",
                            f"{_name(nodes, i)} reads slot {s} with no "
                            f"dependency path from its producer "
                            f"{_name(nodes, p)} (read-after-write "
                            "unordered)", (p, i)))

        # -- WAW part 1: one producer per slot (capture SSA discipline) -----
        for s, ps in producers.items():
            if len(ps) > 1:
                names = ", ".join(_name(nodes, p) for p in ps)
                findings.append(Finding(
                    "waw-race",
                    f"slot {s} has {len(ps)} producers ({names}); captured "
                    "slots are written exactly once", tuple(ps)))

        # -- overwrite hazards: a write/copy that REBINDS a logical buffer
        #    must be ordered after the old value's producer (WAW) and after
        #    every reader of the old value (WAR) --------------------------
        for i, node in enumerate(nodes):
            for s_old in getattr(node, "overwrites", ()):
                for p in producers.get(s_old, ()):
                    if not ordered_before(p, i):
                        findings.append(Finding(
                            "waw-race",
                            f"{_name(nodes, i)} overwrites slot {s_old} "
                            f"without ordering after its producer "
                            f"{_name(nodes, p)}", (p, i)))
                for r in readers.get(s_old, ()):
                    if r != i and not ordered_before(r, i):
                        findings.append(Finding(
                            "war-race",
                            f"{_name(nodes, i)} overwrites slot {s_old} "
                            f"still being read by {_name(nodes, r)} "
                            "(write-after-read unordered)", (r, i)))

        # The launch boundary: nodes producing returned slots plus every
        # ancestor reachable through dep edges (sync nodes included).  This
        # strict frontier is what use-after-donate measures against — it is
        # the realize-then-drain point.  Dead-node uses a wider notion:
        # on a concurrent queue the "last node's slots" return rule is an
        # arbitrary tiebreak, so every dependent-free sink still carrying
        # outputs is the legitimate tail of an independent stream and all
        # its ancestors count as live.
        frontier = [i for i, node in enumerate(nodes)
                    if any(s in out_slots for s in node.out_slots)]
        reach_out = 0
        for o in frontier:
            reach_out |= anc[o] | (1 << o)
        reach_live = reach_out
        for i, node in enumerate(nodes):
            if node.out_slots and i not in dependents:
                reach_live |= anc[i] | (1 << i)

        # -- use-after-donate: a donated external's storage may be reused
        #    the moment the launch completes; a reader whose work never
        #    reaches the launch boundary (the output frontier) is unordered
        #    against the engine's realize-then-drain point ----------------
        donate = tuple(int(i) for i in donate)
        if donate:
            seen: Dict[int, int] = {}
            for pos in donate:
                if pos in seen:
                    findings.append(Finding(
                        "double-donation",
                        f"external input {pos} donated more than once", ()))
                seen[pos] = pos
            ext_values = getattr(graph, "_ext_values", None) or []
            for ai in range(len(donate)):
                for bi in range(ai + 1, len(donate)):
                    a, b = donate[ai], donate[bi]
                    if (a != b and a < len(ext_values) and b < len(ext_values)
                            and ext_values[a] is ext_values[b]):
                        findings.append(Finding(
                            "double-donation",
                            f"external inputs {a} and {b} are aliases of "
                            "one captured array; donating both lets XLA "
                            "reuse the same storage twice", ()))
            donated_slots = {ext_slots[p] for p in donate
                             if 0 <= p < len(ext_slots)}
            for i, node in enumerate(nodes):
                if not (reach_out >> i & 1) and any(
                        s in donated_slots for s in node.in_slots):
                    s = next(x for x in node.in_slots if x in donated_slots)
                    findings.append(Finding(
                        "use-after-donate",
                        f"{_name(nodes, i)} reads donated external slot "
                        f"{s} but has no path to the launch outputs; it is "
                        "unordered against the realize-then-drain boundary "
                        "and may observe reused storage", (i,)))

        # -- dead nodes: costed work whose outputs nobody consumes ---------
        # A node is live when some output is read/returned OR when it is an
        # ancestor of the live frontier (returned slots + concurrent sinks):
        # barrier-/marker-ordered side work and independent out-of-order
        # streams are deliberate, so their modeled cost is intentional even
        # though only the final node's slots are returned.  What remains —
        # a node ordered only into a sync sink nobody else consumes, with
        # unread outputs — is genuinely dropped work.
        for i, node in enumerate(nodes):
            if getattr(node, "kind", "kernel") == "sync" or not node.out_slots:
                continue
            live = (bool(reach_live >> i & 1) or any(
                s in out_slots or readers.get(s) for s in node.out_slots))
            if not live:
                findings.append(Finding(
                    "dead-node",
                    f"{_name(nodes, i)} outputs (slots "
                    f"{tuple(node.out_slots)}) are never read nor returned, "
                    "and its only ordering leads into a sync dead end; its "
                    "modeled cost is booked for work that cannot "
                    "matter", (i,)))

    # -- buffer-flag violations (order-independent) -------------------------
    for i, node in enumerate(nodes):
        kind = getattr(node, "kind", "kernel")
        if kind == "sync":
            continue
        for s in node.in_slots:
            if "r" not in flags_of(s):
                what = ("kernel" if kind == "kernel"
                        else f"{kind} transfer")
                findings.append(Finding(
                    "flag-violation",
                    f"{_name(nodes, i)}: {what} reads slot {s} whose "
                    f"buffer is write-only (flags="
                    f"{flags_of(s)!r})", (i,)))
        if kind in ("write", "copy"):
            for s in node.out_slots:
                if "w" not in flags_of(s):
                    findings.append(Finding(
                        "flag-violation",
                        f"{_name(nodes, i)}: {kind} lands in slot {s} "
                        f"whose buffer is read-only (flags="
                        f"{flags_of(s)!r})", (i,)))

    return tuple(findings)
