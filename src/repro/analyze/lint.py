"""Repo invariant linter: AST checks encoding the ROADMAP's own rules.

Every invariant below is already pinned by example-based tests somewhere in
``tests/``; this linter makes them *structural*, so a new module cannot
violate one silently.  ``python -m repro.analyze src/repro`` runs all rules
and exits non-zero on any finding (wired beside pyflakes in CI).

Rules (stable ids, one :class:`LintFinding` per violation):

``no-builtin-hash``
    Python's builtin ``hash()`` is salted per process (PYTHONHASHSEED), so
    hashing names/identities breaks cross-process determinism.  Use
    ``zlib.crc32(name.encode())`` — the repo's CRC-32 rule (FaultPlan lane
    hashing, param-tree rng folding).

``wall-clock``
    ``time.time()`` is banned everywhere (wall timing uses the monotonic
    ``time.perf_counter``), and *any* wall clock — ``time.time`` or
    ``time.perf_counter`` — is banned inside modeled-accounting modules
    (:data:`MODELED_ACCOUNTING`), where time must come from the machine
    model or an injected clock.  Referencing ``time.perf_counter`` without
    calling it (the serve layers' ``clock=time.perf_counter`` injection
    default) is always allowed: the rule flags *calls*.

``tracer-guard``
    Observability is zero-overhead when off: every ``<obj>.tracer.…`` /
    ``<obj>._tracer.…`` access on a hot path must be dominated by an
    ``… is not None`` guard on the same attribute (or live inside a
    ``_trace*`` helper that is only entered under such a guard).

``registry-kernels``
    Kernel objects are constructed only through the
    :func:`~repro.core.program.kernel_family` registry (builders decorated
    with it), plus the closed allowlist :data:`KERNEL_CTOR_MODULES`
    (the runtime's transfer/marker sentinels, the program registry itself,
    and the ``batched_stages`` adapter that re-wraps an existing kernel).

``bench-history``
    ``BENCH_*.json`` trajectories are append-only and written through
    ``benchmarks/history.py`` only; any module that names a ``BENCH_*.json``
    file and also opens/dumps files itself is flagged.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable, List, Optional, Sequence, Tuple, Union

__all__ = ["LintFinding", "lint_source", "lint_file", "lint_paths",
           "MODELED_ACCOUNTING", "KERNEL_CTOR_MODULES"]


#: module path suffixes where ANY wall-clock call is banned: these modules
#: produce or transform *modeled* time/energy, which must never mix with
#: host wall time (the virtual-clock invariant; serve clocks are injected)
MODELED_ACCOUNTING: Tuple[str, ...] = (
    "repro/core/machine.py",
    "repro/core/power.py",
    "repro/serve/faults.py",
    "repro/obs/",
)

#: module path suffixes allowed to call ``Kernel(...)`` directly:
#: the runtime (defines Kernel + the marker/transfer sentinels), the
#: registry itself, and the micro-batching adapter that re-wraps an
#: existing kernel's executor while preserving its registry identity
KERNEL_CTOR_MODULES: Tuple[str, ...] = (
    "repro/core/runtime.py",
    "repro/core/program.py",
    "repro/serve/batching.py",
)

_BENCH_RE = re.compile(r"BENCH_\w+\.json")
_BENCH_WRITER = "benchmarks/history.py"


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _posix(path: Union[str, pathlib.Path]) -> str:
    return pathlib.PurePath(path).as_posix()


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _parents(tree: ast.AST) -> dict:
    par = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


def _has_not_none_guard(test: ast.AST, dotted: str) -> bool:
    """True when ``test`` contains ``<dotted> is not None`` (possibly inside
    an ``and`` chain or parenthesized boolean expression)."""
    for sub in ast.walk(test):
        if (isinstance(sub, ast.Compare) and len(sub.ops) == 1
                and isinstance(sub.ops[0], ast.IsNot)
                and isinstance(sub.comparators[0], ast.Constant)
                and sub.comparators[0].value is None
                and _dotted(sub.left) == dotted):
            return True
    return False


def _in_subtree(node: ast.AST, roots: Sequence[ast.AST], parents: dict) -> bool:
    cur: Optional[ast.AST] = node
    roots_id = {id(r) for r in roots}
    while cur is not None:
        if id(cur) in roots_id:
            return True
        cur = parents.get(cur)
    return False


def _rule_no_builtin_hash(tree, path, src, findings):
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            findings.append(LintFinding(
                path, node.lineno, "no-builtin-hash",
                "builtin hash() is salted per process (PYTHONHASHSEED); "
                "use zlib.crc32(name.encode()) for stable identities"))


def _rule_wall_clock(tree, path, src, findings):
    modeled = any(m in path for m in MODELED_ACCOUNTING)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = _dotted(node.func)
        if fn == "time.time":
            findings.append(LintFinding(
                path, node.lineno, "wall-clock",
                "time.time() is banned: wall timing uses the monotonic "
                "time.perf_counter()"
                + (" (and modeled-accounting modules use no wall clock "
                   "at all)" if modeled else "")))
        elif fn == "time.perf_counter" and modeled:
            findings.append(LintFinding(
                path, node.lineno, "wall-clock",
                "modeled-accounting module calls time.perf_counter(); "
                "modeled time comes from the machine model / an injected "
                "clock, never the host wall clock"))


def _rule_tracer_guard(tree, path, src, findings):
    parents = _parents(tree)
    for node in ast.walk(tree):
        # match `<expr>.tracer.<attr>` / `<expr>._tracer.<attr>`: the value
        # chain must itself be an attribute named tracer (bare locals named
        # `tracer` are non-None by construction and exempt)
        if not (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr in ("tracer", "_tracer")):
            continue
        receiver = _dotted(node.value)
        if receiver is None:
            continue
        guarded = False
        cur: Optional[ast.AST] = node
        while cur is not None and not guarded:
            parent = parents.get(cur)
            if (isinstance(parent, ast.If)
                    and _in_subtree(node, parent.body, parents)
                    and _has_not_none_guard(parent.test, receiver)):
                guarded = True
            elif (isinstance(parent, ast.IfExp)
                    and _in_subtree(node, [parent.body], parents)
                    and _has_not_none_guard(parent.test, receiver)):
                guarded = True
            elif (isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and parent.name.startswith("_trace")):
                # a `_trace*` helper is the guard's hoisted body: its call
                # sites sit under the `is not None` check
                guarded = True
            cur = parent
        if not guarded:
            findings.append(LintFinding(
                path, node.lineno, "tracer-guard",
                f"unguarded {receiver}.{node.attr} on a hot path; dominate "
                f"it with `if {receiver} is not None:` (zero-overhead-"
                "when-off observability)"))


def _rule_registry_kernels(tree, path, src, findings):
    if any(path.endswith(m) or m in path for m in KERNEL_CTOR_MODULES):
        return
    parents = _parents(tree)

    def in_family_builder(node: ast.AST) -> bool:
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in cur.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    name = _dotted(target) or ""
                    if name.split(".")[-1] == "kernel_family":
                        return True
            cur = parents.get(cur)
        return False

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = _dotted(node.func) or ""
        if fn.split(".")[-1] != "Kernel":
            continue
        if not in_family_builder(node):
            findings.append(LintFinding(
                path, node.lineno, "registry-kernels",
                "direct Kernel(...) construction outside a @kernel_family "
                "builder; register the kernel through repro.core.program "
                "so serving identity/caching stay registry-keyed"))


def _rule_bench_history(tree, path, src, findings):
    if path.endswith(_BENCH_WRITER):
        return
    if not _BENCH_RE.search(src):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = _dotted(node.func) or ""
        flagged = False
        if fn == "open" or fn.endswith(".open"):
            mode = None
            if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
                mode = node.args[1].value
            for kw in node.keywords:
                if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                    mode = kw.value.value
            flagged = isinstance(mode, str) and any(
                c in mode for c in ("w", "a", "x"))
        elif fn == "json.dump" or fn.split(".")[-1] in ("write_text",
                                                        "write_bytes"):
            flagged = True
        if flagged:
            findings.append(LintFinding(
                path, node.lineno, "bench-history",
                "module names a BENCH_*.json trajectory but writes files "
                "directly; append through benchmarks/history.append_entry "
                "(append-only bench trajectories)"))


_RULES = (_rule_no_builtin_hash, _rule_wall_clock, _rule_tracer_guard,
          _rule_registry_kernels, _rule_bench_history)


def lint_source(source: str, path: Union[str, pathlib.Path]) -> List[LintFinding]:
    """Run every rule over one module's source.  ``path`` is used both for
    reporting and for path-keyed allowlists (match it repo-relative)."""
    spath = _posix(path)
    try:
        tree = ast.parse(source, filename=spath)
    except SyntaxError as e:
        return [LintFinding(spath, e.lineno or 0, "syntax-error", str(e.msg))]
    findings: List[LintFinding] = []
    for rule in _RULES:
        rule(tree, spath, source, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_file(path: Union[str, pathlib.Path]) -> List[LintFinding]:
    p = pathlib.Path(path)
    return lint_source(p.read_text(), p)


def _iter_py(paths: Iterable[Union[str, pathlib.Path]]):
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f
        else:
            yield p


def lint_paths(paths: Iterable[Union[str, pathlib.Path]]) -> List[LintFinding]:
    """Lint every ``*.py`` under ``paths`` (files or directories)."""
    findings: List[LintFinding] = []
    for f in _iter_py(paths):
        findings.extend(lint_file(f))
    return findings
