"""repro.apps — end-to-end applications built on the TinyCL runtime."""

from .tinybio import TINYBIO_WORKLOAD, run_tinybio, tinybio_stages

__all__ = ["TINYBIO_WORKLOAD", "run_tinybio", "tinybio_stages"]
