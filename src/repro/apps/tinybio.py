"""TinyBio — the paper's 4-stage biosignal pipeline (MBio-Tracker, Fig 4).

    raw signal → FIR band-pass → delineation (peaks/troughs)
               → Stockham-FFT spectral features (+ time features)
               → SVM cognitive-workload decision

Workload (fixed, documented in EXPERIMENTS.md §Paper-validation): a 65536-
sample int16 recording (≈ 34 min of respiration @ 32 Hz), 128-tap FIR,
spectral features over 128 windows of 512 samples, SVM over 256 support
vectors x 32 features.  With this workload the analytic machine model
reproduces the paper's Fig-4 bands within ±15 % on every stage
(tests/test_paper_validation.py pins them).

Every stage runs functionally (Pallas kernels on TPU, interpret/XLA on CPU)
AND is costed by the machine model — the APU report carries both.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (APU, EGPUConfig, EGPU_16T, Kernel, Program, Stage,
                    kernel_family)
from ..kernels.delineate import ops as delineate_ops
from ..kernels.stockham_fft import ops as fft_ops
from ..kernels.stockham_fft.ref import counts as fft_counts

TINYBIO_WORKLOAD = dict(n=65_536, taps=128, win=512, n_windows=128,
                        n_sv=256, n_features=36)   # 32 bands + 4 stats


def synth_signal(n: int, seed: int = 0) -> np.ndarray:
    """Synthetic respiration-like signal: slow oscillation + drift + noise."""
    rng = np.random.default_rng(seed)
    t = np.arange(n) / 32.0
    breath = np.sin(2 * np.pi * 0.25 * t) + 0.3 * np.sin(2 * np.pi * 0.08 * t)
    sig = breath + 0.1 * rng.standard_normal(n)
    return np.asarray(sig, np.float32)


def _feature_kernel(win: int, n_windows: int):
    """Stage 3: windowed power-spectrum features + time-domain stats."""
    def features(x: jax.Array, flags: jax.Array) -> jax.Array:
        w = x[: win * n_windows].reshape(n_windows, win)
        spec = jax.vmap(fft_ops.power_spectrum)(w)          # (NW, win)
        nf = TINYBIO_WORKLOAD["n_features"]
        bands = spec[:, :win // 2].reshape(n_windows, nf - 4, -1).mean(-1)
        mean = w.mean(axis=1, keepdims=True)
        rms = jnp.sqrt((w * w).mean(axis=1, keepdims=True))
        peaks = (flags[: win * n_windows].reshape(n_windows, win) > 0
                 ).sum(axis=1, keepdims=True).astype(jnp.float32)
        troughs = (flags[: win * n_windows].reshape(n_windows, win) < 0
                   ).sum(axis=1, keepdims=True).astype(jnp.float32)
        feats = jnp.concatenate([bands, mean, rms, peaks, troughs], axis=1)
        # normalize for the RBF kernel
        return feats / (jnp.abs(feats).max(axis=0, keepdims=True) + 1e-6)
    return features


# App-level Tiny-OpenCL registration (host API v2): TinyBio's two composite
# stages join the same kernel registry the built-in families live in, so
# they get the registry's memoization — repeated ``tinybio_stages`` calls
# reuse the exact kernel objects, which keeps executor jit caches warm and
# serve GraphCache keys stable — and show how applications extend the
# program without touching repro.kernels.

@kernel_family("tinybio.delineate_keep")
def _build_delineate_keep(config: EGPUConfig = EGPU_16T) -> Kernel:
    """Delineation that also passes the filtered signal through:
    x -> (x, flags)."""
    del_k = Program.build(config).create_kernel("delineate")
    return Kernel("delineate_keep",
                  executor=lambda x: (x, delineate_ops.delineate(x, 0)),
                  counts=del_k.counts)


@kernel_family("tinybio.fft_features")
def _build_fft_features(config: EGPUConfig = EGPU_16T, *, win: int = 512,
                        n_windows: int = 128) -> Kernel:
    """Stage-3 spectral+time features at a fixed windowing."""
    return Kernel(name="fft_features",
                  executor=_feature_kernel(win, n_windows),
                  counts=lambda **kw: fft_counts(n=win).scaled(n_windows))


def tinybio_stages(config: EGPUConfig = EGPU_16T, seed: int = 0):
    """(stages, inputs) for :meth:`repro.core.APU.offload`."""
    wl = TINYBIO_WORKLOAD
    n, taps, win, nw = wl["n"], wl["taps"], wl["win"], wl["n_windows"]
    rng = np.random.default_rng(seed + 1)
    h = np.asarray(np.hamming(taps) * np.sinc(np.linspace(-4, 4, taps)),
                   np.float32)
    h /= np.abs(h).sum()
    sv = np.asarray(rng.standard_normal((wl["n_sv"], wl["n_features"])),
                    np.float32)
    alpha = np.asarray(rng.standard_normal(wl["n_sv"]) / wl["n_sv"],
                       np.float32)

    # Host API v2: kernels come from the Tiny-OpenCL program registry —
    # memoized per (family, config, variant), so repeated stage builds (a
    # serving loop re-wiring the pipeline per offload) reuse the SAME
    # kernel objects, keep their compiled executors warm, and give the
    # serve GraphCache a stable registry identity to key on.
    program = Program.build(config)
    stages = [
        Stage(program.create_kernel("fir"), consts=(jnp.asarray(h),),
              counts_params={"n": n, "taps": taps, "itemsize": 2}),
        # delineate consumes the filtered signal; passes (signal, flags) on
        Stage(program.create_kernel("tinybio.delineate_keep"),
              counts_params={"n": n}),
        Stage(program.create_kernel("tinybio.fft_features", win=win,
                                    n_windows=nw),
              counts_params={}),
        Stage(program.create_kernel("svm"),
              consts=(jnp.asarray(sv), jnp.asarray(alpha),
                      jnp.float32(0.1)),
              params={"gamma": 0.5},
              counts_params={"q": nw, "m": wl["n_sv"],
                             "d": wl["n_features"]}),
    ]
    inputs = (jnp.asarray(synth_signal(n, seed)),)
    return stages, inputs


def run_tinybio(config: EGPUConfig = EGPU_16T, seed: int = 0,
                mode: str = "graph") -> Tuple[jax.Array, "object"]:
    """Run the full pipeline on an APU; returns (decisions, report).

    ``mode="graph"`` (default) captures all four stages into one TinyCL
    :class:`~repro.core.runtime.CommandGraph` and dispatches them as a
    single fused XLA computation (per-stage machine-model accounting is
    taken from the captured schedule); ``mode="eager"`` dispatches each
    stage as its own kernel launch.
    """
    apu = APU(config)
    outs, report = apu.offload(*tinybio_stages(config, seed), mode=mode)
    return outs[0].data, report
