"""repro.checkpoint — sharded, async, elastically restorable checkpoints."""

from .store import (CheckpointManager, load_checkpoint, restore_sharded,
                    save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "restore_sharded"]
