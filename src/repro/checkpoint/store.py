"""Sharded checkpoint store with async save and elastic restore.

Fault-tolerance design (DESIGN.md §5):

* **Layout**: one ``.npz`` per pytree leaf group (flattened path → array)
  plus a JSON manifest holding global shapes, dtypes and the *logical* axes
  of every leaf.  Restoring never needs the writing mesh: shardings are
  re-derived from logical axes under the *restoring* mesh → elastic
  N→M-device restarts are the default path, not a special case.
* **Async save**: ``save_async`` snapshots device arrays to host (cheap,
  blocking only on transfer) and writes in a background thread — the train
  loop keeps stepping during serialization.  ``wait()`` joins before the
  next save (single outstanding snapshot, bounded memory).
* **Atomicity**: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the last good checkpoint (restart-safety).
* **Self-description**: the manifest records step, config name and data
  seed so restore + deterministic data pipeline give exact replay.

On a real multi-host pod each host writes only its addressable shards
(process-local ``.npz``); the single-process layout here is the degenerate
1-host case of the same format.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..distributed.sharding import ShardingRules, param_shardings

#: dtypes np.savez can store natively; anything else goes as raw bytes
#: (ml_dtypes-backed bf16/f8 views are restored from the manifest dtype).
_NPZ_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
               "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(path: str, tree, *, step: int = 0,
                    meta: Optional[Dict[str, Any]] = None) -> None:
    """Synchronous atomic save of a pytree of (host or device) arrays."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    arrays = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        host = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i:05d}"
        dtype = str(host.dtype)
        if dtype not in _NPZ_NATIVE:          # bf16 etc: store raw bytes
            arrays[name] = np.frombuffer(host.tobytes(), np.uint8)
        else:
            arrays[name] = host
        manifest["leaves"][key] = {
            "file": name, "shape": list(host.shape), "dtype": dtype}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def load_checkpoint(path: str, like=None):
    """Load to host arrays.  With ``like`` (a pytree), restores the tree
    structure; otherwise returns (flat dict, manifest)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = data[info["file"]]
        if info["dtype"] not in _NPZ_NATIVE:   # raw-byte leaves (bf16 etc)
            import ml_dtypes
            dt = getattr(ml_dtypes, info["dtype"], info["dtype"])
            arr = np.frombuffer(arr.tobytes(),
                                np.dtype(dt)).reshape(info["shape"])
        flat[key] = arr
    if like is None:
        return flat, manifest
    leaves, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = [flat[jax.tree_util.keystr(p)] for p, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), manifest


def restore_sharded(path: str, like, spec_tree, rules: ShardingRules, mesh):
    """Elastic restore: place host arrays under ``mesh``'s derived shardings.

    ``spec_tree`` carries the logical axes (ParamSpec tree); the writing
    mesh's size/shape is irrelevant — this is the N→M elastic path.
    """
    tree, manifest = load_checkpoint(path, like=like)
    shardings = param_shardings(spec_tree, rules, mesh)
    placed = jax.tree_util.tree_map(
        lambda host, sh: jax.device_put(host, sh), tree, shardings)
    return placed, manifest


class CheckpointManager:
    """Rotating async checkpoint manager for the train loop."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save_async(self, tree, step: int,
                   meta: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host now, write in the background."""
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self._step_dir(step), host_tree, step=step,
                            meta=meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_latest(self, like, spec_tree=None, rules=None, mesh=None):
        step = self.latest_step()
        if step is None:
            return None, None
        path = self._step_dir(step)
        if spec_tree is not None and mesh is not None:
            return restore_sharded(path, like, spec_tree, rules, mesh)
        return load_checkpoint(path, like=like)
