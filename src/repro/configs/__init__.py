"""repro.configs — the 10 assigned architectures x 4 input shapes.

* :data:`ARCHS` — registry: assignment id → ModelConfig (exact pool dims);
* :data:`SHAPES` — the four shape cells (train_4k / prefill_32k /
  decode_32k / long_500k);
* :func:`input_specs` — ShapeDtypeStruct stand-ins for every model input of
  an (arch, shape) cell: weak-type-correct, shardable, no device allocation
  (the dry-run contract);
* :func:`cells` — the live (arch, shape) grid with the skip rules of
  DESIGN.md §4 applied (long_500k only for sub-quadratic archs; encoder-only
  archs have no decode shapes).

The e-GPU paper's own configurations (Table III presets) live in
``repro.core.device``; these are the datacenter-scale configs the paper's
configurability discipline is exercised against.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.frontends import feature_dim
from . import (deepseek_v2_236b, hubert_xlarge, jamba_1_5_large_398b,
               minicpm_2b, mistral_large_123b, moonshot_v1_16b_a3b,
               paligemma_3b, qwen2_5_3b, rwkv6_3b, stablelm_1_6b)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        jamba_1_5_large_398b.CONFIG,
        deepseek_v2_236b.CONFIG,
        moonshot_v1_16b_a3b.CONFIG,
        paligemma_3b.CONFIG,
        rwkv6_3b.CONFIG,
        stablelm_1_6b.CONFIG,
        mistral_large_123b.CONFIG,
        minicpm_2b.CONFIG,
        qwen2_5_3b.CONFIG,
        hubert_xlarge.CONFIG,
    )
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise why it is skipped (DESIGN.md §4)."""
    if cfg.is_encoder and shape.kind in ("decode",):
        return "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: O(S^2) at 512k context"
    return None


def cells(include_skipped: bool = False
          ) -> List[Tuple[str, str, Optional[str]]]:
    """The (arch, shape, skip_reason) grid — 40 nominal cells."""
    out = []
    for a, cfg in ARCHS.items():
        for s, shape in SHAPES.items():
            reason = skip_reason(cfg, shape)
            if reason is None or include_skipped:
                out.append((a, s, reason))
    return out


# ---------------------------------------------------------------------------
# Input specs (the dry-run contract: ShapeDtypeStructs only)
# ---------------------------------------------------------------------------
def input_specs(arch: str, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one cell.

    train:    {"tokens"/"frames", "labels" [, "patches"]}
    prefill:  {"tokens" [, "patches"] / "frames"}
    decode:   {"tokens" (B,), "pos" ()} — the cache spec comes from
              :func:`repro.models.cache_struct` in the launcher.
    """
    cfg = ARCHS[arch]
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if spec.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio":
        out["frames"] = jax.ShapeDtypeStruct((b, s, feature_dim(cfg)), f)
        if spec.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return out

    text_len = s
    if cfg.frontend == "vision":
        text_len = s - cfg.n_prefix_embed
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_embed, feature_dim(cfg)), f)
    out["tokens"] = jax.ShapeDtypeStruct((b, text_len), i32)
    if spec.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, text_len), i32)
    return out


def get(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
