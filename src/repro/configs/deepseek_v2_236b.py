"""deepseek-v2-236b [moe] — MLA attention + 160-expert MoE.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400, MoE 160e top-6,
MLA kv_lora=512, 2 shared + 160 routed [arXiv:2405.04434; hf].
First layer uses a dense 12288-wide MLP (HF config: first_k_dense_replace=1).
MLA: q_lora 1536, qk_nope 128 + qk_rope 64 per head, v_head_dim 128 — the
compressed 576-wide KV cache is what makes decode_32k memory-light.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,        # MLA: per-head K/V expanded from the latent
    head_dim=128,
    d_ff=1536,             # expert intermediate width (assignment value)
    vocab=102400,
    block_pattern=("mla",),
    mlp_pattern=("moe",),
    first_layer_dense=True,
    d_ff_dense=12288,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
)
