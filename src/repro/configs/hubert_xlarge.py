"""hubert-xlarge [audio] — encoder-only transformer backbone.

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (cluster targets)
[arXiv:2106.07447].  The CNN waveform frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed 512-wide frame features;
a learned linear adapter + sinusoidal positions stand in for the conv
positional encoder.  Encoder-only: bidirectional attention, classic
(non-gated) GELU MLP, no decode shapes (decode_32k / long_500k skipped).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    causal=False,
    is_encoder=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    frontend="audio",
)
