"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7, MoE every other.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf].  Period of 8 layers: one attention layer per seven
mamba layers; MoE replaces the dense MLP on every other layer.
Sub-quadratic (9 attention layers only) → runs the long_500k cell.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    mlp_pattern=("dense", "moe", "dense", "moe",
                 "dense", "moe", "dense", "moe"),
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
)
