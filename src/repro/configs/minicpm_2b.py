"""minicpm-2b [dense] — llama-like with MiniCPM's mu-parametrization tricks.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753 [arXiv:2404.06395].
scale_emb=12, depth-scaled residuals (1.4/sqrt(L)), logits divided by
d_model/dim_model_base (256), tied embeddings.  Trains with the WSD
(warmup-stable-decay) schedule — see repro.optim.wsd_schedule.

36 heads is not divisible by the 16-wide model axis: attention TP falls
back to batch sharding for the head axis (the flattened 2304-wide QKV
projections still shard: 2304 = 16 x 144) — see DESIGN.md §5.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab=122753,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    logit_scale_base=256,
)
