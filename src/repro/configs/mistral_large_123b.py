"""mistral-large-123b (Mistral-Large-Instruct-2407) [dense].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
[hf:mistralai/Mistral-Large-Instruct-2407].  The TP-stress arch of the
pool: the deepest, widest dense stack (123B params, 88 layers).
Pure full attention → long_500k skipped (O(S^2) at 512k).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
)
