"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [moe].

48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=163840,
MoE 64e top-6 [hf:moonshotai/Moonlight-16B-A3B].  Assignment dims used
verbatim (the HF release has 27 layers; the assigned pool pins 48).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    block_pattern=("attn",),
    mlp_pattern=("moe",),
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    rope_theta=1e4,
    norm="rmsnorm",
    act="silu",
)
