"""paligemma-3b [vlm] — SigLIP vision frontend (STUB) + Gemma-2B backbone.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216 [arXiv:2407.07726].
Gemma conventions: head_dim 256, GeGLU MLP, embeddings scaled by
sqrt(d_model), tied LM head.  The SigLIP tower is stubbed per the
assignment: ``input_specs()`` provides 256 precomputed patch embeddings
(1152-wide So400m features) which a learned linear adapter maps to d_model.
"""

import math

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    rope_theta=1e4,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    scale_emb=math.sqrt(2048.0),
    frontend="vision",
    n_prefix_embed=256,      # 224x224 / 14x14 SigLIP patches
)
