"""qwen2.5-3b [dense] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-3B].  Tied embeddings, rope theta 1e6.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab=151936,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)
