"""rwkv6-3b (Finch) [ssm] — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
40 heads of 64; per-layer state is O(1) in context (two token-shift vectors
+ a (H, 64, 64) WKV accumulator) → runs the long_500k cell natively.
The rwkv block carries its own channel-mix (mlp_pattern "none").
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    n_layers=32,
    d_model=2560,
    n_heads=1,               # unused (attention-free)
    n_kv_heads=1,
    head_dim=2560,
    d_ff=8960,
    vocab=65536,
    block_pattern=("rwkv",),
    mlp_pattern=("none",),
    rwkv_head_dim=64,
    norm="layernorm",
)
