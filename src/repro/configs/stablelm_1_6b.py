"""stablelm-1.6b (StableLM-2) [dense].

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b].  LayerNorm, partial rotary (25 % of the
head dim), qkv bias, gated-SiLU MLP.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    qkv_bias=True,
    rotary_pct=0.25,
    rope_theta=1e4,
    norm="layernorm",
    act="silu",
)
