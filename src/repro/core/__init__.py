"""repro.core — the e-GPU paper's contribution as a composable JAX module.

Public API:

* configs/knobs:  :class:`EGPUConfig`, presets ``EGPU_4T/8T/16T``, ``HOST``,
  :class:`KernelKnobs` (TPU projection), DVFS :class:`OperatingPoint`\\ s
  (``OP_ANCHOR``, ``OPERATING_POINTS``, ``EGPUConfig.at``)
* execution model: :class:`NDRange`, :func:`schedule`, :func:`optimal_ndrange`
* runtime (Tiny-OpenCL subset): :class:`Context`, :class:`Device`,
  :class:`CommandQueue` (kernels + explicit write/read/copy transfer
  commands), :class:`Kernel`, :class:`Buffer`, :class:`Event`,
  :class:`CommandGraph` (fused capture/replay dispatch)
* host API v2: :class:`Program` / :class:`KernelRegistry` /
  :func:`kernel_family` (see also the ``repro.tinycl`` façade)
* models: :func:`egpu_time`, :func:`host_time` (machine), :func:`characterize`,
  energy helpers (power)
* APU: :class:`APU`, :class:`PipelineReport`
"""

from .apu import APU, PipelineReport, Stage, StageReport
from .device import (EGPU_4T, EGPU_8T, EGPU_16T, HOST, OP_ANCHOR,
                     OPERATING_POINTS, PRESETS, EGPUConfig, KernelKnobs,
                     OperatingPoint, check_vmem_budget, env_op_point)
from .machine import (CAL, PhaseBreakdown, WorkCounts, egpu_time,
                      fuse_breakdowns, host_time, speedup, transfer_time)
from .ndrange import NDRange, crop_from_groups, edge_mask, global_ids, pad_to_groups
from .power import (StaticCharacter, characterize, dynamic_scale,
                    egpu_active_power_mw, egpu_energy_j, egpu_idle_power_mw,
                    energy_reduction, host_active_power_mw, host_energy_j,
                    leakage_scale)
from .program import (BUILTIN_FAMILIES, REGISTRY, KernelRegistry, Program,
                      kernel_family)
from .runtime import (ArgInfo, Buffer, CommandGraph, CommandQueue, Context,
                      Device, Event, GraphBuffer, Kernel)
from .scheduler import Schedule, optimal_ndrange, schedule

__all__ = [
    "APU", "PipelineReport", "Stage", "StageReport",
    "EGPU_4T", "EGPU_8T", "EGPU_16T", "HOST", "OP_ANCHOR", "OPERATING_POINTS",
    "PRESETS", "EGPUConfig", "KernelKnobs", "OperatingPoint",
    "check_vmem_budget", "env_op_point",
    "CAL", "PhaseBreakdown", "WorkCounts", "egpu_time", "fuse_breakdowns",
    "host_time", "speedup", "transfer_time",
    "NDRange", "crop_from_groups", "edge_mask", "global_ids", "pad_to_groups",
    "StaticCharacter", "characterize", "dynamic_scale", "egpu_active_power_mw",
    "egpu_energy_j", "egpu_idle_power_mw", "energy_reduction",
    "host_active_power_mw", "host_energy_j", "leakage_scale",
    "BUILTIN_FAMILIES", "REGISTRY", "KernelRegistry", "Program",
    "kernel_family",
    "ArgInfo", "Buffer", "CommandGraph", "CommandQueue", "Context", "Device",
    "Event", "GraphBuffer", "Kernel",
    "Schedule", "optimal_ndrange", "schedule",
]
