"""APU orchestration (paper §VI): host + e-GPU as one accelerated system.

``APU.offload`` runs a pipeline of kernels on the e-GPU and compares it
against the same pipeline on the scalar host — producing exactly the
speed-up / energy-reduction numbers of the paper's Fig. 4 (TinyBio) while
also returning the functional outputs, so applications get real results and
the evaluation in one call.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from .device import EGPUConfig, EGPU_16T, HOST
from .machine import PhaseBreakdown
from .ndrange import NDRange
from .runtime import Buffer, CommandQueue, Context, Device, Kernel
from .scheduler import optimal_ndrange


@dataclasses.dataclass
class Stage:
    """One pipeline stage: kernel + its argument/extra-buffer wiring."""

    kernel: Kernel
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    counts_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()       # constant arrays appended to inputs
    n_inputs: int = 0                  # 0 = take all previous outputs


@dataclasses.dataclass(frozen=True)
class StageReport:
    """Per-kernel comparison: the paper's Fig 4 bars."""

    name: str
    egpu: PhaseBreakdown
    host: PhaseBreakdown
    egpu_energy_j: float
    host_energy_j: float

    @property
    def speedup(self) -> float:
        return self.host.total_s / self.egpu.total_s

    @property
    def energy_reduction(self) -> float:
        return self.host_energy_j / self.egpu_energy_j


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    stages: Tuple[StageReport, ...]

    @property
    def overall_speedup(self) -> float:
        h = sum(s.host.total_s for s in self.stages)
        e = sum(s.egpu.total_s for s in self.stages)
        return h / e

    @property
    def overall_energy_reduction(self) -> float:
        h = sum(s.host_energy_j for s in self.stages)
        e = sum(s.egpu_energy_j for s in self.stages)
        return h / e


class APU:
    """An accelerated processing unit: X-HEEP host + one e-GPU instance."""

    def __init__(self, config: EGPUConfig = EGPU_16T):
        self.egpu = Device(config)
        self.host = Device(HOST)
        self.egpu_ctx = Context(self.egpu)
        self.host_ctx = Context(self.host)

    def offload(self, stages: Sequence["Stage"],
                inputs: Sequence[jax.Array],
                ndranges: Optional[Sequence[NDRange]] = None,
                ) -> Tuple[Tuple[Buffer, ...], PipelineReport]:
        """Run :class:`Stage`\\ s as a dataflow pipeline.

        Each stage consumes the previous stage's outputs (plus extra
        constant buffers it declares).  Returns the final outputs (computed
        on the e-GPU path) and the host-vs-e-GPU :class:`PipelineReport`.
        """
        reports: List[StageReport] = []
        final: Tuple[Buffer, ...] = ()

        for which, ctx in (("egpu", self.egpu_ctx), ("host", self.host_ctx)):
            q = CommandQueue(ctx)
            bufs = tuple(ctx.create_buffer(x) for x in inputs)
            evs = []
            for i, stage in enumerate(stages):
                ndr = (ndranges[i] if ndranges is not None
                       else optimal_ndrange(bufs[0].data.size, ctx.device.config))
                extra = tuple(ctx.create_buffer(x) for x in stage.consts)
                take = bufs[:stage.n_inputs] if stage.n_inputs else bufs
                # Resident pipeline (paper §IV-B): after the first kernel,
                # intermediate data stays in the unified memory / D$ — only
                # stage 0 pays the host->D$ fill on the e-GPU path.
                resident = (which == "egpu" and i > 0)
                ev = q.enqueue_nd_range(stage.kernel, ndr, take + extra,
                                        params=stage.params,
                                        counts_params=stage.counts_params,
                                        _resident=resident)
                bufs = ev.outputs
                evs.append(ev)
            q.finish()
            if which == "egpu":
                final = bufs
                egpu_evs = evs
            else:
                host_evs = evs

        for e_ev, h_ev, stage in zip(egpu_evs, host_evs, stages):
            reports.append(StageReport(
                name=stage.kernel.name, egpu=e_ev.modeled, host=h_ev.modeled,
                egpu_energy_j=e_ev.energy_j, host_energy_j=h_ev.energy_j))
        return final, PipelineReport(tuple(reports))
