"""APU orchestration (paper §VI): host + e-GPU as one accelerated system.

``APU.offload`` runs a pipeline of kernels on the e-GPU and compares it
against the same pipeline on the scalar host — producing exactly the
speed-up / energy-reduction numbers of the paper's Fig. 4 (TinyBio) while
also returning the functional outputs, so applications get real results and
the evaluation in one call.

Two dispatch modes (ISSUE 1):

* ``mode="graph"`` (default) captures the whole stage chain into a TinyCL
  :class:`~repro.core.runtime.CommandGraph` and launches it as **one** fused
  XLA computation — the TPU analogue of the paper's §IV-B resident pipeline,
  paying dispatch cost once per chain.  Kernels are pure functions of their
  inputs, so the host comparison is costed analytically from the same
  captured :class:`~repro.core.machine.WorkCounts` (the functional results
  are identical by construction) instead of re-executing the chain.
* ``mode="eager"`` re-runs both paths kernel-by-kernel through asynchronous
  queues — the pre-graph behaviour, kept for A/B validation; graph and
  eager produce bit-identical modeled reports and (up to XLA fusion
  reassociation) the same functional outputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from .device import EGPUConfig, EGPU_16T, HOST
from .machine import PhaseBreakdown
from .ndrange import NDRange
from .runtime import Buffer, CommandGraph, CommandQueue, Context, Device, Kernel
from .scheduler import optimal_ndrange


@dataclasses.dataclass
class Stage:
    """One pipeline stage: kernel + its argument/extra-buffer wiring."""

    kernel: Kernel
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    counts_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    consts: Tuple[Any, ...] = ()       # constant arrays appended to inputs
    n_inputs: int = 0                  # 0 = take all previous outputs


@dataclasses.dataclass(frozen=True)
class StageReport:
    """Per-kernel comparison: the paper's Fig 4 bars."""

    name: str
    egpu: Optional[PhaseBreakdown]      # None when the kernel has no counts
    host: Optional[PhaseBreakdown]      # model or the queue is unprofiled
    egpu_energy_j: Optional[float]
    host_energy_j: Optional[float]

    @property
    def speedup(self) -> float:
        return self.host.total_s / self.egpu.total_s

    @property
    def energy_reduction(self) -> float:
        return self.host_energy_j / self.egpu_energy_j


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    stages: Tuple[StageReport, ...]
    #: modeled breakdown of the fused (CommandGraph) launch — startup +
    #: scheduling paid once per chain (None for eager mode)
    egpu_fused: Optional[PhaseBreakdown] = None

    def _modeled_stages(self) -> Tuple[StageReport, ...]:
        return tuple(s for s in self.stages
                     if s.host is not None and s.egpu is not None)

    @property
    def overall_speedup(self) -> Optional[float]:
        """None when no stage carries a machine model (counts-less kernels
        or an unprofiled queue) — the functional outputs still exist."""
        modeled = self._modeled_stages()
        if not modeled:
            return None
        h = sum(s.host.total_s for s in modeled)
        e = sum(s.egpu.total_s for s in modeled)
        return h / e

    @property
    def overall_energy_reduction(self) -> Optional[float]:
        modeled = self._modeled_stages()
        if not modeled:
            return None
        h = sum(s.host_energy_j for s in modeled)
        e = sum(s.egpu_energy_j for s in modeled)
        return h / e

    @property
    def fused_speedup(self) -> Optional[float]:
        """Host total vs the fused chain (per-chain dispatch accounting)."""
        if self.egpu_fused is None or not self._modeled_stages():
            return None
        h = sum(s.host.total_s for s in self._modeled_stages())
        return h / self.egpu_fused.total_s


class APU:
    """An accelerated processing unit: X-HEEP host + one e-GPU instance.

    ``graph_cache`` (a :class:`repro.serve.GraphCache`, or anything with its
    ``get_or_capture(apu, stages, inputs, ndranges)`` contract) memoizes
    compiled :class:`CommandGraph`\\ s across :meth:`offload` calls: a warm
    cache makes repeated same-shape offloads skip both re-capture and re-jit
    (the ISSUE-2 serving substrate).  Without one, every graph-mode offload
    re-captures — the pre-serving behaviour.
    """

    def __init__(self, config: EGPUConfig = EGPU_16T,
                 graph_cache: Optional[Any] = None,
                 explicit_transfers: bool = False,
                 placement: Optional[Any] = None):
        self.egpu = Device(config)
        self.host = Device(HOST)
        self.egpu_ctx = Context(self.egpu)
        self.host_ctx = Context(self.host)
        self.graph_cache = graph_cache
        #: host API v2: captures wrap the pipeline in explicit
        #: enqueue_write_buffer / enqueue_read_buffer transfer nodes and
        #: mark every kernel resident (the serving workers' default) —
        #: see :meth:`capture_pipeline`
        self.explicit_transfers = explicit_transfers
        #: hashable device-placement identity, or None for plain
        #: single-device execution.  A ShardedWorker stamps its mesh +
        #: sharding-rule signature here; GraphCache keys include it, so a
        #: sharded capture and a single-device capture of the same pipeline
        #: can never collide in a shared cache.
        self.placement = placement
        # This APU's own launch queue: graph offloads bind their events and
        # modeled totals here, so a shared GraphCache entry (same config,
        # several APUs/workers) never mixes launch histories across callers.
        self.queue = CommandQueue(self.egpu_ctx)

    @property
    def program(self) -> Any:
        """The Tiny-OpenCL :class:`~repro.core.program.Program` built for
        this APU's e-GPU config (memoized — cheap to read repeatedly)."""
        from .program import Program
        return Program.build(self.egpu.config)

    # -- shared stage wiring -----------------------------------------------
    def wire_pipeline(self, q: CommandQueue, stages: Sequence["Stage"],
                      inputs: Sequence[jax.Array],
                      ndranges: Optional[Sequence[NDRange]] = None,
                      resident_chain: bool = True,
                      resident_first: bool = False
                      ) -> Tuple[Tuple[Buffer, ...], list]:
        """Enqueue the stage chain on ``q`` (works eagerly or under capture).

        ``resident_chain=True`` applies the paper's §IV-B residency: after
        the first kernel, intermediate data stays in the unified memory /
        D$ — only stage 0 pays the host->D$ fill.  ``resident_first=True``
        waives stage 0's fill too — for captures whose input traffic is
        carried by explicit ``enqueue_write_buffer`` nodes instead of the
        per-kernel heuristic.  Returns (final buffers, per-stage events).
        """
        ctx = q.ctx
        bufs = tuple(x if isinstance(x, Buffer) else ctx.create_buffer(x)
                     for x in inputs)
        evs = []
        for i, stage in enumerate(stages):
            ndr = (ndranges[i] if ndranges is not None
                   else optimal_ndrange(bufs[0].data.size, ctx.device.config))
            extra = tuple(ctx.create_buffer(x) for x in stage.consts)
            take = bufs[:stage.n_inputs] if stage.n_inputs else bufs
            self._check_stage_arity(stage, len(take) + len(extra))
            ev = q.enqueue_nd_range(stage.kernel, ndr, take + extra,
                                    params=stage.params,
                                    counts_params=stage.counts_params,
                                    _resident=(resident_first if i == 0
                                               else resident_chain))
            bufs = ev.outputs
            evs.append(ev)
        return bufs, evs

    @staticmethod
    def _check_stage_arity(stage: "Stage", n_bufs: int) -> None:
        """Loud wiring errors via the kernel's clGetKernelArgInfo metadata:
        a stage feeding the wrong number of buffers fails *here*, naming the
        kernel and its declared args, instead of deep inside jax."""
        arity = stage.kernel.n_buffer_args
        if arity is None:
            return
        lo, hi = arity
        if n_bufs < lo or (hi is not None and n_bufs > hi):
            info = stage.kernel.arg_info or ()
            names = [a.name for a in info if a.kind == "buffer"]
            accepted = (f"exactly {lo}" if hi == lo else
                        f"{lo} or more" if hi is None else f"{lo}..{hi}")
            raise ValueError(
                f"stage {stage.kernel.name!r} wires {n_bufs} buffers but "
                f"the kernel declares {names} ({accepted} accepted); check "
                "n_inputs / consts")

    def _host_costs(self, stages: Sequence["Stage"],
                    ndranges: Optional[Sequence[NDRange]],
                    graph: CommandGraph) -> List[Tuple[PhaseBreakdown, float]]:
        """Analytic host-side cost of each stage (no execution needed).

        Per-stage NDRanges are derived from each captured KERNEL node's
        recorded input size — exactly the sizes the eager host path would
        see — so graph and eager host reports can never diverge.  Transfer
        and sync nodes (explicit-transfer captures) are skipped: the host
        baseline owns the unified memory and pays no bus traffic."""
        hq = CommandQueue(self.host_ctx)
        kernel_nodes = [n for n in graph.nodes if n.kind == "kernel"]
        costs = []
        for i, (stage, node) in enumerate(zip(stages, kernel_nodes)):
            ndr = (ndranges[i] if ndranges is not None
                   else optimal_ndrange(node.n_items, self.host.config))
            modeled, energy, _counts = hq._model(
                stage.kernel, ndr, stage.counts_params, resident=False)
            costs.append((modeled, energy))
        return costs

    def offload(self, stages: Sequence["Stage"],
                inputs: Sequence[jax.Array],
                ndranges: Optional[Sequence[NDRange]] = None,
                mode: str = "graph",
                ) -> Tuple[Tuple[Buffer, ...], PipelineReport]:
        """Run :class:`Stage`\\ s as a dataflow pipeline.

        Each stage consumes the previous stage's outputs (plus extra
        constant buffers it declares).  Returns the final outputs (computed
        on the e-GPU path) and the host-vs-e-GPU :class:`PipelineReport`.
        ``mode`` selects fused CommandGraph dispatch (``"graph"``, default)
        or per-kernel eager dispatch (``"eager"``).
        """
        if mode not in ("graph", "eager"):
            raise ValueError(f"unknown offload mode {mode!r}")
        if mode == "graph":
            return self._offload_graph(stages, inputs, ndranges)
        return self._offload_eager(stages, inputs, ndranges)

    # -- fused CommandGraph path -------------------------------------------
    def capture_pipeline(self, stages: Sequence["Stage"],
                         inputs: Sequence[jax.Array],
                         ndranges: Optional[Sequence[NDRange]] = None,
                         explicit_transfers: Optional[bool] = None,
                         ) -> CommandGraph:
        """Capture the stage chain on the e-GPU queue into a reusable
        :class:`~repro.core.runtime.CommandGraph` (launch it repeatedly,
        amortizing both jit compilation and per-kernel dispatch).

        The pipeline inputs are pinned as the graph's *first* external slots
        in order — even ones no stage ends up consuming — so a cached graph
        can be re-launched on fresh request data with
        ``graph.launch_prefix(new_inputs)`` while the per-stage constant
        buffers keep their captured values.  ``graph.n_request_inputs``
        records how many leading externals are pipeline inputs.

        ``explicit_transfers`` (default: the APU's ``explicit_transfers``
        flag) is the host-API-v2 capture shape: every pipeline input flows
        through an explicit ``enqueue_write_buffer`` node, every final
        output through an ``enqueue_read_buffer`` node, and all kernels are
        marked resident — data movement is priced by dedicated transfer
        nodes on the DAG (visible to the critical-path model) instead of
        the per-kernel overlap heuristic.
        """
        if explicit_transfers is None:
            explicit_transfers = self.explicit_transfers
        q = CommandQueue(self.egpu_ctx)
        with q.capture() as graph:
            bufs = tuple(self.egpu_ctx.create_buffer(x) for x in inputs)
            for b in bufs:
                graph._slot_of(b)
            if explicit_transfers:
                written = []
                for b in bufs:
                    dev = Buffer(b.data)        # device-resident destination
                    q.enqueue_write_buffer(dev, b)
                    written.append(dev)
                finals, _ = self.wire_pipeline(q, stages, written, ndranges,
                                               resident_chain=True,
                                               resident_first=True)
                for out in finals:
                    q.enqueue_read_buffer(out)
            else:
                self.wire_pipeline(q, stages, bufs, ndranges,
                                   resident_chain=True)
        graph.n_request_inputs = len(bufs)
        return graph

    def _offload_graph(self, stages, inputs, ndranges):
        if self.graph_cache is not None:
            graph, _hit = self.graph_cache.get_or_capture(
                self, stages, inputs, ndranges)
        else:
            graph = self.capture_pipeline(stages, inputs, ndranges)
        # Launch-time queue binding: events land on THIS APU's queue, not
        # the capture queue a cached graph happens to carry.
        q = self.queue
        final = graph.launch_prefix(inputs, queue=q)
        q.finish()
        # The whole PipelineReport is launch-invariant for a given graph
        # (host costs come from the captured schedule, not the inputs), so
        # a GraphCache hit reuses the frozen report instead of re-walking
        # the host machine model per offload.
        report = getattr(graph, "_pipeline_report", None)
        if report is None:
            host = self._host_costs(stages, ndranges, graph)
            kernel_nodes = [n for n in graph.nodes if n.kind == "kernel"]
            reports = tuple(
                StageReport(name=stage.kernel.name, egpu=node.modeled,
                            host=h_mod, egpu_energy_j=node.energy_j,
                            host_energy_j=h_en)
                for stage, node, (h_mod, h_en)
                in zip(stages, kernel_nodes, host))
            # Kernels without a counts model (or an unprofiled queue) still
            # get their functional outputs — just no fused cost to report.
            fused, _ = graph.fused_modeled()
            report = PipelineReport(reports, egpu_fused=fused)
            graph._pipeline_report = report
        # This APU's launch queue lives as long as the APU: return it to
        # O(1) memory now that the report is assembled (the modeled totals
        # fold into the queue's running counters).
        q.release_events()
        return final, report

    # -- per-kernel eager path ---------------------------------------------
    def _offload_eager(self, stages, inputs, ndranges):
        final: Tuple[Buffer, ...] = ()
        for which, ctx in (("egpu", self.egpu_ctx), ("host", self.host_ctx)):
            q = CommandQueue(ctx)
            bufs, evs = self.wire_pipeline(q, stages, inputs, ndranges,
                                           resident_chain=which == "egpu")
            q.finish()
            if which == "egpu":
                final = bufs
                egpu_evs = evs
            else:
                host_evs = evs

        reports = tuple(
            StageReport(name=stage.kernel.name, egpu=e_ev.modeled,
                        host=h_ev.modeled, egpu_energy_j=e_ev.energy_j,
                        host_energy_j=h_ev.energy_j)
            for e_ev, h_ev, stage in zip(egpu_evs, host_evs, stages))
        return final, PipelineReport(reports)
