"""e-GPU device configuration — the paper's Table II/III knobs.

The e-GPU paper's central contribution is a *configurability discipline*: the
accelerator's parallelism hierarchy (compute units / warps / threads) and its
memory hierarchy (I$ / D$ size, banks, line) are exposed as first-class knobs,
and a minimal NDRange runtime schedules arbitrary kernels onto whatever
configuration was instantiated.

This module holds:

* :class:`EGPUConfig` — the exact hardware knobs of paper Table II, with the
  three presets of Table III (4T / 8T / 16T) plus the X-HEEP host baseline.
* :class:`OperatingPoint` — a DVFS (frequency, voltage) pair.  The paper
  characterizes everything at 300 MHz / 0.8 V TSMC16 (:data:`OP_ANCHOR`);
  X-HEEP-class platforms expose the full knob space, so a config can be
  rebased onto any point with :meth:`EGPUConfig.at` and the power model
  (:mod:`repro.core.power`) scales dynamic power ∝ f·V² and leakage with
  voltage.  ``freq_hz``/``voltage_v`` are ordinary config fields, so every
  memoization key that includes the config (program/kernel registries, the
  serve-path :class:`~repro.serve.cache.GraphCache`) automatically keys on
  the operating point too.
* :class:`KernelKnobs` — the TPU-native projection of those knobs: Pallas
  BlockSpec tile shapes, pipeline (double-buffering) depth and a VMEM
  working-set budget.  ``EGPUConfig.tpu_knobs()`` performs the mapping
  described in DESIGN.md §2 (threads → lane tile, warps → pipeline depth,
  D$ → VMEM budget).

Nothing here touches jax device state; configs are plain frozen dataclasses so
they can parameterize jitted functions as static arguments.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

KIB = 1024
MIB = 1024 * KIB

# TPU v5e-ish magnitudes used when projecting e-GPU knobs onto Pallas tiling.
TPU_VMEM_BYTES = 16 * MIB  # usable VMEM per core (conservative)
TPU_LANES = 128            # VPU/MXU minor dimension
TPU_SUBLANES = 8           # VPU second-minor dimension (float32)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One DVFS point: the (frequency, voltage) pair a config runs at.

    The paper's post-synthesis characterization is pinned at
    300 MHz / 0.8 V (:data:`OP_ANCHOR`); the named table
    :data:`OPERATING_POINTS` adds a low-voltage retention-class point and a
    turbo point in the ranges X-HEEP-class TSMC16 platforms expose.  Points
    are plain frozen dataclasses so they hash into memoization keys.
    """

    name: str
    freq_hz: float
    voltage_v: float

    def validate(self) -> "OperatingPoint":
        if self.freq_hz <= 0.0:
            raise ValueError(f"freq_hz must be positive, got {self.freq_hz}")
        if self.voltage_v <= 0.0:
            raise ValueError(
                f"voltage_v must be positive, got {self.voltage_v}")
        return self


#: the paper's calibration anchor: every fitted power/area constant in
#: :mod:`repro.core.power` describes silicon at this point, and the model is
#: bit-identical to the pre-DVFS one here (scale factors are exactly 1.0).
OP_ANCHOR = OperatingPoint("nominal", 300e6, 0.8).validate()

#: named DVFS points (f scales roughly linearly with V over this range, the
#: usual near-threshold..nominal TSMC16 corridor)
OPERATING_POINTS: Dict[str, OperatingPoint] = {
    p.name: p for p in (
        OperatingPoint("low", 100e6, 0.60).validate(),
        OP_ANCHOR,
        OperatingPoint("turbo", 450e6, 0.95).validate(),
    )
}


def env_op_point(value: Optional[str] = None) -> Optional[OperatingPoint]:
    """Resolve the ``REPRO_OP_POINT`` environment override (CI's non-anchor
    leg re-runs the serving suites under it to pin op-point-independent
    bit-identical outputs).

    ``value`` (or the env var) is a name from :data:`OPERATING_POINTS` or an
    explicit ``"<freq_hz>:<voltage_v>"`` pair, e.g. ``"200e6:0.7"``.
    Returns ``None`` when unset/empty.
    """
    raw = os.environ.get("REPRO_OP_POINT", "") if value is None else value
    raw = raw.strip()
    if not raw:
        return None
    if raw in OPERATING_POINTS:
        return OPERATING_POINTS[raw]
    parts = raw.split(":")
    if len(parts) != 2:
        raise ValueError(
            f"REPRO_OP_POINT={raw!r}: expected a name in "
            f"{sorted(OPERATING_POINTS)} or '<freq_hz>:<voltage_v>'")
    return OperatingPoint(f"env:{raw}", float(parts[0]),
                          float(parts[1])).validate()


@dataclasses.dataclass(frozen=True)
class EGPUConfig:
    """Hardware configuration of one e-GPU instance (paper Table II).

    All sizes in bytes.  The paper's presets (Table III) are exposed below as
    ``EGPU_4T`` / ``EGPU_8T`` / ``EGPU_16T``.
    """

    name: str = "e-gpu"
    compute_units: int = 2
    threads_per_cu: int = 8         # parallel threads (processing elements)
    warps_per_cu: int = 4           # concurrent warps (latency hiding)
    icache_bytes_per_cu: int = 2 * KIB
    icache_banks: int = 1
    icache_line_bytes: int = 16     # 4 instructions
    dcache_bytes: int = 16 * KIB    # shared across CUs
    dcache_banks: int = 8
    dcache_line_bytes: int = 32     # T x 4B  (one word per thread)
    # --- micro-architectural constants used by the machine model ---
    dcache_latency_cycles: int = 4  # paper §VII-A: shared D$ access latency
    host_bus_bytes_per_cycle: int = 4  # 32-bit OBI beats (paper §VIII-B)
    freq_hz: float = 300e6          # paper: 300 MHz @ 0.8 V, TSMC16
    has_fpu: bool = False           # removed for TinyAI (paper §IV-A)
    voltage_v: float = 0.8          # supply voltage of the operating point

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------
    @property
    def total_threads(self) -> int:
        """Max resident work-items = CUs x warps x threads (paper §VIII-B)."""
        return self.compute_units * self.warps_per_cu * self.threads_per_cu

    @property
    def parallel_lanes(self) -> int:
        """Work executed per cycle across the device (one warp per CU issues)."""
        return self.compute_units * self.threads_per_cu

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.freq_hz

    @property
    def operating_point(self) -> OperatingPoint:
        """This config's DVFS point (a named table entry when it matches
        one exactly, else a ``custom`` point)."""
        for p in OPERATING_POINTS.values():
            if p.freq_hz == self.freq_hz and p.voltage_v == self.voltage_v:
                return p
        return OperatingPoint("custom", self.freq_hz, self.voltage_v)

    def at(self, point: OperatingPoint) -> "EGPUConfig":
        """The same silicon rebased onto another DVFS point.

        Only ``freq_hz``/``voltage_v`` change — name and every structural
        knob stay put, so ``config.at(OP_ANCHOR)`` round-trips exactly and
        area (:func:`repro.core.power.characterize`) is invariant.
        """
        point.validate()
        return dataclasses.replace(self, freq_hz=point.freq_hz,
                                   voltage_v=point.voltage_v)

    def validate(self) -> "EGPUConfig":
        if self.compute_units < 1 or self.threads_per_cu < 1 or self.warps_per_cu < 1:
            raise ValueError(f"non-positive parallelism knob in {self}")
        if self.freq_hz <= 0.0 or self.voltage_v <= 0.0:
            raise ValueError(
                f"operating point must be positive: freq_hz={self.freq_hz}, "
                f"voltage_v={self.voltage_v}")
        for field in ("icache_bytes_per_cu", "dcache_bytes"):
            v = getattr(self, field)
            if v <= 0 or v & (v - 1):
                raise ValueError(f"{field}={v} must be a positive power of two")
        if self.dcache_line_bytes % 4:
            raise ValueError("dcache line must be a multiple of 4B (32-bit words)")
        if self.dcache_bytes % (self.dcache_banks * self.dcache_line_bytes):
            raise ValueError("dcache must divide evenly into banks x lines")
        return self

    # ------------------------------------------------------------------
    # TPU projection
    # ------------------------------------------------------------------
    def tpu_knobs(self) -> "KernelKnobs":
        """Project the e-GPU knobs onto TPU Pallas tiling (DESIGN.md §2).

        The *ratios* between configurations are preserved; magnitudes are
        scaled to TPU VMEM / lane widths:

        * threads/CU  → minor (lane) tile, in multiples of 128
        * warps/CU    → pipeline depth (outstanding HBM→VMEM DMAs)
        * D$ size     → VMEM working-set budget (scaled by VMEM/16KiB)
        * D$ line     → second-minor (sublane) tile granularity
        """
        scale = TPU_VMEM_BYTES // self.dcache_bytes if self.dcache_bytes else 1
        lane_tile = TPU_LANES * max(1, self.threads_per_cu // 2)
        sublane_tile = TPU_SUBLANES * max(1, self.dcache_line_bytes // 8)
        return KernelKnobs(
            lane_tile=lane_tile,
            sublane_tile=sublane_tile,
            pipeline_depth=max(2, self.warps_per_cu),
            vmem_budget_bytes=self.dcache_bytes * scale,
            grid_parallelism=self.compute_units,
        )


@dataclasses.dataclass(frozen=True)
class KernelKnobs:
    """TPU-native kernel tuning knobs derived from an :class:`EGPUConfig`.

    These drive Pallas BlockSpec choices in ``repro.kernels.*``:
    block minor dim = ``lane_tile``; block second-minor = ``sublane_tile``;
    the kernel's total VMEM block footprint must stay under
    ``vmem_budget_bytes`` (checked by :func:`check_vmem_budget`).
    """

    lane_tile: int = 128
    sublane_tile: int = 8
    pipeline_depth: int = 2
    vmem_budget_bytes: int = TPU_VMEM_BYTES
    grid_parallelism: int = 1

    def block_for(self, rows: int, cols: int) -> Tuple[int, int]:
        """Largest (rows, cols)-aligned block fitting the knobs."""
        br = min(rows, max(self.sublane_tile, TPU_SUBLANES))
        bc = min(cols, self.lane_tile)
        return (br, bc)


def check_vmem_budget(knobs: KernelKnobs, *block_bytes: int) -> None:
    """Raise if the sum of per-buffer VMEM block footprints (times the
    pipeline depth, since Pallas multi-buffers blocks) exceeds the budget."""
    total = sum(block_bytes) * knobs.pipeline_depth
    if total > knobs.vmem_budget_bytes:
        raise ValueError(
            f"VMEM working set {total/MIB:.2f} MiB exceeds budget "
            f"{knobs.vmem_budget_bytes/MIB:.2f} MiB "
            f"(blocks={[b/KIB for b in block_bytes]} KiB x depth {knobs.pipeline_depth})"
        )


def _preset(name: str, threads: int) -> EGPUConfig:
    """Paper Table III: 2 CUs, 4 warps, 2 KiB I$/CU (1 bank, 16 B line),
    16 KiB shared D$ with T banks and T x 4 B lines."""
    return EGPUConfig(
        name=name,
        compute_units=2,
        threads_per_cu=threads,
        warps_per_cu=4,
        icache_bytes_per_cu=2 * KIB,
        icache_banks=1,
        icache_line_bytes=16,
        dcache_bytes=16 * KIB,
        dcache_banks=2 * threads // 2,   # 2 / 4 / 8 banks for 4T / 8T / 16T
        dcache_line_bytes=4 * threads,   # T x 4 B
    ).validate()


EGPU_4T = _preset("e-gpu-4t", 2)    # 2 threads/CU x 2 CUs = 4 parallel threads
EGPU_8T = _preset("e-gpu-8t", 4)
EGPU_16T = _preset("e-gpu-16t", 8)

#: X-HEEP host baseline: a single-issue scalar RISC-V CPU (paper §VI-B).
HOST = EGPUConfig(
    name="x-heep-host",
    compute_units=1,
    threads_per_cu=1,
    warps_per_cu=1,
    icache_bytes_per_cu=4 * KIB,
    icache_banks=1,
    icache_line_bytes=16,
    dcache_bytes=4 * KIB,
    dcache_banks=1,
    dcache_line_bytes=4,
)

PRESETS = {c.name: c for c in (EGPU_4T, EGPU_8T, EGPU_16T, HOST)}
