"""Analytic machine model of the e-GPU and its X-HEEP host (paper §VII-C).

The paper evaluates post-synthesis netlists we do not have; what we *can*
reproduce faithfully is the structural performance model implied by the
microarchitecture description (§IV) and calibrate its handful of free
constants against the subset of published numbers, then validate against the
rest (EXPERIMENTS.md §Paper-validation).  Structure:

* an e-GPU executes ``ops`` over ``lanes = CUs x threads`` processing
  elements; ``warps`` hide the 4-cycle D$ latency (4 warps -> 1 access/cycle,
  §VII-A), fewer warps stall the pipeline;
* the shared D$ supplies ``banks x 4`` bytes/cycle; kernels are
  ``max(compute, memory)``-bound;
* SIMT divergence serializes masked paths (delineation);
* inter-stage barriers drain the warp pipeline (Stockham FFT);
* host<->D$ traffic moves at 4 B/cycle over the OBI port (§VIII-B), partially
  overlapped with compute via line prefetch (longer lines -> more overlap);
* the Tiny-OpenCL startup+scheduling overhead comes from `core.scheduler`;
* the host is a single-issue scalar RISC-V with DSP extensions (RI5CY) and
  single-cycle SRAM.

All calibration constants live in :data:`CAL` and are documented there.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from .device import EGPUConfig, HOST
from .ndrange import NDRange
from .scheduler import schedule

# ---------------------------------------------------------------------------
# Calibration constants (fitted once against paper Figs 3/4; see
# tests/test_paper_validation.py for the ranges they must reproduce).
# ---------------------------------------------------------------------------
CAL: Dict[str, float] = {
    "HOST_CPI": 1.05,          # RI5CY w/ DSP ext: ~1 op/cycle incl. post-inc loads
    "EGPU_CPI": 1.0,           # per-lane issue rate with full warp occupancy
    "DIV_PENALTY": 0.25,       # serialization cost multiplier for divergent ops
    "BARRIER_BASE": 28.0,      # cycles: barrier entry + warp re-activation
    "CAPACITY_FACTOR": 2.7,    # host-traffic inflation when WS > D$ (fits Fig 3)
    "OVERLAP_PER_LINE_B": 0.009,  # transfer/compute overlap gained per line byte
    "OVERLAP_MAX": 0.45,       # cap on hidden transfer fraction
    "HOST_MEM_BPC": 4.0,       # host SRAM bytes/cycle
}


@dataclasses.dataclass(frozen=True)
class WorkCounts:
    """Structural work of one kernel execution (derived analytically from the
    problem size by each kernel's ``counts()`` in ``repro.kernels.*.ref``)."""

    ops: float                 # scalar ALU/MAC operations (MAC = 1 op)
    dcache_bytes: float        # core <-> D$ traffic (loads + stores)
    host_bytes: float          # compulsory unique bytes moved host <-> D$
    working_set: float         # bytes that must stay resident for full reuse
    barriers: int = 0          # pipeline-wide synchronization points
    divergence: float = 0.0    # fraction of ops under divergent control flow

    def scaled(self, k: float) -> "WorkCounts":
        return dataclasses.replace(
            self, ops=self.ops * k, dcache_bytes=self.dcache_bytes * k,
            host_bytes=self.host_bytes * k, working_set=self.working_set * k)


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    """Cycles per execution phase (the paper's Fig 3 decomposition)."""

    startup: float
    scheduling: float
    transfer: float            # exposed (non-overlapped) host<->D$ transfer
    compute: float             # max(compute, D$-bandwidth) + divergence + barriers
    freq_hz: float

    @property
    def total_cycles(self) -> float:
        return self.startup + self.scheduling + self.transfer + self.compute

    @property
    def total_s(self) -> float:
        return self.total_cycles / self.freq_hz

    def scaled(self, k: float) -> "PhaseBreakdown":
        """Uniformly scale every phase by ``k`` (same frequency).

        The serving layer uses ``scaled(1 / batch)`` for a request's share
        of a batched fused launch: the batch pays startup + scheduling once,
        and each of its ``batch`` requests owns an equal slice of the chain
        (energy-per-request and amortized-latency accounting in
        :class:`repro.serve.ServeReport`).
        """
        return dataclasses.replace(
            self, startup=self.startup * k, scheduling=self.scheduling * k,
            transfer=self.transfer * k, compute=self.compute * k)

    @property
    def transfer_fraction(self) -> float:
        return self.transfer / self.total_cycles

    @property
    def scheduling_fraction(self) -> float:
        return (self.startup + self.scheduling) / self.total_cycles

    def as_dict(self) -> Dict[str, float]:
        return {
            "startup_cycles": self.startup,
            "scheduling_cycles": self.scheduling,
            "transfer_cycles": self.transfer,
            "compute_cycles": self.compute,
            "total_cycles": self.total_cycles,
            "total_s": self.total_s,
        }


def egpu_time(config: EGPUConfig, counts: WorkCounts, ndr: NDRange) -> PhaseBreakdown:
    """Execution-time model for one kernel launch on an e-GPU config."""
    sched = schedule(ndr, config)
    lanes = config.parallel_lanes

    # --- core: compute vs D$ bandwidth, whichever binds -------------------
    warp_stall = max(1.0, config.dcache_latency_cycles / config.warps_per_cu)
    # Divergent regions execute both sides of each branch under a thread mask
    # (§VIII-C): the serialization multiplier is width-independent because the
    # masked path runs on every lane either way.
    div = 1.0 + counts.divergence * CAL["DIV_PENALTY"]
    compute = counts.ops / lanes * CAL["EGPU_CPI"] * warp_stall * div
    compute /= max(sched.occupancy, 1e-9)
    # line-interleaved multi-bank D$: one full line per CU per cycle when
    # threads access sequential words (§VII-A "a single cache line fetch
    # suffices"); line = T x 4B, so bandwidth scales with the thread knob.
    dcache_bpc = config.dcache_line_bytes * config.compute_units
    mem = counts.dcache_bytes / dcache_bpc
    core = max(compute, mem)

    # --- barriers: drain the warp pipeline, re-fill after ------------------
    barrier = counts.barriers * (
        CAL["BARRIER_BASE"]
        + config.warps_per_cu * config.dcache_latency_cycles)

    # --- host <-> D$ transfer ----------------------------------------------
    traffic = counts.host_bytes
    if counts.working_set > config.dcache_bytes:
        traffic *= CAL["CAPACITY_FACTOR"]
    raw_transfer = traffic / config.host_bus_bytes_per_cycle
    overlap = min(CAL["OVERLAP_MAX"],
                  CAL["OVERLAP_PER_LINE_B"] * config.dcache_line_bytes)
    transfer = raw_transfer * (1.0 - overlap)

    return PhaseBreakdown(
        startup=float(sched.startup_cycles),
        scheduling=float(sched.scheduling_cycles),
        transfer=transfer,
        compute=core + barrier,
        freq_hz=config.freq_hz,
    )


def transfer_time(config: EGPUConfig, nbytes: float) -> PhaseBreakdown:
    """Transfer-only breakdown of an *explicit* buffer command (host API v2).

    ``clEnqueueWriteBuffer`` / ``ReadBuffer`` / ``CopyBuffer`` analogues move
    ``nbytes`` over the host<->D$ bus at ``host_bus_bytes_per_cycle`` (the
    32-bit OBI port, paper §VIII-B).  Unlike the per-kernel ``host_bytes``
    heuristic in :func:`egpu_time`, an explicit transfer gets **no** prefetch
    overlap discount — it *is* the traffic, and hiding it behind compute is
    now the scheduler's job: transfer nodes are ordinary DAG nodes, so
    :func:`fuse_breakdowns`' critical-path mode overlaps them with compute
    on independent branches instead of baking a fixed overlap fraction into
    every kernel.  Startup/scheduling are zero: a DMA-style copy never
    enters the Tiny-OpenCL kernel scheduler.
    """
    if nbytes < 0:
        raise ValueError(f"transfer of negative size: {nbytes}")
    return PhaseBreakdown(
        startup=0.0, scheduling=0.0,
        transfer=float(nbytes) / config.host_bus_bytes_per_cycle,
        compute=0.0, freq_hz=config.freq_hz)


def host_time(counts: WorkCounts, config: EGPUConfig = HOST) -> PhaseBreakdown:
    """Execution-time model for the scalar X-HEEP host baseline.

    The host owns the unified memory, so there is no transfer phase; its
    SRAM is single-cycle so memory time folds into CPI except for streaming
    misses beyond its small D$.
    """
    compute = counts.ops * CAL["HOST_CPI"]
    mem = counts.host_bytes / CAL["HOST_MEM_BPC"]
    return PhaseBreakdown(
        startup=0.0, scheduling=0.0, transfer=0.0,
        compute=compute + mem, freq_hz=config.freq_hz)


def speedup(host: PhaseBreakdown, egpu: PhaseBreakdown) -> float:
    return host.total_s / egpu.total_s


def fuse_breakdowns(stages: "Sequence[PhaseBreakdown]",
                    deps: "Optional[Sequence[Sequence[int]]]" = None
                    ) -> PhaseBreakdown:
    """Model a fused (CommandGraph) launch of an already-costed kernel chain.

    The paper's §IV-B resident pipeline pays the Tiny-OpenCL startup +
    scheduling once per *chain*, not once per kernel: after the first launch
    the warps are active and the kernel-args region is hot, so subsequent
    stages chain without re-entering the scheduler.  Transfer and compute
    phases are work, not overhead.  This mirrors the TinyCL
    ``CommandGraph.launch`` path, which dispatches the whole chain as one
    XLA computation.

    Two modes:

    * ``deps=None`` (chain): every stage is serially dependent — transfer
      and compute sum unchanged.
    * ``deps`` given (DAG critical path): ``deps[i]`` lists the indices of
      the stages node ``i`` waits on (an out-of-order queue's
      ``wait_events`` + dataflow edges, as captured by
      :class:`~repro.core.runtime.CommandGraph`).  Fused latency is the
      longest dependency path — concurrent branches overlap instead of
      summing.  A ``None`` entry in ``stages`` (a node with no machine
      model) is a zero-cost pass-through on the path.

    In both modes stages may sit on devices with different clocks — host +
    e-GPU nodes in one capture, or e-GPU stages priced at different DVFS
    :class:`~repro.core.device.OperatingPoint`\\ s (ISSUE 8): every phase is
    normalized per stage by *its own* ``freq_hz`` onto the fastest clock, so
    wall time is preserved exactly.  (Chain mode used to assume one
    config-default frequency and reject mixes — latent breakage once
    op-points landed; pinned by the mixed-op-point regression tests.)
    Startup + scheduling are paid once (the normalized max across stages);
    for a linear chain the two modes agree exactly.
    """
    if deps is None:
        stages = [s for s in stages if s is not None]
        if not stages:
            raise ValueError("fuse_breakdowns needs at least one PhaseBreakdown")
        freq = max(s.freq_hz for s in stages)
        # per-stage normalization onto the fastest clock; for a uniform-
        # frequency chain every scale is exactly 1.0, keeping the historical
        # numbers bit-identical
        return PhaseBreakdown(
            startup=max(s.startup * (freq / s.freq_hz) for s in stages),
            scheduling=max(s.scheduling * (freq / s.freq_hz) for s in stages),
            transfer=sum(s.transfer * (freq / s.freq_hz) for s in stages),
            compute=sum(s.compute * (freq / s.freq_hz) for s in stages),
            freq_hz=freq,
        )

    # --- DAG critical-path mode -------------------------------------------
    stages = list(stages)
    if len(deps) != len(stages):
        raise ValueError(
            f"deps must align with stages: {len(deps)} vs {len(stages)}")
    modeled = [s for s in stages if s is not None]
    if not modeled:
        raise ValueError("fuse_breakdowns needs at least one PhaseBreakdown")
    freq = max(s.freq_hz for s in modeled)
    n = len(stages)
    finish = [0.0] * n                    # seconds: node ready time
    path = [(0.0, 0.0)] * n               # (transfer, compute) ref-freq
                                          # cycles along the best path
    for i, (s, ds) in enumerate(zip(stages, deps)):
        best_s, best_path = 0.0, (0.0, 0.0)
        for d in ds:
            if not 0 <= d < i:
                raise ValueError(
                    f"node {i} depends on node {d}: deps must reference "
                    "earlier nodes (topological capture order)")
            if finish[d] > best_s:
                best_s, best_path = finish[d], path[d]
        if s is None:
            finish[i], path[i] = best_s, best_path
            continue
        scale = freq / s.freq_hz
        t, c = s.transfer * scale, s.compute * scale
        finish[i] = best_s + (t + c) / freq
        path[i] = (best_path[0] + t, best_path[1] + c)
    end = max(range(n), key=lambda i: finish[i])
    return PhaseBreakdown(
        startup=max(s.startup * freq / s.freq_hz for s in modeled),
        scheduling=max(s.scheduling * freq / s.freq_hz for s in modeled),
        transfer=path[end][0],
        compute=path[end][1],
        freq_hz=freq,
    )
