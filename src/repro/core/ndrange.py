"""NDRange — the Tiny-OpenCL execution model (paper §III-B / §V-B).

OpenCL launches a *kernel* over a ``global_size`` of work-items, grouped into
work-groups of ``local_size``.  The paper's Tiny-OpenCL scheduler distributes
work-groups over compute units and performs all boundary checks up-front so
the user kernel never has to.

On TPU the same structure maps onto a Pallas grid:

* one **work-group**  → one grid step (one VMEM-resident block)
* **work-items**      → lanes within the block (vectorized, masked at edges)
* **compute units**   → grid parallelism / mesh shards (see runtime.py)

:func:`to_grid` performs the mapping; :func:`global_ids` reconstructs each
work-item's global ID inside a kernel body (the OpenCL ``get_global_id``),
and :func:`edge_mask` gives the boundary mask the Tiny-OpenCL scheduler
implicitly applies.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NDRange:
    """An OpenCL-style NDRange: 1-D or 2-D global/local sizes.

    ``global_size`` need not divide by ``local_size`` — the scheduler pads to
    whole work-groups and masks the tail, mirroring the paper's up-front
    boundary checks (§V-B: "the user kernel is relieved from handling such
    logic").
    """

    global_size: Tuple[int, ...]
    local_size: Tuple[int, ...]

    def __post_init__(self):
        if len(self.global_size) not in (1, 2):
            raise ValueError("NDRange supports 1-D and 2-D launches")
        if len(self.global_size) != len(self.local_size):
            raise ValueError("global/local rank mismatch")
        if any(g <= 0 for g in self.global_size) or any(l <= 0 for l in self.local_size):
            raise ValueError("sizes must be positive")

    @property
    def rank(self) -> int:
        return len(self.global_size)

    @property
    def num_groups(self) -> Tuple[int, ...]:
        """Work-groups per dimension (ceil division — tail groups are masked)."""
        return tuple(-(-g // l) for g, l in zip(self.global_size, self.local_size))

    @property
    def total_groups(self) -> int:
        return math.prod(self.num_groups)

    @property
    def total_work_items(self) -> int:
        return math.prod(self.global_size)

    @property
    def padded_size(self) -> Tuple[int, ...]:
        return tuple(n * l for n, l in zip(self.num_groups, self.local_size))

    def to_grid(self) -> Tuple[int, ...]:
        """The Pallas grid for this NDRange (one grid step per work-group)."""
        return self.num_groups


def global_ids(ndr: NDRange, dim: int = 0) -> jax.Array:
    """Inside a Pallas kernel body: the global IDs of this work-group's items.

    Returns a ``local_size``-shaped int32 array — 2-D iota throughout (TPU
    requires >= 2-D iota; interpret mode matches).
    """
    import jax.experimental.pallas as pl  # local import: keep module import cheap

    if ndr.rank == 1:
        base = pl.program_id(0) * ndr.local_size[0]
        ids = jax.lax.broadcasted_iota(jnp.int32, (ndr.local_size[0], 1), 0)
        return base + ids[:, 0] if dim == 0 else ids[:, 0] * 0
    base = pl.program_id(dim) * ndr.local_size[dim]
    ids = jax.lax.broadcasted_iota(jnp.int32, ndr.local_size, dim)
    return base + ids


def edge_mask(ndr: NDRange) -> jax.Array:
    """Boundary mask for the current work-group (True = real work-item).

    This is the Tiny-OpenCL scheduler's up-front boundary check, expressed as
    a vector mask (the TPU analogue of SIMT thread masking).
    """
    if ndr.rank == 1:
        return global_ids(ndr, 0) < ndr.global_size[0]
    m0 = global_ids(ndr, 0) < ndr.global_size[0]
    m1 = global_ids(ndr, 1) < ndr.global_size[1]
    return jnp.logical_and(m0, m1)


def pad_to_groups(x: jax.Array, ndr: NDRange, axis: int = 0,
                  fill: float | int = 0) -> jax.Array:
    """Pad ``x`` along ``axis`` so whole work-groups tile it exactly."""
    target = ndr.padded_size[axis if ndr.rank > 1 else 0]
    cur = x.shape[axis]
    if cur == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads, constant_values=fill)


def crop_from_groups(x: jax.Array, ndr: NDRange, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pad_to_groups`."""
    size = ndr.global_size[axis if ndr.rank > 1 else 0]
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, size)
    return x[tuple(sl)]
