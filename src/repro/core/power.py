"""Area / leakage / energy model, calibrated to the paper's TSMC16 data.

The paper characterizes post-synthesis netlists at 300 MHz / 0.8 V in TSMC
16 nm SVT (Figs 2 & 4).  We reproduce that characterization with a
component-level analytic model whose free constants are fitted to the
published endpoints and whose *structure* follows the paper's observations:

* I$ area is constant across configs (not scaled with threads) — §VIII-A;
* D$ area grows slightly with banking (sub-banking is less area-efficient);
* CU area/leakage nearly doubles per 2x thread step (more ALUs, larger
  register files, wider control) — §VIII-A;
* leakage tracks area with SRAM leaking less per mm² than logic;
* dynamic power scales with active lanes; the power controller clock-gates
  finished CUs (SLEEP_REQ, §IV-A/C), so idle CUs contribute leakage only.

Published anchors (paper abstract + §VIII-A):
  host:   0.15 mm²,  29.50 uW leakage,  ~5.5 mW active
  systems (host + e-GPU): 0.24..0.38 mm² (1.6x..2.5x), 130.13..305.32 uW
  (4.4x..10.3x), <= 28 mW total power for the 16T config.

DVFS (ISSUE 8): every fitted constant above describes silicon at the
:data:`~repro.core.device.OP_ANCHOR` point (300 MHz / 0.8 V).  A config
rebased onto another :class:`~repro.core.device.OperatingPoint` via
``config.at(point)`` scales

* **dynamic power** by ``(f / f0) * (V / V0)**2`` — the CV²f law
  (:func:`dynamic_scale`);
* **leakage** by ``(V / V0)**LEAK_VOLTAGE_EXP`` — a power-law fit to the
  super-linear leakage-vs-supply behavior (DIBL + gate leakage) of
  short-channel SVT devices (:func:`leakage_scale`);
* **area** not at all — :func:`characterize` geometry is voltage-invariant,
  only its leakage columns move.

Both scale factors are *exactly* 1.0 at the anchor, so anchor-point numbers
stay bit-identical to the pre-DVFS model (pinned by
``tests/test_paper_validation.py``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

from .device import EGPUConfig, HOST, KIB, OP_ANCHOR
from .machine import PhaseBreakdown

# --- fitted component constants (mm², uW, mW) ------------------------------
HOST_AREA_MM2 = 0.15
HOST_LEAK_UW = 29.50
HOST_ACTIVE_MW = 5.5          # scalar core + SRAM active power at 300 MHz

CU_AREA_BASE_MM2 = 0.0020     # per-CU control/front-end, thread-independent
CU_AREA_PER_THREAD_MM2 = 0.0110  # ALUs + register-file slice per PE
ICACHE_AREA_PER_KIB_MM2 = 0.0030
DCACHE_AREA_PER_KIB_MM2 = 0.0019
DCACHE_BANK_SPLIT_MM2 = 0.0011   # periphery duplicated per extra bank

LOGIC_LEAK_UW_PER_MM2 = 1296.0   # SVT logic leakage density (fitted)
SRAM_LEAK_UW_PER_MM2 = 884.0     # SRAM macros leak less per area (fitted)

EGPU_DYN_MW_PER_LANE = 1.27      # active power per busy processing element
EGPU_DYN_BASE_MW = 5.6           # caches + controller + interconnect + clocks
HOST_IDLE_MW = 0.9               # host waiting on e-GPU interrupt (§VI-A)

#: leakage-vs-supply exponent: leakage ~ (V/V0)**3 captures the combined
#: sub-threshold (DIBL) + gate-leakage super-linearity of 16 nm SVT over the
#: 0.6..0.95 V corridor; exactly 1.0 at the 0.8 V anchor.
LEAK_VOLTAGE_EXP = 3.0


def dynamic_scale(config: EGPUConfig) -> float:
    """CV²f scaling of every dynamic-power constant vs the anchor point.

    ``(f/f0) * (V/V0)**2`` — exactly 1.0 for a config at
    :data:`~repro.core.device.OP_ANCHOR` (the fitted constants' native
    point), monotone increasing in both frequency and voltage.
    """
    return ((config.freq_hz / OP_ANCHOR.freq_hz)
            * (config.voltage_v / OP_ANCHOR.voltage_v) ** 2)


def leakage_scale(config: EGPUConfig) -> float:
    """Leakage scaling vs the anchor supply: ``(V/V0)**LEAK_VOLTAGE_EXP``.

    Frequency-independent (leakage burns whether or not the clock runs),
    monotone increasing in voltage, exactly 1.0 at 0.8 V.
    """
    return (config.voltage_v / OP_ANCHOR.voltage_v) ** LEAK_VOLTAGE_EXP


@dataclasses.dataclass(frozen=True)
class StaticCharacter:
    """Fig 2: per-component area and leakage of one system instance."""

    name: str
    host_area_mm2: float
    icache_area_mm2: float
    dcache_area_mm2: float
    cu_area_mm2: float
    host_leak_uw: float
    icache_leak_uw: float
    dcache_leak_uw: float
    cu_leak_uw: float

    @property
    def total_area_mm2(self) -> float:
        return (self.host_area_mm2 + self.icache_area_mm2 +
                self.dcache_area_mm2 + self.cu_area_mm2)

    @property
    def total_leak_uw(self) -> float:
        return (self.host_leak_uw + self.icache_leak_uw +
                self.dcache_leak_uw + self.cu_leak_uw)

    @property
    def area_overhead(self) -> float:
        return self.total_area_mm2 / self.host_area_mm2

    @property
    def leak_overhead(self) -> float:
        return self.total_leak_uw / self.host_leak_uw

    def as_dict(self) -> Dict[str, float]:
        return {
            "area_mm2": self.total_area_mm2,
            "leak_uw": self.total_leak_uw,
            "area_overhead_x": self.area_overhead,
            "leak_overhead_x": self.leak_overhead,
        }


@functools.lru_cache(maxsize=512)
def characterize(config: EGPUConfig) -> StaticCharacter:
    """Area/leakage of an APU built from the host plus this e-GPU config.

    Geometry is operating-point-invariant; leakage columns scale with the
    config's supply voltage (:func:`leakage_scale` — a factor of exactly
    1.0 at the 0.8 V anchor, so anchor numbers are bit-identical).  Memoized:
    configs are frozen, and the serve path re-derives power per launch.
    """
    ls = leakage_scale(config)
    if config.name == HOST.name:
        return StaticCharacter(config.name, HOST_AREA_MM2, 0, 0, 0,
                               HOST_LEAK_UW * ls, 0, 0, 0)
    icache_kib = config.icache_bytes_per_cu * config.compute_units / KIB
    icache = ICACHE_AREA_PER_KIB_MM2 * icache_kib
    dcache = (DCACHE_AREA_PER_KIB_MM2 * config.dcache_bytes / KIB
              + DCACHE_BANK_SPLIT_MM2 * max(0, config.dcache_banks - 1))
    cus = config.compute_units * (
        CU_AREA_BASE_MM2 + CU_AREA_PER_THREAD_MM2 * config.threads_per_cu)
    return StaticCharacter(
        name=config.name,
        host_area_mm2=HOST_AREA_MM2,
        icache_area_mm2=icache,
        dcache_area_mm2=dcache,
        cu_area_mm2=cus,
        host_leak_uw=HOST_LEAK_UW * ls,
        icache_leak_uw=icache * SRAM_LEAK_UW_PER_MM2 * ls,
        dcache_leak_uw=dcache * SRAM_LEAK_UW_PER_MM2 * ls,
        cu_leak_uw=cus * LOGIC_LEAK_UW_PER_MM2 * ls,
    )


def egpu_active_power_mw(config: EGPUConfig) -> float:
    """Total APU power while the e-GPU runs a kernel (host idles on IRQ).

    Dynamic terms scale with the config's operating point (CV²f,
    :func:`dynamic_scale`); leakage arrives voltage-scaled from
    :func:`characterize`.
    """
    lanes = config.parallel_lanes
    return (dynamic_scale(config)
            * (HOST_IDLE_MW + EGPU_DYN_BASE_MW + EGPU_DYN_MW_PER_LANE * lanes)
            + characterize(config).total_leak_uw / 1000.0)


def egpu_idle_power_mw(config: EGPUConfig) -> float:
    """Power of a *quiescent* APU lane: every CU clock-gated via SLEEP_REQ
    (§IV-A/C) and the host asleep between requests, so only leakage burns.
    The serving layer integrates this over idle lane-time so fleet energy
    accounting is honest (ISSUE 8 satellite)."""
    return characterize(config).total_leak_uw / 1000.0


def host_active_power_mw() -> float:
    return HOST_ACTIVE_MW + HOST_LEAK_UW / 1000.0


def egpu_energy_j(config: EGPUConfig, t: PhaseBreakdown) -> float:
    """Energy of an offloaded kernel.  During startup/scheduling/transfer the
    CUs are mostly idle (clock-gated via SLEEP_REQ's converse — they have not
    started), so those phases burn base+leakage only.  Wall time enters via
    ``t.freq_hz`` (the breakdown's own clock) and power via the config's
    operating point, so the DVFS energy trade is modeled end to end: lower
    V² beats the longer runtime for dynamic energy, while leakage energy
    *grows* as the clock slows."""
    p_active = egpu_active_power_mw(config) * 1e-3
    p_idle = (dynamic_scale(config) * (HOST_IDLE_MW + EGPU_DYN_BASE_MW)
              + characterize(config).total_leak_uw / 1000.0) * 1e-3
    t_active = t.compute / t.freq_hz
    t_idle = (t.startup + t.scheduling + t.transfer) / t.freq_hz
    return p_active * t_active + p_idle * t_idle


def host_energy_j(t: PhaseBreakdown) -> float:
    return host_active_power_mw() * 1e-3 * t.total_s


def energy_reduction(host_t: PhaseBreakdown, config: EGPUConfig,
                     egpu_t: PhaseBreakdown) -> float:
    return host_energy_j(host_t) / egpu_energy_j(config, egpu_t)
