"""Tiny-OpenCL host API v2 — ``Program`` / ``KernelRegistry`` objects.

The paper's Tiny-OpenCL (§IV) is a *real* (if tiny) OpenCL host API: the
host builds a program, creates kernel objects from it, sets their arguments
and enqueues them.  Until this module, our runtime reproduced the execution
side (queues, events, graphs) but the host-facing surface was ad-hoc —
seven per-family factory functions scattered across
``repro.kernels.*.ops`` (removed once the registry below became the only
entry point).  This module is the clProgram/clKernel analogue:

* every kernel family registers a **builder** through the
  :func:`kernel_family` decorator into one :class:`KernelRegistry`
  (``clCreateProgramWithBuiltInKernels`` semantics — the e-GPU ships its
  kernels pre-compiled, there is no runtime source compiler);
* :meth:`Program.build` binds the registry to one
  :class:`~repro.core.device.EGPUConfig` (the clBuildProgram analogue:
  device knobs pick tile sizes, block sizes, jit wrappers);
* :meth:`Program.create_kernel` returns a configured
  :class:`~repro.core.runtime.Kernel` — **memoized** per
  ``(family, config, variant)``, so repeated builds reuse the same kernel
  object (and therefore the same compiled executor, the same jit cache
  entries, and a *stable* serving-cache identity);
* the created kernel carries its registry identity (``kernel.family`` /
  ``kernel.config`` / ``kernel.variant``), which
  :func:`repro.serve.cache.stage_signature` uses as the cache key instead
  of hashing executor bytecode and closures.

Builders are plain functions ``builder(config, **variant) -> Kernel``.
The seven built-in families (gemm, stockham_fft, fir, delineate, svm,
mamba_scan, decode_attention) live in ``repro.kernels.*.ops`` and are
imported lazily on first :meth:`Program.build`; applications may register
their own families (namespaced names like ``"lm.embed"`` recommended) —
see ``examples/serve_lm.py``.

OpenCL mapping::

    clCreateProgramWithBuiltInKernels  ->  Program.build(config)
    clCreateKernel(program, name)      ->  program.create_kernel(name)
    clCreateKernelsInProgram           ->  program.create_kernels()
    clGetKernelArgInfo                 ->  kernel.arg_info
    clSetKernelArg                     ->  kernel.set_arg / kernel.set_args
    clEnqueueNDRangeKernel             ->  queue.enqueue_kernel(kernel, ndr)
"""

from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, Hashable, Optional, Tuple

from .device import EGPUConfig, EGPU_16T
from .runtime import Kernel

#: built-in kernel families -> module whose import registers them.  Imports
#: are lazy (first ``Program.build``) so ``import repro.core`` stays light.
BUILTIN_FAMILIES: Dict[str, str] = {
    "gemm": "repro.kernels.gemm.ops",
    "stockham_fft": "repro.kernels.stockham_fft.ops",
    "fir": "repro.kernels.fir.ops",
    "delineate": "repro.kernels.delineate.ops",
    "svm": "repro.kernels.svm.ops",
    "mamba_scan": "repro.kernels.mamba_scan.ops",
    "decode_attention": "repro.kernels.decode_attention.ops",
}


class KernelRegistry:
    """Name -> builder mapping populated by :func:`kernel_family`.

    One process-wide instance (:data:`REGISTRY`) backs every
    :class:`Program`; tests may instantiate private registries.
    """

    def __init__(self) -> None:
        self._builders: Dict[str, Callable[..., Kernel]] = {}

    def register(self, name: str, builder: Callable[..., Kernel],
                 replace: bool = False) -> None:
        if not name or not isinstance(name, str):
            raise ValueError(f"kernel family name must be a non-empty str, "
                             f"got {name!r}")
        if name in self._builders and not replace:
            existing = self._builders[name]
            if existing is builder:        # idempotent re-import
                return
            raise ValueError(
                f"kernel family {name!r} is already registered "
                f"({existing.__module__}.{existing.__qualname__}); pass "
                "replace=True to override")
        self._builders[name] = builder

    def builder(self, name: str) -> Callable[..., Kernel]:
        try:
            return self._builders[name]
        except KeyError:
            known = ", ".join(sorted(self._builders)) or "<none>"
            raise KeyError(
                f"unknown kernel family {name!r}; registered: {known}"
            ) from None

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._builders))

    def __contains__(self, name: str) -> bool:
        return name in self._builders

    def __len__(self) -> int:
        return len(self._builders)


#: the process-wide registry every ``Program`` builds from by default
REGISTRY = KernelRegistry()


def kernel_family(name: str, registry: Optional[KernelRegistry] = None,
                  replace: bool = False):
    """Decorator registering ``builder(config, **variant) -> Kernel``.

    ::

        @kernel_family("gemm")
        def build_kernel(config=EGPU_16T, *, use_pallas=True) -> Kernel:
            ...

    The builder must be deterministic in ``(config, variant)`` — the
    resulting kernel is memoized on exactly that key and its registry
    identity feeds the serving layer's graph-cache keys.
    """
    def deco(builder: Callable[..., Kernel]) -> Callable[..., Kernel]:
        (registry if registry is not None else REGISTRY).register(
            name, builder, replace=replace)
        return builder
    return deco


def _variant_key(builder: Callable[..., Kernel],
                 variant: Dict[str, Any]) -> Tuple[Tuple[str, Hashable], ...]:
    """Canonical hashable variant key: the builder's keyword defaults merged
    with the caller's overrides, so ``create_kernel("gemm")`` and
    ``create_kernel("gemm", use_pallas=True)`` share one memo entry."""
    import inspect
    merged = dict(variant)
    try:
        params = list(inspect.signature(builder).parameters.values())
    except (TypeError, ValueError):
        params = []
    for p in params[1:]:                       # skip the config positional
        if (p.default is not p.empty and p.name not in merged
                and p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)):
            merged[p.name] = p.default
    try:
        return tuple(sorted((k, v) for k, v in merged.items()))
    except TypeError as e:
        raise TypeError(
            f"kernel variant values must be hashable "
            f"(memoization key): {variant!r}") from e


class Program:
    """A built Tiny-OpenCL program: the registry bound to one device config.

    ``Program.build(config)`` is memoized per ``(config, registry)``;
    :meth:`create_kernel` is memoized per ``(family, config, variant)`` in a
    process-wide table, so two programs built for the same config hand out
    the *same* kernel objects — repeated pipeline constructions (TinyBio per
    offload, serving workers per bucket) reuse compiled executors and keep
    stable cache identities instead of minting fresh closures.
    """

    _programs: Dict[Tuple[int, EGPUConfig], "Program"] = {}
    _kernels: Dict[Tuple[int, str, EGPUConfig,
                         Tuple[Tuple[str, Hashable], ...]], Kernel] = {}

    def __init__(self, config: EGPUConfig = EGPU_16T,
                 registry: Optional[KernelRegistry] = None):
        self.config = config
        self.registry = registry if registry is not None else REGISTRY
        if self.registry is REGISTRY:
            self._ensure_builtins()

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, config: EGPUConfig = EGPU_16T,
              registry: Optional[KernelRegistry] = None) -> "Program":
        """clBuildProgram analogue (memoized — building twice is free).

        The key is the *whole* frozen config, so a program (and below, every
        kernel) builds once per (structural knobs, DVFS operating point):
        ``config.at(point)`` yields a distinct config and therefore a
        distinct memo entry — op-points never alias (ISSUE 8).
        """
        reg = registry if registry is not None else REGISTRY
        key = (id(reg), config)
        prog = cls._programs.get(key)
        if prog is None:
            prog = cls(config, reg)
            cls._programs[key] = prog
        return prog

    def _ensure_builtins(self) -> None:
        for name, module in BUILTIN_FAMILIES.items():
            if name not in self.registry:
                importlib.import_module(module)

    # -- kernel creation ----------------------------------------------------
    @property
    def kernel_names(self) -> Tuple[str, ...]:
        """Every kernel family this program can create (sorted)."""
        return self.registry.names()

    def create_kernel(self, name: str, **variant: Any) -> Kernel:
        """clCreateKernel analogue: a configured, memoized :class:`Kernel`.

        ``variant`` keywords are forwarded to the family's builder
        (e.g. ``use_pallas=False`` for the pure-jnp reference executor);
        distinct variants are distinct kernels.
        """
        builder = self.registry.builder(name)
        vkey = _variant_key(builder, variant)
        key = (id(self.registry), name, self.config, vkey)
        kern = Program._kernels.get(key)
        if kern is None:
            built = builder(self.config, **variant)
            if not isinstance(built, Kernel):
                raise TypeError(
                    f"builder for family {name!r} returned "
                    f"{type(built).__name__}, expected Kernel")
            kern = built.with_identity(family=name, config=self.config,
                                       variant=vkey)
            Program._kernels[key] = kern
        return kern

    def create_kernels(self, **variant: Any) -> Dict[str, Kernel]:
        """clCreateKernelsInProgram analogue: one kernel per family."""
        return {name: self.create_kernel(name, **variant)
                for name in self.kernel_names}

    def __contains__(self, name: str) -> bool:
        return name in self.registry

    def __repr__(self) -> str:
        return (f"Program(config={self.config.name!r}, "
                f"families={len(self.registry)})")
