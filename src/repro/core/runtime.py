"""TinyCL — the host-side Tiny-OpenCL runtime (paper §V / §VI-C), in JAX.

The paper's runtime is a subset of the OpenCL host API that works without an
OS, file system, or multithreading: create buffers, set kernel args, enqueue
an NDRange, wait for the completion interrupt.  We reproduce that API shape
with JAX semantics:

* a :class:`Buffer` wraps a ``jax.Array`` living in the *unified* memory
  (host HBM == device global memory, exactly the paper's §IV-B model);
* a :class:`Kernel` couples an executor (a pure JAX callable — either the
  pure-jnp reference or the Pallas TPU implementation) with a ``counts``
  function that derives the structural :class:`~repro.core.machine.WorkCounts`
  for the analytic machine model;
* ``CommandQueue.enqueue_nd_range`` jit-executes the kernel and returns an
  :class:`Event` carrying both the functional results and the modeled
  :class:`~repro.core.machine.PhaseBreakdown` / energy for the queue's device
  configuration — the numbers behind Figs 3 & 4;
* events chain: kernels consuming a prior event's outputs execute after it
  (JAX dataflow gives this for free, matching in-order OpenCL queues).

Execution model — asynchronous dispatch (paper §VIII-B)
-------------------------------------------------------

``enqueue_nd_range`` is **non-blocking**: it hands the launch to XLA and
returns immediately with an :class:`Event` whose output buffers hold
*unrealized* ``jax.Array``\\ s.  Back-to-back enqueues therefore overlap
host-side dispatch with device compute — true in-order OpenCL queue
semantics.  Synchronization points are explicit:

* ``Event.wait()`` blocks until that launch (and, by in-order dataflow,
  everything it depends on) completed;
* ``CommandQueue.finish()`` drains the whole queue (``clFinish``);
* ``CommandQueue(..., blocking=True)`` restores the old eager-sync behaviour
  (one host↔device round-trip per launch) for A/B benchmarking.

Execution model — CommandGraph fused dispatch (paper §IV-B)
-----------------------------------------------------------

The paper's TinyBio pipeline chains kernels whose intermediates stay
*resident* in the unified memory; the scheduling cost is paid per launch,
not per byte.  The TPU analogue is whole-chain fusion: ``queue.capture()``
records every ``enqueue_nd_range`` issued inside the ``with`` block —
without executing it (output shapes come from ``jax.eval_shape``) — into a
:class:`CommandGraph`.  ``graph.launch(*inputs)`` then replays the entire
chain as **one** jitted XLA computation: intermediates never materialize as
separate dispatches, XLA reuses their buffers, and optional
``donate_argnums`` donation extends that reuse to the graph's external
inputs.  Dispatch cost is paid once per graph instead of once per kernel.
Per-stage machine-model accounting is preserved: each captured node is
costed from its recorded ``WorkCounts`` at capture time (the captured
schedule), not from wall clock.

Execution model — event-dependency DAGs (ISSUE 3)
-------------------------------------------------

Real OpenCL expresses parallelism through ``event_wait_list`` and
out-of-order queues; TinyCL mirrors both:

* ``CommandQueue(..., out_of_order=True)`` drops the implicit launch-order
  chain — dependencies come only from ``enqueue_nd_range(...,
  wait_events=[...])``, dataflow, and ``enqueue_barrier()`` points;
  ``enqueue_marker()`` (clEnqueueMarkerWithWaitList) aggregates events.
* A capture records these edges per node (``GraphNode.deps``), may span
  *multiple* queues (``graph.join(host_queue)`` — host + e-GPU nodes in one
  graph), and ``fused_modeled()`` reports the DAG's **critical path**:
  concurrent branches overlap in the modeled latency instead of summing.
* ``graph.launch(..., queue=...)`` binds the launch's events and modeled
  totals to the *caller's* queue — a cached graph shared across serving
  workers never books one worker's launch on another's history.

Host API v2 — Program objects and explicit data movement (ISSUE 4)
------------------------------------------------------------------

The host-facing surface mirrors real Tiny-OpenCL object semantics (see
``repro.core.program`` and the ``repro.tinycl`` façade):

* kernels come from a **registry** (``Program.build(config)`` /
  ``program.create_kernel(name)``) and carry clSetKernelArg-style argument
  state (:meth:`Kernel.set_args`, :attr:`Kernel.arg_info`,
  :meth:`CommandQueue.enqueue_kernel`);
* data movement is **first-class**: ``enqueue_write_buffer`` /
  ``enqueue_read_buffer`` / ``enqueue_copy_buffer`` return real events
  costed as transfer-only :class:`PhaseBreakdown`\\ s from the machine
  model's bus parameters, obey queue ordering / ``wait_events`` /
  barriers, and capture as transfer :class:`GraphNode`\\ s — the DAG
  critical path can overlap a branch's traffic with another branch's
  compute instead of hiding it inside each kernel's overlap heuristic;
* :class:`Buffer` flags are enforced: kernels cannot read write-only
  buffers, transfers cannot write read-only ones.

Kernels are executed functionally (outputs are fresh buffers); this is the
one semantic departure from OpenCL's in-place buffer writes and is what makes
every kernel jit/grad/vmap-compatible (the explicit transfer commands are
the only in-place buffer updates, and they replace the whole value).
Out-of-order execution therefore can never change functional results —
ordering is a synchronization and machine-model contract.
"""

from __future__ import annotations

import dataclasses
import inspect
import os
import time
import warnings
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .device import EGPUConfig, EGPU_16T, HOST
from .machine import (PhaseBreakdown, WorkCounts, egpu_time, fuse_breakdowns,
                      host_time, transfer_time)
from .ndrange import NDRange
from .power import egpu_energy_j, host_energy_j
from .scheduler import optimal_ndrange


#: valid CL_MEM-style access flags: read-only, write-only, read-write
_BUFFER_FLAGS = ("r", "w", "rw")


class Buffer:
    """A unified-memory buffer with **enforced** CL_MEM-style access flags.

    ``flags`` mirror CL_MEM_READ_ONLY / WRITE_ONLY / READ_WRITE and are a
    real contract since the host API v2 redesign: a kernel launch *reads*
    its argument buffers, so passing a write-only (``"w"``) buffer raises;
    :meth:`CommandQueue.enqueue_write_buffer` / ``enqueue_copy_buffer``
    *write* their destination, so a read-only (``"r"``) destination raises.
    Kernels execute functionally (outputs are fresh buffers), so explicit
    transfer commands are the only in-place writes in TinyCL.
    """

    def __init__(self, data: jax.Array, flags: str = "rw"):
        if flags not in _BUFFER_FLAGS:
            raise ValueError(
                f"invalid buffer flags {flags!r}: expected one of "
                f"{_BUFFER_FLAGS} (CL_MEM_READ_ONLY / WRITE_ONLY / "
                "READ_WRITE)")
        self.data = data if isinstance(data, jax.Array) else jnp.asarray(data)
        self.flags = flags

    @property
    def readable(self) -> bool:
        return "r" in self.flags

    @property
    def writable(self) -> bool:
        return "w" in self.flags

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def read(self) -> jax.Array:
        """clEnqueueReadBuffer — a no-op copy under unified memory."""
        return self.data


class GraphBuffer(Buffer):
    """A symbolic buffer produced while capturing a :class:`CommandGraph`.

    Carries only a ``jax.ShapeDtypeStruct`` (shape/dtype/size all work); the
    concrete value exists only inside the fused computation at launch time.
    ``flags`` inherit from the logical source buffer when the node has one
    (transfer commands) instead of hardcoding ``"rw"``, so access control
    survives capture.
    """

    def __init__(self, aval: jax.ShapeDtypeStruct, slot: int,
                 flags: str = "rw"):
        if flags not in _BUFFER_FLAGS:
            raise ValueError(f"invalid buffer flags {flags!r}")
        self.data = aval          # duck-types shape/dtype/size for wiring code
        self.flags = flags
        self.slot = slot

    def read(self) -> jax.Array:
        raise RuntimeError(
            "GraphBuffer holds no data during capture; launch the graph and "
            "read its outputs instead.")


@dataclasses.dataclass(frozen=True)
class ArgInfo:
    """clGetKernelArgInfo analogue: one executor argument's metadata.

    ``kind`` is ``"buffer"`` for required positional arguments (memory
    objects in OpenCL terms) and ``"param"`` for defaulted / keyword-only
    arguments (the kernel-args scalar region).
    """

    index: int
    name: str
    kind: str                       # "buffer" | "param"
    has_default: bool = False


class _ArgState:
    """Mutable clSetKernelArg storage (excluded from Kernel eq/hash)."""

    __slots__ = ("buffers", "params")

    def __init__(self) -> None:
        self.buffers: Optional[List[Optional["Buffer"]]] = None
        self.params: Dict[str, Any] = {}


#: memoized executor introspection: executor -> (arg_info, (min, max) buffer
#: arity).  Weak keys — the cache never outlives an ad-hoc executor; the
#: registry's memoized kernels keep theirs alive anyway.  Executors that
#: reject weakrefs fall through to per-call inspection.
_ARG_INFO_CACHE: "weakref.WeakKeyDictionary[Any, Tuple]" = (
    weakref.WeakKeyDictionary())


def _introspect_executor(executor: Callable[..., Any]) -> Tuple[
        Optional[Tuple["ArgInfo", ...]], Optional[Tuple[int, Optional[int]]]]:
    try:
        cached = _ARG_INFO_CACHE.get(executor)
    except TypeError:
        cached = None
    if cached is not None:
        return cached
    try:
        sig = inspect.signature(executor)
    except (TypeError, ValueError):
        result = (None, None)
    else:
        info: List[ArgInfo] = []
        lo = hi = 0
        variadic = False
        for i, p in enumerate(sig.parameters.values()):
            if p.kind is p.VAR_POSITIONAL:
                info.append(ArgInfo(i, f"*{p.name}", "buffer"))
                variadic = True
            elif p.kind is p.VAR_KEYWORD:
                continue
            elif p.kind is p.KEYWORD_ONLY or p.default is not p.empty:
                info.append(ArgInfo(i, p.name, "param",
                                    has_default=p.default is not p.empty))
            else:
                info.append(ArgInfo(i, p.name, "buffer"))
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
                hi += 1
                if p.default is p.empty:
                    lo += 1
        result = (tuple(info), (lo, None) if variadic else (lo, hi))
    try:
        _ARG_INFO_CACHE[executor] = result
    except TypeError:
        pass
    return result


@dataclasses.dataclass(frozen=True)
class Kernel:
    """An OpenCL kernel object: executor + structural work counts.

    ``executor(*arrays, **params) -> array | tuple[array]`` must be pure.
    ``counts(**params) -> WorkCounts`` derives the machine-model inputs from
    the problem size (shapes are passed through ``params`` by the caller).
    ``jitted=True`` marks executors that are already ``jax.jit``-wrapped
    (the ``repro.kernels.*.ops`` wrappers): the queue dispatches them
    directly instead of stacking a second jit on top.

    Host API v2 (``repro.core.program``): kernels created through a
    :class:`~repro.core.program.Program` additionally carry their registry
    identity — ``family`` (registry name), ``config`` (the
    :class:`~repro.core.device.EGPUConfig` they were built for) and
    ``variant`` (canonicalized builder keywords).  The serving layer keys
    graph caches on this identity instead of hashing executor closures.

    clSetKernelArg-style argument state: :attr:`arg_info` introspects the
    executor signature, :meth:`set_arg`/:meth:`set_args` stage arguments on
    the kernel object, and :meth:`CommandQueue.enqueue_kernel` launches with
    the staged arguments.  The staged state is *per kernel object* (and
    Program-created kernels are memoized singletons), so concurrent users
    staging different args on one kernel must pass args explicitly through
    ``enqueue_nd_range`` instead.
    """

    name: str
    executor: Callable[..., Any]
    counts: Optional[Callable[..., WorkCounts]] = None
    jitted: bool = False
    #: registry identity (set by Program.create_kernel; None for ad-hoc kernels)
    family: Optional[str] = None
    config: Optional[Any] = None            # EGPUConfig (hashable, frozen)
    variant: Tuple[Any, ...] = ()
    #: mutable clSetKernelArg storage; excluded from eq/hash so kernels stay
    #: usable as jit-cache keys
    args_state: _ArgState = dataclasses.field(
        default_factory=_ArgState, compare=False, repr=False)

    def with_identity(self, family: str, config: Any,
                      variant: Tuple[Any, ...]) -> "Kernel":
        """A copy of this kernel stamped with its registry identity."""
        return dataclasses.replace(self, family=family, config=config,
                                   variant=variant, args_state=_ArgState())

    # -- clGetKernelArgInfo --------------------------------------------------
    @property
    def arg_info(self) -> Optional[Tuple[ArgInfo, ...]]:
        """Executor argument metadata, or ``None`` when the executor's
        signature cannot be introspected (C builtins).  A ``*args``
        executor reports a single trailing variadic buffer entry named
        ``"*<name>"``.  Memoized per executor — APU stage wiring reads it
        on every offload."""
        return _introspect_executor(self.executor)[0]

    @property
    def n_buffer_args(self) -> Optional[Tuple[int, Optional[int]]]:
        """(min, max) buffer-argument arity; max is None for ``*args``
        executors, and the whole thing None when not introspectable.
        Defaulted positionals may be fed either a buffer or a param, so they
        widen max without raising min."""
        return _introspect_executor(self.executor)[1]

    # -- clSetKernelArg ------------------------------------------------------
    def set_args(self, *buffers: Any, **params: Any) -> "Kernel":
        """Stage positional buffer args and keyword params (clSetKernelArg
        for every index at once).  Non-:class:`Buffer` positionals are
        wrapped.  Returns ``self`` for chaining."""
        arity = self.n_buffer_args
        if arity is not None:
            lo, hi = arity
            if len(buffers) < lo or (hi is not None and len(buffers) > hi):
                bound = f"exactly {lo}" if hi == lo else (
                    f">= {lo}" if hi is None else f"{lo}..{hi}")
                raise ValueError(
                    f"kernel {self.name!r} takes {bound} buffer args, "
                    f"got {len(buffers)}")
        self.args_state.buffers = [
            b if isinstance(b, Buffer) else Buffer(b) for b in buffers]
        self.args_state.params = dict(params)
        return self

    def set_arg(self, index: int, value: Any) -> "Kernel":
        """clSetKernelArg: stage one argument by position.

        Buffer-kind indices take a :class:`Buffer` (or array, wrapped);
        param-kind indices stage the value under the parameter's name.
        """
        info = self.arg_info
        if info is None:
            raise TypeError(
                f"kernel {self.name!r} executor is not introspectable; "
                "use set_args(...) or pass args to enqueue_nd_range")
        if not 0 <= index < len(info):
            raise IndexError(
                f"kernel {self.name!r} has {len(info)} args, index {index} "
                "out of range")
        arg = info[index]
        if arg.kind == "param":
            self.args_state.params[arg.name] = value
            return self
        if arg.name.startswith("*"):
            raise ValueError(
                f"kernel {self.name!r} is variadic; stage buffers with "
                "set_args(...)")
        n_buf = sum(1 for a in info if a.kind == "buffer")
        if self.args_state.buffers is None:
            self.args_state.buffers = [None] * n_buf
        slot = sum(1 for a in info[:index] if a.kind == "buffer")
        self.args_state.buffers[slot] = (
            value if isinstance(value, Buffer) else Buffer(value))
        return self

    def staged_args(self) -> Tuple[Tuple["Buffer", ...], Dict[str, Any]]:
        """The staged (buffers, params) — raises if any buffer slot is unset."""
        st = self.args_state
        if st.buffers is None:
            raise RuntimeError(
                f"kernel {self.name!r} has no staged args; call set_args "
                "first (or pass args to enqueue_nd_range)")
        missing = [i for i, b in enumerate(st.buffers) if b is None]
        if missing:
            raise RuntimeError(
                f"kernel {self.name!r} buffer args {missing} are unset")
        return tuple(st.buffers), dict(st.params)


class Event:
    """Kernel-completion event: functional results + modeled time/energy.

    ``dispatch_s`` is the host-side time to *enqueue* the launch (the queue
    is asynchronous, so this excludes device compute); ``wait()`` blocks
    until the results are realized.  ``wall_s`` is kept as an alias of
    ``dispatch_s`` for older call sites.

    Events are reference-counted like ``cl_event`` (clRetainEvent /
    clReleaseEvent): :meth:`release` drops the event's hold on its output
    buffers once the count reaches zero, so a long-lived queue can return
    completed launches to O(in-flight) memory (see
    :meth:`CommandQueue.release_events`).  Modeled cost metadata survives
    release — only the (potentially large) functional outputs are dropped.
    """

    def __init__(self, kernel: Kernel, outputs: Tuple[Buffer, ...],
                 modeled: Optional[PhaseBreakdown], energy_j: Optional[float],
                 dispatch_s: float, deps: Tuple["Event", ...] = ()):
        self.kernel = kernel
        self.outputs = outputs
        self.modeled = modeled
        self.energy_j = energy_j
        self.dispatch_s = dispatch_s
        #: events this one waits on (explicit ``wait_events`` plus the
        #: in-order queue's implicit predecessor); cleared once realized or
        #: released so a long-lived queue never chains its whole history
        self.deps = tuple(deps)
        self._done = False
        self._refcount = 1

    @property
    def wall_s(self) -> float:
        """Alias of ``dispatch_s``.

        Deliberately readable on a *released* event (unlike :meth:`wait`,
        which raises): ``dispatch_s`` is O(1) cost metadata exactly like
        ``modeled`` / ``energy_j``, and the released-event contract keeps
        all three — release drops only the functional outputs.  Pinned by
        ``test_released_event_metadata_survives_profiling_window``.
        """
        return self.dispatch_s

    @property
    def done(self) -> bool:
        return self._done

    @property
    def released(self) -> bool:
        return self._refcount <= 0

    def retain(self) -> "Event":
        """clRetainEvent: keep output buffers alive across a queue release."""
        if self._refcount <= 0:
            raise RuntimeError("cannot retain a released Event")
        self._refcount += 1
        return self

    def release(self) -> None:
        """clReleaseEvent: drop one reference; at zero, free the outputs.

        Idempotent once released.  The modeled breakdown / energy stay
        readable (they are O(1)); only the buffer references are dropped.
        """
        if self._refcount <= 0:
            return
        self._refcount -= 1
        if self._refcount == 0:
            self.outputs = ()
            self.deps = ()

    def wait(self) -> Tuple[Buffer, ...]:
        """Block until this event (and its dependencies) completed.

        Waiting a *released* event is loud (``RuntimeError``), matching
        :meth:`retain` — the outputs are gone, so a silent empty return
        would hide a use-after-release bug.
        """
        if self.released:
            raise RuntimeError("cannot wait a released Event")
        # Iterative traversal: a long in-order chain of implicit deps must
        # not overflow the stack; already-realized or released deps prune.
        stack, seen, pending = [self], set(), []
        while stack:
            ev = stack.pop()
            if id(ev) in seen or ev._done or ev.released:
                continue
            seen.add(id(ev))
            pending.append(ev)
            stack.extend(ev.deps)
        for ev in pending:                 # realization order is immaterial:
            for b in ev.outputs:           # each blocks until its own work
                if isinstance(b.data, jax.Array):
                    b.data.block_until_ready()
            ev._done = True
            ev.deps = ()                   # realized: drop the chain refs
        return self.outputs


def _static_signature(params: Dict[str, Any]) -> Tuple[str, ...]:
    """Param names that must be jit-static (everything that isn't an array)."""
    return tuple(sorted(
        k for k, v in params.items()
        if not isinstance(v, (jax.Array, jnp.ndarray))))


#: sentinel kernel for marker/barrier events (clEnqueueMarkerWithWaitList /
#: clEnqueueBarrierWithWaitList) — never executed, carries no cost model
_MARKER = Kernel(name="marker", executor=lambda: ())

#: sentinel kernels identifying explicit data-movement commands; their
#: modeled cost is a transfer-only PhaseBreakdown attached per event/node
_WRITE = Kernel(name="write_buffer", executor=lambda x: (x,))
_READ = Kernel(name="read_buffer", executor=lambda x: (x,))
_COPY = Kernel(name="copy_buffer", executor=lambda x: (x,))
_TRANSFER_KINDS = {"write_buffer": "write", "read_buffer": "read",
                   "copy_buffer": "copy"}


class CommandQueue:
    """A command queue bound to one device.

    ``blocking=False`` (default) gives asynchronous OpenCL semantics: enqueue
    returns immediately and only ``Event.wait()`` / :meth:`finish`
    synchronize.  ``blocking=True`` restores eager-sync dispatch (one device
    round-trip per kernel) for overhead A/B comparisons.

    Ordering (``out_of_order``): an in-order queue (default) implicitly
    chains every launch after the previous one, exactly OpenCL's default
    queue semantics.  ``out_of_order=True`` is the
    ``CL_QUEUE_OUT_OF_ORDER_EXEC_MODE_ENABLE`` analogue: launches carry NO
    implicit ordering — dependencies come only from explicit
    ``wait_events=`` lists, dataflow (consuming a prior event's output
    buffers), and :meth:`enqueue_barrier` points.  Two launches with neither
    are *unordered* (concurrent in the machine model's critical path).
    Kernels are pure functions, so out-of-order execution can never change
    functional results — ordering is a synchronization and *modeling*
    contract, which :class:`CommandGraph` captures as a dependency DAG.

    Event lifecycle (serving workloads): an unprofiled queue auto-releases
    its events on :meth:`finish` — nobody can need them for accounting, so
    the queue stays O(in-flight) memory on a long-lived server.  A profiled
    queue keeps every event by default (full Fig-3/4 history); pass
    ``max_events=N`` for a *bounded profiling window*: only the newest N
    drained events are retained, older ones are released with their modeled
    time/energy folded into the queue's running totals, so
    :meth:`total_modeled_s` / :meth:`total_energy_j` stay exact regardless
    of the window.
    """

    def __init__(self, ctx: Context, profile: bool = True,
                 blocking: bool = False, max_events: Optional[int] = None,
                 out_of_order: bool = False, tracer: Optional[Any] = None,
                 trace_track: Optional[str] = None):
        if max_events is not None and max_events < 0:
            raise ValueError("max_events must be None or >= 0")
        self.ctx = ctx
        self.profile = profile
        self.blocking = blocking
        self.max_events = max_events
        self.out_of_order = out_of_order
        # Opt-in span tracing (ISSUE 7, repro.obs): every booked event
        # becomes one span on this queue's track, laid out end-to-end on
        # the queue's cumulative *modeled* timeline.  Strictly
        # observational — guarded at each booking site, so an untraced
        # queue (the default) allocates nothing from repro.obs.
        self._tracer = tracer
        self._trace_track = trace_track or f"queue:{ctx.device.config.name}"
        self._trace_t = 0.0
        self._barrier: Optional[Event] = None   # latest eager barrier event
        self._events: List[Event] = []
        self._drained = 0              # finish() watermark: events before
                                       # this index are already waited
        # Running totals of *released* events, so dropping an event from the
        # retained window never changes the queue's modeled accounting.
        self._released_count = 0
        self._released_modeled_s = 0.0
        self._released_energy_j = 0.0
        # Keyed on (kernel, static-arg signature): the same kernel enqueued
        # with a different static/traced split gets its own jit wrapper
        # instead of silently reusing the first call's (see ISSUE 1).
        self._jit_cache: Dict[Tuple[Kernel, Tuple[str, ...]], Callable] = {}
        self._capture: Optional[CommandGraph] = None

    # -- jit plumbing ------------------------------------------------------
    def _executor_for(self, kernel: Kernel, params: Dict[str, Any]) -> Callable:
        if kernel.jitted:
            return kernel.executor
        statics = _static_signature(params)
        key = (kernel, statics)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = jax.jit(kernel.executor, static_argnames=statics)
            self._jit_cache[key] = fn
        return fn

    def _model(self, kernel: Kernel, ndr: NDRange,
               counts_params: Dict[str, Any], resident: bool
               ) -> Tuple[Optional[PhaseBreakdown], Optional[float],
                          Optional[WorkCounts]]:
        """Machine-model (breakdown, energy, counts) of one enqueued command.

        The :class:`WorkCounts` actually priced (resident adjustment
        applied) ride along so a capture can pin them on its
        :class:`GraphNode` — downstream consumers (the serve engine's
        bytes-per-step roofline) read traffic straight off the captured
        schedule instead of re-deriving it.

        Operating-point audit (ISSUE 8): the config comes off the queue's
        device, so the breakdown is stamped with *that config's* clock
        (``PhaseBreakdown.freq_hz``) and energy prices at its (f, V) point —
        a graph captured at one DVFS point books honest numbers at any
        other, and downstream consumers (fusion, spikes, sharding, serve
        decomposition) all re-derive from the breakdown's own ``freq_hz``,
        never from a config default.
        """
        if not self.profile or kernel.counts is None:
            return None, None, None
        counts = kernel.counts(**counts_params)
        if resident:
            counts = dataclasses.replace(counts, host_bytes=0.0)
        cfg = self.ctx.device.config
        if self.ctx.device.is_host:
            modeled = host_time(counts, cfg)
            return modeled, host_energy_j(modeled), counts
        modeled = egpu_time(cfg, counts, ndr)
        return modeled, egpu_energy_j(cfg, modeled), counts

    def _trace_event(self, ev: "Event") -> None:
        """Record one booked event as a span on this queue's modeled
        timeline (only reached when a tracer is installed)."""
        dur = ev.modeled.total_s if ev.modeled is not None else 0.0
        self._tracer.span(ev.kernel.name, self._trace_t,
                          self._trace_t + dur, track=self._trace_track,
                          dispatch_s=ev.dispatch_s)
        self._trace_t += dur

    def _model_transfer(self, nbytes: float
                        ) -> Tuple[Optional[PhaseBreakdown], Optional[float]]:
        """Transfer-only cost of an explicit buffer command on this device."""
        if not self.profile:
            return None, None
        cfg = self.ctx.device.config
        modeled = transfer_time(cfg, nbytes)
        if self.ctx.device.is_host:
            return modeled, host_energy_j(modeled)
        return modeled, egpu_energy_j(cfg, modeled)

    def _check_wait_events(self, wait_events: Optional[Sequence[Event]]
                           ) -> Tuple[Event, ...]:
        evs = tuple(wait_events or ())
        for ev in evs:
            if not isinstance(ev, Event):
                raise TypeError(
                    f"wait_events must contain Events, got "
                    f"{type(ev).__name__}")
            if ev.released:
                raise RuntimeError(
                    "wait_events contains a released Event (use-after-"
                    "release)")
            if (self._capture is None
                    and getattr(ev, "_graph", None) is not None):
                raise RuntimeError(
                    "wait_events contains a capture-time Event; an eager "
                    "command can only wait events of executed launches")
        return evs

    def _implicit_deps(self) -> Tuple[Event, ...]:
        """The queue's implicit ordering edge for the next eager launch."""
        if not self.out_of_order:
            prev = self._events[-1] if self._events else None
            return (prev,) if prev is not None and not prev.released else ()
        if self._barrier is not None and not self._barrier.released:
            return (self._barrier,)
        return ()

    # -- the OpenCL-subset entry point -------------------------------------
    def enqueue_nd_range(self, kernel: Kernel, ndr: NDRange,
                         args: Sequence[Buffer],
                         params: Optional[Dict[str, Any]] = None,
                         counts_params: Optional[Dict[str, Any]] = None,
                         wait_events: Optional[Sequence[Event]] = None,
                         _resident: bool = False) -> Event:
        """Launch ``kernel`` over ``ndr`` with buffer ``args`` (non-blocking).

        ``params`` are executor kwargs (the paper's kernel-args region);
        ``counts_params`` are the problem sizes handed to the kernel's
        ``counts()`` for the machine model (defaults to ``params``).
        ``wait_events`` is the OpenCL ``event_wait_list``: events this
        launch must observe beyond its dataflow inputs.  On an in-order
        queue it adds edges on top of the implicit chain; on an
        ``out_of_order`` queue it is the ONLY explicit ordering (launches
        with no wait list and no dataflow link stay unordered).
        ``_resident=True`` marks a stage whose inputs are already resident
        in the unified memory / D$ (paper §IV-B pipeline chaining): the
        modeled host<->D$ transfer is waived for it.

        Inside a :meth:`capture` block the launch is recorded into the
        active :class:`CommandGraph` instead of executed; the returned
        event carries symbolic :class:`GraphBuffer` outputs and the
        dependency edges become graph nodes' ``deps``.
        """
        params = params or {}
        cp = counts_params if counts_params is not None else params
        waits = self._check_wait_events(wait_events)
        for i, b in enumerate(args):
            if not b.readable:
                raise ValueError(
                    f"kernel {kernel.name!r} arg {i} is a write-only "
                    f"(flags={b.flags!r}) buffer; kernels read their "
                    "arguments (CL_MEM_WRITE_ONLY violation)")
        if self._capture is not None:
            return self._capture._record(self, kernel, ndr, args, params, cp,
                                         _resident, waits)
        fn = self._executor_for(kernel, params)
        t0 = time.perf_counter()
        raw = fn(*[b.data for b in args], **params)
        if self.blocking:
            jax.block_until_ready(raw)
        dispatch = time.perf_counter() - t0
        outs = tuple(Buffer(r) for r in (raw if isinstance(raw, tuple) else (raw,)))

        modeled, energy, _counts = self._model(kernel, ndr, cp, _resident)
        deps = waits + self._implicit_deps()
        # Dataflow edges, mirroring capture's slot-producer tracking:
        # consuming another launch's output buffer is an ordering edge even
        # on an out-of-order queue (and across queues), so wait() realizes
        # the producer's done-flag transitively.
        for b in args:
            producer = getattr(b, "_event", None)
            if (producer is not None and not producer._done
                    and not producer.released and producer not in deps):
                deps += (producer,)
        ev = Event(kernel, outs, modeled, energy, dispatch, deps=deps)
        for b in outs:
            b._event = ev
        if self.blocking:
            ev._done = True
            ev.deps = ()
        self._events.append(ev)
        if self._tracer is not None:
            self._trace_event(ev)
        return ev

    def enqueue_kernel(self, kernel: Kernel, ndr: Optional[NDRange] = None,
                       counts_params: Optional[Dict[str, Any]] = None,
                       wait_events: Optional[Sequence[Event]] = None,
                       _resident: bool = False) -> Event:
        """clEnqueueNDRangeKernel over the kernel's *staged* arguments.

        The OpenCL-shaped companion to :meth:`enqueue_nd_range`: arguments
        come from :meth:`Kernel.set_args` / :meth:`Kernel.set_arg` instead
        of the call site.  ``ndr`` defaults to the paper's §VIII-B optimal
        NDRange for the first buffer's element count on this queue's device.
        """
        bufs, params = kernel.staged_args()
        if ndr is None:
            if not bufs:
                raise ValueError(
                    "enqueue_kernel needs an explicit NDRange for a kernel "
                    "with no buffer args")
            ndr = optimal_ndrange(int(bufs[0].data.size),
                                  self.ctx.device.config)
        return self.enqueue_nd_range(kernel, ndr, bufs, params=params,
                                     counts_params=counts_params,
                                     wait_events=wait_events,
                                     _resident=_resident)

    # -- explicit data movement (host API v2) -------------------------------
    def _transfer_event(self, kernel: Kernel, outputs: Tuple[Buffer, ...],
                        nbytes: float, waits: Tuple[Event, ...],
                        producers: Sequence[Buffer], blocking: bool) -> Event:
        """Eager transfer command: modeled cost + event-DAG bookkeeping."""
        deps = waits + self._implicit_deps()
        for b in producers:
            producer = getattr(b, "_event", None)
            if (producer is not None and not producer._done
                    and not producer.released and producer not in deps):
                deps += (producer,)
        modeled, energy = self._model_transfer(nbytes)
        ev = Event(kernel, outputs, modeled, energy, 0.0, deps=deps)
        if self.blocking or blocking:
            ev.wait()
        self._events.append(ev)
        if self._tracer is not None:
            self._trace_event(ev)
        return ev

    @staticmethod
    def _check_aval_match(what: str, data: Any, buf: Buffer) -> None:
        if tuple(data.shape) != tuple(buf.shape) or data.dtype != buf.dtype:
            raise ValueError(
                f"{what}: source {tuple(data.shape)}/{data.dtype} does not "
                f"match destination buffer {tuple(buf.shape)}/{buf.dtype} "
                "(sub-buffer offsets are not supported)")

    def enqueue_write_buffer(self, buf: Buffer, src: Any,
                             wait_events: Optional[Sequence[Event]] = None,
                             blocking: bool = False) -> Event:
        """clEnqueueWriteBuffer: move host data into ``buf`` (host -> D$).

        A first-class command: it returns a real :class:`Event`, is costed
        as a transfer-only :class:`PhaseBreakdown` from the device's bus
        parameters, obeys the queue's ordering rules (implicit chain /
        ``wait_events`` / barriers) and — under :meth:`capture` — records a
        transfer :class:`GraphNode`, so the DAG critical path can overlap
        it with compute on independent branches.  ``buf`` must be writable;
        later commands consuming ``buf`` observe the written value (the
        event is ``buf``'s new producer).  ``blocking=True`` is CL_TRUE:
        wait before returning.
        """
        waits = self._check_wait_events(wait_events)
        if not buf.writable:
            raise ValueError(
                f"enqueue_write_buffer into a read-only buffer "
                f"(flags={buf.flags!r}) — CL_MEM_READ_ONLY violation")
        if isinstance(src, Buffer) and not src.readable:
            raise ValueError(
                f"enqueue_write_buffer from a write-only source "
                f"(flags={src.flags!r}) — CL_MEM_WRITE_ONLY violation")
        if self._capture is not None:
            return self._capture._record_transfer(self, "write", buf, src,
                                                  waits)
        if isinstance(buf, GraphBuffer):
            raise RuntimeError(
                "cannot write a GraphBuffer eagerly; it has no storage "
                "outside its graph's launch")
        data = src.data if isinstance(src, Buffer) else jnp.asarray(src)
        if not isinstance(data, jax.Array):
            raise RuntimeError(
                "enqueue_write_buffer source must hold concrete data "
                "(GraphBuffer sources are capture-only)")
        self._check_aval_match("enqueue_write_buffer", data, buf)
        producers = (src, buf) if isinstance(src, Buffer) else (buf,)
        buf.data = data
        ev = self._transfer_event(_WRITE, (buf,), buf.nbytes, waits,
                                  producers, blocking)
        buf._event = ev
        return ev

    def enqueue_read_buffer(self, buf: Buffer,
                            wait_events: Optional[Sequence[Event]] = None,
                            blocking: bool = False) -> Event:
        """clEnqueueReadBuffer: move ``buf`` to the host (D$ -> host).

        Under unified memory the returned event's output *is* the buffer
        (no copy is made), but the command is costed as a real transfer over
        the host bus and participates in event ordering and graph capture —
        a capture ending in read commands returns the read-back values as
        the graph's outputs.  ``buf`` must be readable.
        """
        waits = self._check_wait_events(wait_events)
        if not buf.readable:
            raise ValueError(
                f"enqueue_read_buffer from a write-only buffer "
                f"(flags={buf.flags!r}) — CL_MEM_WRITE_ONLY violation")
        if self._capture is not None:
            return self._capture._record_transfer(self, "read", buf, None,
                                                  waits)
        if isinstance(buf, GraphBuffer):
            raise RuntimeError(
                "cannot read a GraphBuffer eagerly; launch its graph and "
                "read the outputs instead")
        return self._transfer_event(_READ, (buf,), buf.nbytes, waits, (buf,),
                                    blocking)

    def enqueue_copy_buffer(self, src: Buffer, dst: Buffer,
                            wait_events: Optional[Sequence[Event]] = None
                            ) -> Event:
        """clEnqueueCopyBuffer: device-side copy ``src`` -> ``dst``.

        ``src`` must be readable and ``dst`` writable, with matching
        shape/dtype.  Costed as one bus transfer of ``src.nbytes``; after
        the event, ``dst`` holds ``src``'s value (kernels are functional and
        arrays immutable, so the unified-memory copy is an alias).
        """
        waits = self._check_wait_events(wait_events)
        if not src.readable:
            raise ValueError(
                f"enqueue_copy_buffer from a write-only source "
                f"(flags={src.flags!r})")
        if not dst.writable:
            raise ValueError(
                f"enqueue_copy_buffer into a read-only destination "
                f"(flags={dst.flags!r}) — CL_MEM_READ_ONLY violation")
        self._check_aval_match("enqueue_copy_buffer", src.data, dst)
        if self._capture is not None:
            return self._capture._record_transfer(self, "copy", dst, src,
                                                  waits)
        if isinstance(src, GraphBuffer) or isinstance(dst, GraphBuffer):
            raise RuntimeError(
                "cannot copy GraphBuffers eagerly; they have no storage "
                "outside their graph's launch")
        dst.data = src.data
        ev = self._transfer_event(_COPY, (dst,), src.nbytes, waits,
                                  (src, dst), blocking=False)
        dst._event = ev
        return ev

    # -- synchronization commands ------------------------------------------
    def enqueue_marker(self, wait_events: Optional[Sequence[Event]] = None
                       ) -> Event:
        """clEnqueueMarkerWithWaitList: an event completing once
        ``wait_events`` (default: everything enqueued on this queue so far)
        have completed.  Carries no cost model and no outputs."""
        return self._enqueue_sync(wait_events, barrier=False)

    def enqueue_barrier(self, wait_events: Optional[Sequence[Event]] = None
                        ) -> Event:
        """clEnqueueBarrierWithWaitList: like :meth:`enqueue_marker`, but
        also an ordering point — every *subsequent* launch on an
        ``out_of_order`` queue implicitly depends on it (in-order queues
        already chain, so there it only returns the aggregate event)."""
        return self._enqueue_sync(wait_events, barrier=True)

    def _enqueue_sync(self, wait_events: Optional[Sequence[Event]],
                      barrier: bool) -> Event:
        # OpenCL: an empty wait list means "all previously enqueued
        # commands", same as passing none at all.
        waits = self._check_wait_events(wait_events) or None
        if self._capture is not None:
            return self._capture._record_sync(self, waits, barrier)
        if waits:
            # The queue's ordering rules still apply to the marker itself
            # (in-order: chained after the previous command; out-of-order:
            # after the latest barrier).
            deps = waits + self._implicit_deps()
        else:
            # Events before the finish()/drain() watermark are already
            # realized and contribute nothing — snapshotting them would
            # make each marker O(history) on a long-lived profiled queue.
            deps = tuple(e for e in self._events[self._drained:]
                         if not e.released)
        ev = Event(_MARKER, (), None, None, 0.0, deps=deps)
        self._events.append(ev)
        if self._tracer is not None:
            self._trace_event(ev)
        if barrier:
            self._barrier = ev
        return ev

    # -- graph capture ------------------------------------------------------
    def capture(self) -> "CommandGraph":
        """Record subsequent enqueues into a :class:`CommandGraph`.

        Use as a context manager::

            with q.capture() as graph:
                q.enqueue_nd_range(k1, ndr, (a, b))   # recorded, not run
                ...
            outs = graph.launch()                      # one fused dispatch

        Launches inside the block are traced abstractly (``jax.eval_shape``)
        so capture itself never touches the device.
        """
        return CommandGraph(self)

    def flush(self) -> None:
        """clFlush — dispatch is eager under JAX, so this is a no-op."""

    def finish(self) -> None:
        """Block until every enqueued kernel completed (clFinish).

        Only events enqueued since the last ``finish()`` are waited (a
        drained-watermark: repeated drains on a long-lived queue stay O(new
        work), not O(full history)).  On an unprofiled queue the drained
        events are then released outright; with ``max_events`` set, the
        retained history is trimmed to the window (oldest first)."""
        for ev in self._events[self._drained:]:
            if not ev.released:            # user-released mid-history: the
                ev.wait()                  # outputs are gone, nothing to wait
        self._drained = len(self._events)
        if not self.profile:
            self.release_events()
        elif (self.max_events is not None
              and len(self._events) > self.max_events):
            self.release_events(upto=len(self._events) - self.max_events)

    def drain(self, n: int) -> None:
        """Wait the oldest ``n`` retained events (a *partial* clFinish).

        Starts at the ``finish()`` watermark — events a previous drain
        already realized are never re-waited, so repeated partial drains on
        a long-lived queue stay O(new work), not O(history).  Lets a
        serving layer retire one launch's event segment without
        synchronizing launches enqueued after it — pair with
        ``release_events(upto=n)`` to drop exactly that segment."""
        n = min(n, len(self._events))
        for ev in self._events[self._drained:n]:
            if not ev.released:
                ev.wait()
        self._drained = max(self._drained, n)

    def release_events(self, upto: Optional[int] = None) -> int:
        """Release and drop the oldest ``upto`` events (clReleaseEvent sweep).

        Only *drained* events are eligible — an event :meth:`finish` has not
        waited yet may still be in flight.  Each dropped event's modeled
        time/energy is folded into the queue's running totals first, so
        :meth:`total_modeled_s` / :meth:`total_energy_j` are unaffected.
        ``Event.retain()``-ed events are still dropped from the queue's
        history, but keep their output buffers alive for the holder.
        Returns the number of events released.
        """
        upto = self._drained if upto is None else min(upto, self._drained)
        if upto <= 0:
            return 0
        for ev in self._events[:upto]:
            if ev.modeled is not None:
                self._released_modeled_s += ev.modeled.total_s
            if ev.energy_j is not None:
                self._released_energy_j += ev.energy_j
            self._released_count += 1
            ev.release()
        del self._events[:upto]
        self._drained -= upto
        return upto

    @property
    def events(self) -> Tuple[Event, ...]:
        """Retained (not yet released) events, oldest first."""
        return tuple(self._events)

    @property
    def released_count(self) -> int:
        """Events released from this queue's history so far."""
        return self._released_count

    def total_modeled_s(self) -> float:
        # `is not None`, not truthiness: an all-zero PhaseBreakdown (e.g. a
        # fully resident stage) must still be counted.  Released events are
        # accounted via the running totals.
        return self._released_modeled_s + sum(
            e.modeled.total_s for e in self._events if e.modeled is not None)

    def total_energy_j(self) -> float:
        return self._released_energy_j + sum(
            e.energy_j for e in self._events if e.energy_j is not None)


@dataclasses.dataclass
class GraphNode:
    """One captured launch: kernel + wiring + capture-time machine model."""

    kernel: Kernel
    call: Callable[..., Any]            # executor with params pre-bound
    in_slots: Tuple[int, ...]
    out_slots: Tuple[int, ...]
    out_avals: Tuple[jax.ShapeDtypeStruct, ...]
    modeled: Optional[PhaseBreakdown]
    energy_j: Optional[float]
    n_items: int = 0                    # first input's element count (the
                                        # NDRange sizing the eager path uses)
    #: indices of earlier nodes this one depends on (dataflow slots +
    #: wait_events + the enqueueing queue's ordering rules) — the edges of
    #: the event-dependency DAG the critical-path model walks
    deps: Tuple[int, ...] = ()
    #: node class: "kernel", "sync" (marker/barrier), or an explicit
    #: transfer command — "write" / "read" / "copy"
    kind: str = "kernel"
    #: bytes moved over the host bus (transfer nodes only)
    nbytes: float = 0.0
    #: the WorkCounts this node was priced with at capture (resident
    #: adjustment applied; ``None`` for sync/transfer nodes and unprofiled
    #: queues) — lets consumers read modeled traffic straight off the
    #: captured schedule (the serve engine's bytes/step roofline)
    counts: Optional[WorkCounts] = None
    #: slots whose logical buffer this (write/copy) node's output REBINDS —
    #: the destination's previous value.  Slots are SSA, so without this
    #: the overwrite relationship is gone after capture; the graph
    #: sanitizer (repro.analyze) re-proves the WAR/WAW ordering edges the
    #: capture added for it
    overwrites: Tuple[int, ...] = ()

    @property
    def is_transfer(self) -> bool:
        return self.kind in ("write", "read", "copy")


class CommandGraph:
    """A captured kernel DAG, launched as one fused XLA computation.

    Built by :meth:`CommandQueue.capture`.  While capturing, every
    ``enqueue_nd_range`` appends a :class:`GraphNode`: inputs are resolved to
    *slots* — either graph-external buffers (concrete data seen during
    capture) or earlier nodes' outputs — and output shapes come from
    ``jax.eval_shape``, so nothing executes.  Each node also records its
    dependency edges: dataflow (consuming an earlier node's output slot),
    explicit ``wait_events``, and the enqueueing queue's ordering rules
    (implicit chaining on in-order queues, barrier frontiers on out-of-order
    ones).  Markers and barriers are recorded as zero-cost, output-less
    nodes, so OpenCL's transitive ordering falls out of the DAG structure.
    :meth:`join` records enqueues from *additional* queues (e.g. the
    host's) into the same capture, so one graph can hold host + e-GPU nodes
    with cross-queue event edges.

    :meth:`launch` replays all nodes inside a single ``jax.jit``; the
    graph's outputs are the final kernel node's outputs.  Launches bind to the
    *caller's* queue (``launch(..., queue=...)``): events and modeled
    totals land on the queue that launched, not the one that captured —
    a cached graph shared by several serving workers keeps every worker's
    accounting separate.

    Per-node ``modeled`` / ``energy_j`` come from the captured schedule
    (``WorkCounts`` at capture time) on the enqueueing queue's device,
    giving the same per-stage Fig-3/Fig-4 accounting as eager dispatch
    while the wall-clock path is fused; :meth:`fused_modeled` walks the
    dependency DAG's critical path, so concurrent branches overlap instead
    of summing.
    """

    def __init__(self, queue: CommandQueue):
        self.queue = queue                     # home queue: default binding
        self.queues: List[CommandQueue] = [queue]
        self.nodes: List[GraphNode] = []
        self._n_slots = 0
        self._ext_slots: List[int] = []        # slot index of each external
        self._ext_values: List[jax.Array] = [] # captured concrete externals
        self._ext_avals: List[jax.ShapeDtypeStruct] = []
        self._buf_slot: Dict[int, int] = {}    # id(Buffer) -> slot
        self._bufs_alive: List[Buffer] = []    # keep ids stable during capture
        self._slot_producer: Dict[int, int] = {}   # slot -> producing node
        self._slot_readers: Dict[int, List[int]] = {}  # slot -> consumer nodes
        self._queue_nodes: Dict[int, List[int]] = {}   # id(queue) -> nodes
        self._last_node: Dict[int, int] = {}   # id(queue) -> last node idx
        self._barrier_node: Dict[int, int] = {}  # out-of-order barrier point
        self._jit_cache: Dict[Tuple[Any, ...], Callable] = {}
        self._sealed = False
        self._fused_memo: Optional[Tuple[Optional[PhaseBreakdown], float]] = None
        #: slot -> CL_MEM-style access flags of the buffer behind it, so
        #: the sanitizer can re-check flag discipline after capture
        self._slot_flags: Dict[int, str] = {}
        #: verify() results per donation tuple — verification is a pure
        #: function of the sealed capture, so warm serving pays one dict
        #: lookup at most (and zero when REPRO_VERIFY is off)
        self._verify_memo: Dict[Tuple[int, ...], Tuple[Any, ...]] = {}

    # -- capture ------------------------------------------------------------
    def __enter__(self) -> "CommandGraph":
        if self.queue._capture is not None:
            raise RuntimeError("CommandQueue is already capturing")
        self.queue._capture = self
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        for q in self.queues:                  # joined queues too
            if q._capture is self:
                q._capture = None
        # Only a capture body that completed cleanly yields a launchable
        # graph; an exception mid-capture leaves a truncated chain.
        self._sealed = exc_type is None
        # REPRO_VERIFY=1 (repro.analyze): sanitize every capture at seal
        # time, so a whole test/bench run doubles as a sanitizer sweep.
        if (self._sealed and self.nodes
                and os.environ.get("REPRO_VERIFY") == "1"):
            findings = self.verify()
            if findings:
                from ..analyze.graph import GraphVerifyError
                raise GraphVerifyError(findings)

    def join(self, queue: CommandQueue) -> "_GraphJoin":
        """Record enqueues on another queue into this capture.

        Use as a context manager *inside* the capture block to build a
        multi-queue graph (host + e-GPU nodes in one capture)::

            with egpu_q.capture() as graph:
                pre = egpu_q.enqueue_nd_range(k_pre, ndr, (a,))
                with graph.join(host_q):
                    post = host_q.enqueue_nd_range(k_post, ndr_h, pre.outputs,
                                                   wait_events=[pre])

        Each node is costed with its *own* queue's device model (the host
        node above uses the scalar-host machine model), and cross-queue
        ``wait_events`` become ordinary DAG edges.
        """
        return _GraphJoin(self, queue)

    def _slot_of(self, buf: Buffer) -> int:
        slot = self._buf_slot.get(id(buf))
        if slot is None:
            if isinstance(buf, GraphBuffer):
                raise RuntimeError(
                    "GraphBuffer from a different capture passed as input")
            slot = self._new_slot()
            self._buf_slot[id(buf)] = slot
            self._bufs_alive.append(buf)
            self._slot_flags[slot] = buf.flags
            self._ext_slots.append(slot)
            self._ext_values.append(buf.data)
            self._ext_avals.append(
                jax.ShapeDtypeStruct(buf.data.shape, buf.data.dtype))
        return slot

    def _new_slot(self) -> int:
        s = self._n_slots
        self._n_slots += 1
        return s

    def _dep_nodes_of(self, ev: Event) -> frozenset:
        """Node indices an event stands for (capture-time events only)."""
        nodes = getattr(ev, "_dep_nodes", None)
        if nodes is None or getattr(ev, "_graph", None) is not self:
            raise RuntimeError(
                "wait_events during capture must be events returned by this "
                "capture (eager or foreign-graph events have no node "
                "identity here)")
        return nodes

    def _record(self, queue: CommandQueue, kernel: Kernel, ndr: NDRange,
                args: Sequence[Buffer], params: Dict[str, Any],
                counts_params: Dict[str, Any], resident: bool,
                wait_events: Tuple[Event, ...] = ()) -> Event:
        in_slots = tuple(self._slot_of(b) for b in args)
        in_avals = tuple(
            jax.ShapeDtypeStruct(b.data.shape, b.data.dtype) for b in args)

        def call(*arrays, _exe=kernel.executor, _params=dict(params)):
            out = _exe(*arrays, **_params)
            return out if isinstance(out, tuple) else (out,)

        out_avals = tuple(jax.eval_shape(call, *in_avals))
        out_slots = tuple(self._new_slot() for _ in out_avals)
        # Cost the node on the ENQUEUEING queue's device: a multi-queue
        # capture mixes host and e-GPU nodes, each with its own model.
        modeled, energy, counts = queue._model(kernel, ndr, counts_params,
                                               resident)

        # Dependency edges: dataflow + wait_events + queue ordering.
        deps = set()
        for s in in_slots:
            producer = self._slot_producer.get(s)
            if producer is not None:
                deps.add(producer)
        for ev in wait_events:
            deps.update(self._dep_nodes_of(ev))
        deps.update(self._queue_order_deps(queue))
        idx = self._append_node(
            queue, GraphNode(kernel, call, in_slots, out_slots,
                             out_avals, modeled, energy,
                             n_items=int(args[0].data.size) if args else 0,
                             deps=tuple(sorted(deps)), counts=counts))
        for s in in_slots:
            self._slot_readers.setdefault(s, []).append(idx)
        for s in out_slots:
            self._slot_producer[s] = idx
            self._slot_flags[s] = "rw"      # kernel outputs: fresh rw slots
        outs = tuple(GraphBuffer(a, s) for a, s in zip(out_avals, out_slots))
        for b in outs:
            self._buf_slot[id(b)] = b.slot
            self._bufs_alive.append(b)
        ev = Event(kernel, outs, modeled, energy, 0.0)
        ev._graph = self
        ev._dep_nodes = frozenset((idx,))
        return ev

    def _queue_order_deps(self, queue: CommandQueue) -> Tuple[int, ...]:
        """The enqueueing queue's implicit ordering edge for the next node:
        its previous command (in-order) or its latest barrier node
        (out-of-order).  One edge — earlier constraints flow transitively
        through the chain / barrier nodes."""
        qid = id(queue)
        if not queue.out_of_order:
            last = self._last_node.get(qid)
            return () if last is None else (last,)
        bar = self._barrier_node.get(qid)
        return () if bar is None else (bar,)

    def _append_node(self, queue: CommandQueue, node: GraphNode) -> int:
        qid = id(queue)
        idx = len(self.nodes)
        self.nodes.append(node)
        self._queue_nodes.setdefault(qid, []).append(idx)
        self._last_node[qid] = idx
        return idx

    def _record_sync(self, queue: CommandQueue,
                     wait_events: Optional[Tuple[Event, ...]],
                     barrier: bool) -> Event:
        """Capture-time marker/barrier: a zero-cost :class:`GraphNode`.

        Recording sync commands as real (modeled-``None``, output-less)
        nodes makes OpenCL's transitivity structural: a later marker's
        default wait list ("all previously enqueued commands") includes
        earlier sync nodes and hence — through THEIR edges — cross-queue
        dependencies; a new barrier chains to the previous barrier node, so
        every earlier barrier's constraint keeps reaching later launches
        with O(1) edges per node.  The critical-path model treats them as
        zero-cost pass-throughs."""
        qid = id(queue)
        deps = set(self._queue_order_deps(queue))
        if not wait_events:
            # None or empty: all commands enqueued on this queue so far
            # (sync nodes included — that's what carries transitivity).
            deps.update(self._queue_nodes.get(qid, ()))
        else:
            # _queue_order_deps already chained this command after the
            # queue's latest barrier (out-of-order) or predecessor
            # (in-order), so an earlier barrier's constraint persists
            # alongside the explicit list.
            for e in wait_events:
                deps.update(self._dep_nodes_of(e))
        idx = self._append_node(
            queue, GraphNode(_MARKER, lambda: (), (), (), (),
                             None, None, n_items=0,
                             deps=tuple(sorted(deps)), kind="sync"))
        if barrier:
            self._barrier_node[qid] = idx
        ev = Event(_MARKER, (), None, None, 0.0)
        ev._graph = self
        ev._dep_nodes = frozenset((idx,))
        return ev

    def _record_transfer(self, queue: CommandQueue, kind: str, buf: Buffer,
                         src: Any, wait_events: Tuple[Event, ...]) -> Event:
        """Capture an explicit transfer command as a real :class:`GraphNode`.

        The node's ``call`` is identity (XLA elides it inside the fused
        computation — under unified memory the data never actually moves),
        but it carries the transfer-only machine model and full dependency
        edges, so ``fused_modeled()``'s critical path prices the traffic
        and can overlap it with compute on independent branches.

        Slot wiring per command:

        * ``write``: the host source becomes an input slot (an *external*
          when it is fresh data — ``launch_prefix`` can then feed new
          request payloads straight through write nodes); the destination
          buffer is **rebound** to the node's output slot, so later
          consumers of ``buf`` depend on the write.  The old binding (if
          any) contributes a write-after-read/write ordering edge.
        * ``read``: consumes the buffer's current slot, produces a fresh
          slot holding the host copy; the buffer keeps its binding.
        * ``copy``: consumes the source's slot, rebinds the destination.
        """
        if kind == "write":
            src_buf = src if isinstance(src, Buffer) else Buffer(src)
            CommandQueue._check_aval_match("enqueue_write_buffer",
                                           src_buf.data, buf)
            in_buf, rebind = src_buf, buf
            sentinel, out_flags = _WRITE, buf.flags
        elif kind == "read":
            in_buf, rebind = buf, None
            sentinel, out_flags = _READ, buf.flags
        else:
            CommandQueue._check_aval_match("enqueue_copy_buffer",
                                           src.data, buf)
            in_buf, rebind = src, buf
            sentinel, out_flags = _COPY, buf.flags
        in_slot = self._slot_of(in_buf)
        aval = jax.ShapeDtypeStruct(tuple(in_buf.data.shape),
                                    in_buf.data.dtype)
        nbytes = float(aval.size * aval.dtype.itemsize)
        modeled, energy = queue._model_transfer(nbytes)

        deps = set()
        overwrites: Tuple[int, ...] = ()
        producer = self._slot_producer.get(in_slot)
        if producer is not None:
            deps.add(producer)
        if rebind is not None:
            # write-after-write on the destination's old producer, plus
            # write-after-read on every node that consumed the old value —
            # an overwrite must not model as concurrent with readers of the
            # value it replaces
            prev_slot = self._buf_slot.get(id(rebind))
            if prev_slot is not None:
                prev_producer = self._slot_producer.get(prev_slot)
                if prev_producer is not None:
                    deps.add(prev_producer)
                deps.update(self._slot_readers.get(prev_slot, ()))
                overwrites = (prev_slot,)    # sanitizer re-proves the edges
        for ev in wait_events:
            deps.update(self._dep_nodes_of(ev))
        deps.update(self._queue_order_deps(queue))

        out_slot = self._new_slot()
        idx = self._append_node(
            queue, GraphNode(sentinel, lambda x: (x,), (in_slot,),
                             (out_slot,), (aval,), modeled, energy,
                             n_items=int(aval.size),
                             deps=tuple(sorted(deps)), kind=kind,
                             nbytes=nbytes, overwrites=overwrites))
        self._slot_readers.setdefault(in_slot, []).append(idx)
        self._slot_producer[out_slot] = idx
        self._slot_flags[out_slot] = out_flags
        if rebind is not None:
            self._buf_slot[id(rebind)] = out_slot
            self._bufs_alive.append(rebind)
        out = GraphBuffer(aval, out_slot, flags=out_flags)
        self._buf_slot[id(out)] = out_slot
        self._bufs_alive.append(out)
        ev = Event(sentinel, (out,), modeled, energy, 0.0)
        ev._graph = self
        ev._dep_nodes = frozenset((idx,))
        return ev

    # -- accounting ---------------------------------------------------------
    @property
    def n_external(self) -> int:
        return len(self._ext_slots)

    @property
    def ext_avals(self) -> Tuple[jax.ShapeDtypeStruct, ...]:
        """Shape/dtype of each external input, in capture order."""
        return tuple(self._ext_avals)

    def modeled_breakdowns(self) -> Tuple[Optional[PhaseBreakdown], ...]:
        return tuple(n.modeled for n in self.nodes)

    def node_deps(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-node dependency edges (indices into :attr:`nodes`)."""
        return tuple(n.deps for n in self.nodes)

    def verify(self, donate: Sequence[int] = ()) -> Tuple[Any, ...]:
        """Statically sanitize the captured DAG (see :mod:`repro.analyze`).

        Returns the :class:`~repro.analyze.graph.Finding` tuple — empty for
        a hazard-free capture.  ``donate`` lists donated external-input
        positions (capture order), enabling the use-after-donate /
        double-donation checks.  Results are memoized per donation tuple:
        verification is a pure function of the sealed capture, so a warm
        serving path re-verifying before every donating launch pays one
        dict lookup, never a re-walk.
        """
        key = tuple(sorted(int(i) for i in donate))
        memo = self._verify_memo.get(key)
        if memo is None:
            from ..analyze.graph import verify_graph
            memo = verify_graph(self, donate=key)
            self._verify_memo[key] = memo
        return memo

    def total_modeled_s(self) -> float:
        return sum(n.modeled.total_s for n in self.nodes
                   if n.modeled is not None)

    def total_energy_j(self) -> float:
        return sum(n.energy_j for n in self.nodes if n.energy_j is not None)

    def fused_modeled(self) -> Tuple[Optional[PhaseBreakdown], float]:
        """(fused breakdown, total energy) of the captured DAG, memoized.

        The breakdown is the *critical path* through the dependency DAG
        (:func:`~repro.core.machine.fuse_breakdowns` with ``deps``):
        concurrent branches of an out-of-order capture overlap instead of
        summing, while a linear in-order chain reproduces the classic
        chain fusion exactly.  Energy is total work — it sums over every
        node regardless of concurrency.  Both come from capture time and
        never change across launches — the serving hot path reads them
        once per launch, so re-walking the node list every time would be
        pure waste.  The breakdown is ``None`` when no node carries a
        machine model.
        """
        if self._fused_memo is None:
            mods = self.modeled_breakdowns()
            fused = (fuse_breakdowns(mods, deps=self.node_deps())
                     if any(m is not None for m in mods) else None)
            self._fused_memo = (fused, self.total_energy_j())
        return self._fused_memo

    @property
    def out_avals(self) -> Tuple[jax.ShapeDtypeStruct, ...]:
        """Shape/dtype of each launch output, in output order (what a
        serving layer needs to derive per-output shardings before any
        launch happened)."""
        slot_aval: Dict[int, jax.ShapeDtypeStruct] = {}
        for node in self.nodes:
            for s, a in zip(node.out_slots, node.out_avals):
                slot_aval[s] = a
        return tuple(slot_aval[s] for s in self._output_slots())

    # -- launch -------------------------------------------------------------
    def _output_slots(self) -> Tuple[int, ...]:
        """The slots a launch returns.

        Trailing ``read_buffer`` nodes define the outputs (a capture ending
        in explicit reads returns the read-back values, one per read, in
        enqueue order — markers/barriers in between are ignored); otherwise
        the last node with outputs, so a trailing marker/barrier never eats
        them.
        """
        reads: List[GraphNode] = []
        for node in reversed(self.nodes):
            if node.kind == "read":
                reads.append(node)
            elif node.out_slots:
                break
        if reads:
            return tuple(s for n in reversed(reads) for s in n.out_slots)
        return next(n.out_slots for n in reversed(self.nodes) if n.out_slots)

    def _fused(self, donate: Tuple[int, ...],
               in_shardings: Optional[Tuple[Any, ...]] = None,
               out_shardings: Optional[Tuple[Any, ...]] = None) -> Callable:
        # One compiled executable per (donation, mesh binding): the same
        # captured graph serves single-device and sharded launches side by
        # side — shardings are a launch-time property, never part of the
        # capture (NamedShardings hash by mesh + spec, so the key is cheap).
        key = (donate, in_shardings, out_shardings)
        fn = self._jit_cache.get(key)
        if fn is not None:
            return fn

        nodes = tuple(self.nodes)
        ext_slots = tuple(self._ext_slots)
        out_slots = self._output_slots()
        n_slots = self._n_slots

        def run(*ext):
            vals: List[Any] = [None] * n_slots
            for slot, v in zip(ext_slots, ext):
                vals[slot] = v
            for node in nodes:
                outs = node.call(*[vals[s] for s in node.in_slots])
                for slot, o in zip(node.out_slots, outs):
                    vals[slot] = o
            return tuple(vals[s] for s in out_slots)

        jit_kwargs: Dict[str, Any] = {}
        if in_shardings is not None:
            jit_kwargs["in_shardings"] = in_shardings
        if out_shardings is not None:
            jit_kwargs["out_shardings"] = out_shardings
        fn = jax.jit(run, donate_argnums=donate, **jit_kwargs)
        self._jit_cache[key] = fn
        return fn

    def launch(self, *inputs: Any, donate: Sequence[int] = (),
               queue_events: bool = True,
               queue: Optional[CommandQueue] = None,
               in_shardings: Optional[Sequence[Any]] = None,
               out_shardings: Optional[Sequence[Any]] = None
               ) -> Tuple[Buffer, ...]:
        """Execute the captured chain as one fused dispatch (non-blocking).

        ``inputs`` replace the graph's external buffers in capture order
        (shapes/dtypes must match); with no inputs the arrays captured at
        record time are reused.  ``donate`` lists external-input positions
        whose device buffers XLA may reuse for the computation (jit
        ``donate_argnums``); never pass an index whose buffer the caller
        still needs.  Backends without donation support (CPU) silently
        ignore it.  Returns the final node's outputs as fresh buffers.

        **Mesh binding** (sharded serving): ``in_shardings`` — one
        ``jax.sharding.Sharding`` (or ``None`` = unconstrained) per external
        input, in capture order — and ``out_shardings`` — one per graph
        output — compile the fused computation under that placement
        (GSPMD partitions it across the shardings' mesh).  A cached graph
        stays pure compiled code under any mesh binding: each distinct
        (donate, shardings) combination gets its own jitted executable in
        the graph's jit cache, so one entry serves single-device workers
        and :class:`~repro.serve.sharded.ShardedWorker`\\ s side by side.
        Kernels are pure and the batch rows independent, so a data-parallel
        binding can never change functional results.

        **Launch-time queue binding**: per-node modeled events are appended
        to ``queue`` — the *caller's* queue — defaulting to the capture
        queue for one-shot use.  A cached graph launched by several
        workers therefore books each launch's events and modeled totals on
        the launching worker's own queue; nothing ever lands on a sibling's
        history.  The binding queue owns the WHOLE launch: for a
        multi-queue graph (:meth:`join`) the joined queues' nodes are
        booked there too — per-queue totals are per *launching* queue, not
        per device; read :meth:`modeled_breakdowns` for the per-node /
        per-device split.
        """
        if any(q._capture is self for q in self.queues):
            raise RuntimeError("cannot launch while still capturing")
        if not self._sealed:
            raise RuntimeError(
                "capture did not complete cleanly; re-capture the chain "
                "before launching")
        if not any(n.out_slots for n in self.nodes):
            raise RuntimeError(
                "cannot launch an empty CommandGraph (no kernel nodes)")
        if donate and not inputs:
            # Donating the graph's own captured arrays would poison every
            # later zero-argument launch on backends that honor donation.
            raise ValueError(
                "donate requires explicit launch inputs: the captured "
                "external arrays must stay valid for later launches")
        ext = list(inputs) if inputs else list(self._ext_values)
        if len(ext) != len(self._ext_slots):
            raise ValueError(
                f"graph takes {len(self._ext_slots)} external inputs, "
                f"got {len(ext)}")
        ext = [jnp.asarray(x) for x in ext]
        # Shape/dtype must match the capture: a silent retrace would attach
        # capture-time modeled costs to a differently-sized computation.
        for i, (x, aval) in enumerate(zip(ext, self._ext_avals)):
            if x.shape != aval.shape or x.dtype != aval.dtype:
                raise ValueError(
                    f"launch input {i} is {x.shape}/{x.dtype}, but the graph "
                    f"was captured with {aval.shape}/{aval.dtype}; re-capture "
                    "for a different problem size")
        in_sh = None
        if in_shardings is not None:
            in_sh = tuple(in_shardings)
            if len(in_sh) != len(self._ext_slots):
                raise ValueError(
                    f"in_shardings must cover all {len(self._ext_slots)} "
                    f"external inputs (None for unconstrained), got "
                    f"{len(in_sh)}")
        out_sh = None
        if out_shardings is not None:
            out_sh = tuple(out_shardings)
            n_out = len(self._output_slots())
            if len(out_sh) != n_out:
                raise ValueError(
                    f"out_shardings must cover all {n_out} graph outputs "
                    f"(None for unconstrained), got {len(out_sh)}")
        donate_key = tuple(sorted(int(i) for i in donate))
        if donate_key and os.environ.get("REPRO_VERIFY") == "1":
            # donation-aware sweep (memoized): a reader of a donated slot
            # off the ordered path would observe reused storage
            findings = self.verify(donate=donate_key)
            if findings:
                from ..analyze.graph import GraphVerifyError
                raise GraphVerifyError(findings)
        fn = self._fused(donate_key, in_sh, out_sh)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # CPU backends warn that donated buffers were unused; donation
            # is best-effort there by design.
            warnings.filterwarnings(
                "ignore", message=".*donated.*", category=UserWarning)
            raw = fn(*ext)
        dispatch = time.perf_counter() - t0
        outs = tuple(Buffer(r) for r in raw)
        if queue_events:
            target = queue if queue is not None else self.queue
            # Outputs belong to the node that produced them (mirrors
            # _output_slots): the last out_slot-bearing node, or — when the
            # capture ends in explicit reads — each trailing read node gets
            # its own read-back buffer.
            slot_buf = dict(zip(self._output_slots(), outs))
            for i, node in enumerate(self.nodes):
                node_outs = tuple(slot_buf[s] for s in node.out_slots
                                  if s in slot_buf)
                per_node = dispatch if i == 0 else 0.0
                ev = Event(node.kernel, node_outs, node.modeled,
                           node.energy_j, per_node)
                target._events.append(ev)
                if target._tracer is not None:
                    target._trace_event(ev)
                for b in node_outs:      # dataflow edge for later eager
                    b._event = ev        # consumers, same as enqueue
        return outs

    def launch_prefix(self, inputs: Sequence[Any],
                      **launch_kwargs: Any) -> Tuple[Buffer, ...]:
        """Launch with only the first ``len(inputs)`` externals replaced.

        The remaining externals keep the arrays captured at record time —
        for a pipeline graph these are the per-stage constant buffers
        (weights, coefficients), so a serving layer can feed fresh request
        data without re-threading the pipeline's parameters (this is the
        entry point ``repro.serve.GraphCache`` launches through).  Pass
        ``queue=`` to bind the launch's events and modeled totals to the
        caller's queue, and ``in_shardings=``/``out_shardings=`` to bind
        the launch to a device mesh (see :meth:`launch`; ``in_shardings``
        covers ALL externals — replaced prefix and captured constants
        alike — in capture order).
        """
        inputs = list(inputs)
        if len(inputs) > len(self._ext_values):
            raise ValueError(
                f"launch_prefix got {len(inputs)} inputs but the graph has "
                f"only {len(self._ext_values)} externals")
        donate = launch_kwargs.get("donate", ())
        if any(int(i) >= len(inputs) for i in donate):
            # Positions beyond the replaced prefix are filled from the
            # graph's own captured arrays — donating one would consume a
            # buffer every later launch still needs (same hazard the
            # donate-without-inputs guard in launch() exists for).
            raise ValueError(
                "launch_prefix can only donate caller-supplied positions "
                f"(< {len(inputs)}); the rest are captured externals")
        return self.launch(*inputs, *self._ext_values[len(inputs):],
                           **launch_kwargs)


class _GraphJoin:
    """Context manager adding a second queue to an active capture."""

    def __init__(self, graph: CommandGraph, queue: CommandQueue):
        self._graph = graph
        self._queue = queue
        self._attached = False

    def __enter__(self) -> CommandGraph:
        graph, queue = self._graph, self._queue
        if graph.queue._capture is not graph:
            raise RuntimeError("join() is only valid inside an active capture")
        if queue._capture is not None and queue._capture is not graph:
            raise RuntimeError("queue is already capturing another graph")
        # Only detach on exit what THIS join attached: joining a queue that
        # is already capturing the graph (the capture's own queue, or a
        # nested join) must not end its capture when the inner block closes.
        self._attached = queue._capture is None
        queue._capture = graph
        if all(q is not queue for q in graph.queues):
            graph.queues.append(queue)
        return graph

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._attached and self._queue._capture is self._graph:
            self._queue._capture = None


class Device:
    """One compute device: an e-GPU instance or the scalar host baseline."""

    def __init__(self, config: EGPUConfig = EGPU_16T):
        self.config = config

    @property
    def is_host(self) -> bool:
        return self.config.name == HOST.name


class Context:
    def __init__(self, device: Device):
        self.device = device

    def create_buffer(self, data, flags: str = "rw",
                      copy: Optional[bool] = None,
                      use_host_ptr: bool = False) -> Buffer:
        """clCreateBuffer analogue.

        ``copy=None`` (default) picks the cheap path per input: a
        ``jax.Array`` is adopted as-is (it already lives in the unified
        memory — copying it again would be pure waste), anything else is
        converted.  ``copy=True`` forces a fresh device array
        (CL_MEM_COPY_HOST_PTR); ``copy=False`` requires a ``jax.Array`` and
        guarantees adoption.  ``use_host_ptr=True`` is the
        CL_MEM_USE_HOST_PTR analogue: the buffer *aliases* the caller's
        array (same object — exact under unified memory and immutable
        arrays); it implies ``copy=False`` and rejects non-JAX data, whose
        storage TinyCL could not alias.
        """
        if use_host_ptr:
            if copy:
                raise ValueError("use_host_ptr=True is incompatible with "
                                 "copy=True (CL_MEM_USE_HOST_PTR aliases "
                                 "the host array)")
            copy = False
        if copy is None:
            copy = not isinstance(data, jax.Array)
        if not copy:
            if not isinstance(data, jax.Array):
                if use_host_ptr:
                    raise TypeError(
                        "use_host_ptr requires a jax.Array host pointer, "
                        f"got {type(data).__name__}")
                raise TypeError(
                    f"copy=False requires a jax.Array, got "
                    f"{type(data).__name__} (TinyCL cannot adopt foreign "
                    "storage without a copy)")
            return Buffer(data, flags)
        arr = jnp.array(data) if isinstance(data, jax.Array) else jnp.asarray(data)
        return Buffer(arr, flags)
