"""TinyCL — the host-side Tiny-OpenCL runtime (paper §V / §VI-C), in JAX.

The paper's runtime is a subset of the OpenCL host API that works without an
OS, file system, or multithreading: create buffers, set kernel args, enqueue
an NDRange, wait for the completion interrupt.  We reproduce that API shape
with JAX semantics:

* a :class:`Buffer` wraps a ``jax.Array`` living in the *unified* memory
  (host HBM == device global memory, exactly the paper's §IV-B model);
* a :class:`Kernel` couples an executor (a pure JAX callable — either the
  pure-jnp reference or the Pallas TPU implementation) with a ``counts``
  function that derives the structural :class:`~repro.core.machine.WorkCounts`
  for the analytic machine model;
* ``CommandQueue.enqueue_nd_range`` jit-executes the kernel and returns an
  :class:`Event` carrying both the functional results and the modeled
  :class:`~repro.core.machine.PhaseBreakdown` / energy for the queue's device
  configuration — the numbers behind Figs 3 & 4;
* events chain: kernels consuming a prior event's outputs execute after it
  (JAX dataflow gives this for free, matching in-order OpenCL queues).

Execution model — asynchronous dispatch (paper §VIII-B)
-------------------------------------------------------

``enqueue_nd_range`` is **non-blocking**: it hands the launch to XLA and
returns immediately with an :class:`Event` whose output buffers hold
*unrealized* ``jax.Array``\\ s.  Back-to-back enqueues therefore overlap
host-side dispatch with device compute — true in-order OpenCL queue
semantics.  Synchronization points are explicit:

* ``Event.wait()`` blocks until that launch (and, by in-order dataflow,
  everything it depends on) completed;
* ``CommandQueue.finish()`` drains the whole queue (``clFinish``);
* ``CommandQueue(..., blocking=True)`` restores the old eager-sync behaviour
  (one host↔device round-trip per launch) for A/B benchmarking.

Execution model — CommandGraph fused dispatch (paper §IV-B)
-----------------------------------------------------------

The paper's TinyBio pipeline chains kernels whose intermediates stay
*resident* in the unified memory; the scheduling cost is paid per launch,
not per byte.  The TPU analogue is whole-chain fusion: ``queue.capture()``
records every ``enqueue_nd_range`` issued inside the ``with`` block —
without executing it (output shapes come from ``jax.eval_shape``) — into a
:class:`CommandGraph`.  ``graph.launch(*inputs)`` then replays the entire
chain as **one** jitted XLA computation: intermediates never materialize as
separate dispatches, XLA reuses their buffers, and optional
``donate_argnums`` donation extends that reuse to the graph's external
inputs.  Dispatch cost is paid once per graph instead of once per kernel.
Per-stage machine-model accounting is preserved: each captured node is
costed from its recorded ``WorkCounts`` at capture time (the captured
schedule), not from wall clock.

Kernels are executed functionally (outputs are fresh buffers); this is the
one semantic departure from OpenCL's in-place buffer writes and is what makes
every kernel jit/grad/vmap-compatible.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .device import EGPUConfig, EGPU_16T, HOST
from .machine import (PhaseBreakdown, WorkCounts, egpu_time, fuse_breakdowns,
                      host_time)
from .ndrange import NDRange
from .power import egpu_energy_j, host_energy_j


class Buffer:
    """A unified-memory buffer (CL_MEM-style flags kept for API fidelity)."""

    def __init__(self, data: jax.Array, flags: str = "rw"):
        self.data = jnp.asarray(data)
        self.flags = flags

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def read(self) -> jax.Array:
        """clEnqueueReadBuffer — a no-op copy under unified memory."""
        return self.data


class GraphBuffer(Buffer):
    """A symbolic buffer produced while capturing a :class:`CommandGraph`.

    Carries only a ``jax.ShapeDtypeStruct`` (shape/dtype/size all work); the
    concrete value exists only inside the fused computation at launch time.
    """

    def __init__(self, aval: jax.ShapeDtypeStruct, slot: int):
        self.data = aval          # duck-types shape/dtype/size for wiring code
        self.flags = "rw"
        self.slot = slot

    def read(self) -> jax.Array:
        raise RuntimeError(
            "GraphBuffer holds no data during capture; launch the graph and "
            "read its outputs instead.")


@dataclasses.dataclass(frozen=True)
class Kernel:
    """An OpenCL kernel: executor + structural work counts.

    ``executor(*arrays, **params) -> array | tuple[array]`` must be pure.
    ``counts(**params) -> WorkCounts`` derives the machine-model inputs from
    the problem size (shapes are passed through ``params`` by the caller).
    ``jitted=True`` marks executors that are already ``jax.jit``-wrapped
    (the ``repro.kernels.*.ops`` wrappers): the queue dispatches them
    directly instead of stacking a second jit on top.
    """

    name: str
    executor: Callable[..., Any]
    counts: Optional[Callable[..., WorkCounts]] = None
    jitted: bool = False


class Event:
    """Kernel-completion event: functional results + modeled time/energy.

    ``dispatch_s`` is the host-side time to *enqueue* the launch (the queue
    is asynchronous, so this excludes device compute); ``wait()`` blocks
    until the results are realized.  ``wall_s`` is kept as an alias of
    ``dispatch_s`` for older call sites.

    Events are reference-counted like ``cl_event`` (clRetainEvent /
    clReleaseEvent): :meth:`release` drops the event's hold on its output
    buffers once the count reaches zero, so a long-lived queue can return
    completed launches to O(in-flight) memory (see
    :meth:`CommandQueue.release_events`).  Modeled cost metadata survives
    release — only the (potentially large) functional outputs are dropped.
    """

    def __init__(self, kernel: Kernel, outputs: Tuple[Buffer, ...],
                 modeled: Optional[PhaseBreakdown], energy_j: Optional[float],
                 dispatch_s: float):
        self.kernel = kernel
        self.outputs = outputs
        self.modeled = modeled
        self.energy_j = energy_j
        self.dispatch_s = dispatch_s
        self._done = False
        self._refcount = 1

    @property
    def wall_s(self) -> float:
        return self.dispatch_s

    @property
    def done(self) -> bool:
        return self._done

    @property
    def released(self) -> bool:
        return self._refcount <= 0

    def retain(self) -> "Event":
        """clRetainEvent: keep output buffers alive across a queue release."""
        if self._refcount <= 0:
            raise RuntimeError("cannot retain a released Event")
        self._refcount += 1
        return self

    def release(self) -> None:
        """clReleaseEvent: drop one reference; at zero, free the outputs.

        Idempotent once released.  The modeled breakdown / energy stay
        readable (they are O(1)); only the buffer references are dropped.
        """
        if self._refcount <= 0:
            return
        self._refcount -= 1
        if self._refcount == 0:
            self.outputs = ()

    def wait(self) -> Tuple[Buffer, ...]:
        for b in self.outputs:
            if isinstance(b.data, jax.Array):
                b.data.block_until_ready()
        self._done = True
        return self.outputs


def _static_signature(params: Dict[str, Any]) -> Tuple[str, ...]:
    """Param names that must be jit-static (everything that isn't an array)."""
    return tuple(sorted(
        k for k, v in params.items()
        if not isinstance(v, (jax.Array, jnp.ndarray))))


class CommandQueue:
    """An in-order command queue bound to one device.

    ``blocking=False`` (default) gives asynchronous OpenCL semantics: enqueue
    returns immediately and only ``Event.wait()`` / :meth:`finish`
    synchronize.  ``blocking=True`` restores eager-sync dispatch (one device
    round-trip per kernel) for overhead A/B comparisons.

    Event lifecycle (serving workloads): an unprofiled queue auto-releases
    its events on :meth:`finish` — nobody can need them for accounting, so
    the queue stays O(in-flight) memory on a long-lived server.  A profiled
    queue keeps every event by default (full Fig-3/4 history); pass
    ``max_events=N`` for a *bounded profiling window*: only the newest N
    drained events are retained, older ones are released with their modeled
    time/energy folded into the queue's running totals, so
    :meth:`total_modeled_s` / :meth:`total_energy_j` stay exact regardless
    of the window.
    """

    def __init__(self, ctx: Context, profile: bool = True,
                 blocking: bool = False, max_events: Optional[int] = None):
        if max_events is not None and max_events < 0:
            raise ValueError("max_events must be None or >= 0")
        self.ctx = ctx
        self.profile = profile
        self.blocking = blocking
        self.max_events = max_events
        self._events: List[Event] = []
        self._drained = 0              # finish() watermark: events before
                                       # this index are already waited
        # Running totals of *released* events, so dropping an event from the
        # retained window never changes the queue's modeled accounting.
        self._released_count = 0
        self._released_modeled_s = 0.0
        self._released_energy_j = 0.0
        # Keyed on (kernel, static-arg signature): the same kernel enqueued
        # with a different static/traced split gets its own jit wrapper
        # instead of silently reusing the first call's (see ISSUE 1).
        self._jit_cache: Dict[Tuple[Kernel, Tuple[str, ...]], Callable] = {}
        self._capture: Optional[CommandGraph] = None

    # -- jit plumbing ------------------------------------------------------
    def _executor_for(self, kernel: Kernel, params: Dict[str, Any]) -> Callable:
        if kernel.jitted:
            return kernel.executor
        statics = _static_signature(params)
        key = (kernel, statics)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = jax.jit(kernel.executor, static_argnames=statics)
            self._jit_cache[key] = fn
        return fn

    def _model(self, kernel: Kernel, ndr: NDRange,
               counts_params: Dict[str, Any], resident: bool
               ) -> Tuple[Optional[PhaseBreakdown], Optional[float]]:
        if not self.profile or kernel.counts is None:
            return None, None
        counts = kernel.counts(**counts_params)
        if resident:
            counts = dataclasses.replace(counts, host_bytes=0.0)
        cfg = self.ctx.device.config
        if self.ctx.device.is_host:
            modeled = host_time(counts, cfg)
            return modeled, host_energy_j(modeled)
        modeled = egpu_time(cfg, counts, ndr)
        return modeled, egpu_energy_j(cfg, modeled)

    # -- the OpenCL-subset entry point -------------------------------------
    def enqueue_nd_range(self, kernel: Kernel, ndr: NDRange,
                         args: Sequence[Buffer],
                         params: Optional[Dict[str, Any]] = None,
                         counts_params: Optional[Dict[str, Any]] = None,
                         _resident: bool = False) -> Event:
        """Launch ``kernel`` over ``ndr`` with buffer ``args`` (non-blocking).

        ``params`` are executor kwargs (the paper's kernel-args region);
        ``counts_params`` are the problem sizes handed to the kernel's
        ``counts()`` for the machine model (defaults to ``params``).
        ``_resident=True`` marks a stage whose inputs are already resident
        in the unified memory / D$ (paper §IV-B pipeline chaining): the
        modeled host<->D$ transfer is waived for it.

        Inside a :meth:`capture` block the launch is recorded into the
        active :class:`CommandGraph` instead of executed; the returned
        event carries symbolic :class:`GraphBuffer` outputs.
        """
        params = params or {}
        cp = counts_params if counts_params is not None else params
        if self._capture is not None:
            return self._capture._record(kernel, ndr, args, params, cp,
                                         _resident)
        fn = self._executor_for(kernel, params)
        t0 = time.perf_counter()
        raw = fn(*[b.data for b in args], **params)
        if self.blocking:
            jax.block_until_ready(raw)
        dispatch = time.perf_counter() - t0
        outs = tuple(Buffer(r) for r in (raw if isinstance(raw, tuple) else (raw,)))

        modeled, energy = self._model(kernel, ndr, cp, _resident)
        ev = Event(kernel, outs, modeled, energy, dispatch)
        if self.blocking:
            ev._done = True
        self._events.append(ev)
        return ev

    # -- graph capture ------------------------------------------------------
    def capture(self) -> "CommandGraph":
        """Record subsequent enqueues into a :class:`CommandGraph`.

        Use as a context manager::

            with q.capture() as graph:
                q.enqueue_nd_range(k1, ndr, (a, b))   # recorded, not run
                ...
            outs = graph.launch()                      # one fused dispatch

        Launches inside the block are traced abstractly (``jax.eval_shape``)
        so capture itself never touches the device.
        """
        return CommandGraph(self)

    def flush(self) -> None:
        """clFlush — dispatch is eager under JAX, so this is a no-op."""

    def finish(self) -> None:
        """Block until every enqueued kernel completed (clFinish).

        Only events enqueued since the last ``finish()`` are waited (a
        drained-watermark: repeated drains on a long-lived queue stay O(new
        work), not O(full history)).  On an unprofiled queue the drained
        events are then released outright; with ``max_events`` set, the
        retained history is trimmed to the window (oldest first)."""
        for ev in self._events[self._drained:]:
            ev.wait()
        self._drained = len(self._events)
        if not self.profile:
            self.release_events()
        elif (self.max_events is not None
              and len(self._events) > self.max_events):
            self.release_events(upto=len(self._events) - self.max_events)

    def drain(self, n: int) -> None:
        """Wait the oldest ``n`` retained events (a *partial* clFinish).

        Lets a serving layer retire one launch's event segment without
        synchronizing launches enqueued after it — pair with
        ``release_events(upto=n)`` to drop exactly that segment."""
        n = min(n, len(self._events))
        for ev in self._events[:n]:
            ev.wait()
        self._drained = max(self._drained, n)

    def release_events(self, upto: Optional[int] = None) -> int:
        """Release and drop the oldest ``upto`` events (clReleaseEvent sweep).

        Only *drained* events are eligible — an event :meth:`finish` has not
        waited yet may still be in flight.  Each dropped event's modeled
        time/energy is folded into the queue's running totals first, so
        :meth:`total_modeled_s` / :meth:`total_energy_j` are unaffected.
        ``Event.retain()``-ed events are still dropped from the queue's
        history, but keep their output buffers alive for the holder.
        Returns the number of events released.
        """
        upto = self._drained if upto is None else min(upto, self._drained)
        if upto <= 0:
            return 0
        for ev in self._events[:upto]:
            if ev.modeled is not None:
                self._released_modeled_s += ev.modeled.total_s
            if ev.energy_j is not None:
                self._released_energy_j += ev.energy_j
            self._released_count += 1
            ev.release()
        del self._events[:upto]
        self._drained -= upto
        return upto

    @property
    def events(self) -> Tuple[Event, ...]:
        """Retained (not yet released) events, oldest first."""
        return tuple(self._events)

    @property
    def released_count(self) -> int:
        """Events released from this queue's history so far."""
        return self._released_count

    def total_modeled_s(self) -> float:
        # `is not None`, not truthiness: an all-zero PhaseBreakdown (e.g. a
        # fully resident stage) must still be counted.  Released events are
        # accounted via the running totals.
        return self._released_modeled_s + sum(
            e.modeled.total_s for e in self._events if e.modeled is not None)

    def total_energy_j(self) -> float:
        return self._released_energy_j + sum(
            e.energy_j for e in self._events if e.energy_j is not None)


@dataclasses.dataclass
class GraphNode:
    """One captured launch: kernel + wiring + capture-time machine model."""

    kernel: Kernel
    call: Callable[..., Any]            # executor with params pre-bound
    in_slots: Tuple[int, ...]
    out_slots: Tuple[int, ...]
    out_avals: Tuple[jax.ShapeDtypeStruct, ...]
    modeled: Optional[PhaseBreakdown]
    energy_j: Optional[float]
    n_items: int = 0                    # first input's element count (the
                                        # NDRange sizing the eager path uses)


class CommandGraph:
    """A captured kernel chain, launched as one fused XLA computation.

    Built by :meth:`CommandQueue.capture`.  While capturing, every
    ``enqueue_nd_range`` appends a :class:`GraphNode`: inputs are resolved to
    *slots* — either graph-external buffers (concrete data seen during
    capture) or earlier nodes' outputs — and output shapes come from
    ``jax.eval_shape``, so nothing executes.  :meth:`launch` replays all
    nodes inside a single ``jax.jit``; the graph's outputs are the final
    node's outputs.

    Per-node ``modeled`` / ``energy_j`` come from the captured schedule
    (``WorkCounts`` at capture time), giving the same per-stage Fig-3/Fig-4
    accounting as eager dispatch while the wall-clock path is fused.
    """

    def __init__(self, queue: CommandQueue):
        self.queue = queue
        self.nodes: List[GraphNode] = []
        self._n_slots = 0
        self._ext_slots: List[int] = []        # slot index of each external
        self._ext_values: List[jax.Array] = [] # captured concrete externals
        self._ext_avals: List[jax.ShapeDtypeStruct] = []
        self._buf_slot: Dict[int, int] = {}    # id(Buffer) -> slot
        self._bufs_alive: List[Buffer] = []    # keep ids stable during capture
        self._jit_cache: Dict[Tuple[Any, ...], Callable] = {}
        self._sealed = False
        self._fused_memo: Optional[Tuple[Optional[PhaseBreakdown], float]] = None

    # -- capture ------------------------------------------------------------
    def __enter__(self) -> "CommandGraph":
        if self.queue._capture is not None:
            raise RuntimeError("CommandQueue is already capturing")
        self.queue._capture = self
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.queue._capture = None
        # Only a capture body that completed cleanly yields a launchable
        # graph; an exception mid-capture leaves a truncated chain.
        self._sealed = exc_type is None

    def _slot_of(self, buf: Buffer) -> int:
        slot = self._buf_slot.get(id(buf))
        if slot is None:
            if isinstance(buf, GraphBuffer):
                raise RuntimeError(
                    "GraphBuffer from a different capture passed as input")
            slot = self._new_slot()
            self._buf_slot[id(buf)] = slot
            self._bufs_alive.append(buf)
            self._ext_slots.append(slot)
            self._ext_values.append(buf.data)
            self._ext_avals.append(
                jax.ShapeDtypeStruct(buf.data.shape, buf.data.dtype))
        return slot

    def _new_slot(self) -> int:
        s = self._n_slots
        self._n_slots += 1
        return s

    def _record(self, kernel: Kernel, ndr: NDRange, args: Sequence[Buffer],
                params: Dict[str, Any], counts_params: Dict[str, Any],
                resident: bool) -> Event:
        in_slots = tuple(self._slot_of(b) for b in args)
        in_avals = tuple(
            jax.ShapeDtypeStruct(b.data.shape, b.data.dtype) for b in args)

        def call(*arrays, _exe=kernel.executor, _params=dict(params)):
            out = _exe(*arrays, **_params)
            return out if isinstance(out, tuple) else (out,)

        out_avals = tuple(jax.eval_shape(call, *in_avals))
        out_slots = tuple(self._new_slot() for _ in out_avals)
        modeled, energy = self.queue._model(kernel, ndr, counts_params,
                                            resident)
        self.nodes.append(GraphNode(kernel, call, in_slots, out_slots,
                                    out_avals, modeled, energy,
                                    n_items=int(args[0].data.size)
                                    if args else 0))
        outs = tuple(GraphBuffer(a, s) for a, s in zip(out_avals, out_slots))
        for b in outs:
            self._buf_slot[id(b)] = b.slot
            self._bufs_alive.append(b)
        return Event(kernel, outs, modeled, energy, 0.0)

    # -- accounting ---------------------------------------------------------
    @property
    def n_external(self) -> int:
        return len(self._ext_slots)

    @property
    def ext_avals(self) -> Tuple[jax.ShapeDtypeStruct, ...]:
        """Shape/dtype of each external input, in capture order."""
        return tuple(self._ext_avals)

    def modeled_breakdowns(self) -> Tuple[Optional[PhaseBreakdown], ...]:
        return tuple(n.modeled for n in self.nodes)

    def total_modeled_s(self) -> float:
        return sum(n.modeled.total_s for n in self.nodes
                   if n.modeled is not None)

    def total_energy_j(self) -> float:
        return sum(n.energy_j for n in self.nodes if n.energy_j is not None)

    def fused_modeled(self) -> Tuple[Optional[PhaseBreakdown], float]:
        """(fused breakdown, total energy) of the captured chain, memoized.

        Both come from capture time and never change across launches — the
        serving hot path reads them once per launch, so re-walking the node
        list every time would be pure waste.  The breakdown is ``None`` when
        no node carries a machine model.
        """
        if self._fused_memo is None:
            mods = [m for m in self.modeled_breakdowns() if m is not None]
            self._fused_memo = (fuse_breakdowns(mods) if mods else None,
                                self.total_energy_j())
        return self._fused_memo

    # -- launch -------------------------------------------------------------
    def _fused(self, donate: Tuple[int, ...]) -> Callable:
        key = donate
        fn = self._jit_cache.get(key)
        if fn is not None:
            return fn

        nodes = tuple(self.nodes)
        ext_slots = tuple(self._ext_slots)
        out_slots = nodes[-1].out_slots
        n_slots = self._n_slots

        def run(*ext):
            vals: List[Any] = [None] * n_slots
            for slot, v in zip(ext_slots, ext):
                vals[slot] = v
            for node in nodes:
                outs = node.call(*[vals[s] for s in node.in_slots])
                for slot, o in zip(node.out_slots, outs):
                    vals[slot] = o
            return tuple(vals[s] for s in out_slots)

        fn = jax.jit(run, donate_argnums=donate)
        self._jit_cache[key] = fn
        return fn

    def launch(self, *inputs: Any, donate: Sequence[int] = (),
               queue_events: bool = True) -> Tuple[Buffer, ...]:
        """Execute the captured chain as one fused dispatch (non-blocking).

        ``inputs`` replace the graph's external buffers in capture order
        (shapes/dtypes must match); with no inputs the arrays captured at
        record time are reused.  ``donate`` lists external-input positions
        whose device buffers XLA may reuse for the computation (jit
        ``donate_argnums``); never pass an index whose buffer the caller
        still needs.  Backends without donation support (CPU) silently
        ignore it.  Returns the final node's outputs as fresh buffers;
        per-node modeled events are appended to the owning queue so
        ``finish()`` / modeled totals keep working.
        """
        if self.queue._capture is self:
            raise RuntimeError("cannot launch while still capturing")
        if not self._sealed:
            raise RuntimeError(
                "capture did not complete cleanly; re-capture the chain "
                "before launching")
        if not self.nodes:
            raise RuntimeError("cannot launch an empty CommandGraph")
        if donate and not inputs:
            # Donating the graph's own captured arrays would poison every
            # later zero-argument launch on backends that honor donation.
            raise ValueError(
                "donate requires explicit launch inputs: the captured "
                "external arrays must stay valid for later launches")
        ext = list(inputs) if inputs else list(self._ext_values)
        if len(ext) != len(self._ext_slots):
            raise ValueError(
                f"graph takes {len(self._ext_slots)} external inputs, "
                f"got {len(ext)}")
        ext = [jnp.asarray(x) for x in ext]
        # Shape/dtype must match the capture: a silent retrace would attach
        # capture-time modeled costs to a differently-sized computation.
        for i, (x, aval) in enumerate(zip(ext, self._ext_avals)):
            if x.shape != aval.shape or x.dtype != aval.dtype:
                raise ValueError(
                    f"launch input {i} is {x.shape}/{x.dtype}, but the graph "
                    f"was captured with {aval.shape}/{aval.dtype}; re-capture "
                    "for a different problem size")
        fn = self._fused(tuple(sorted(int(i) for i in donate)))
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # CPU backends warn that donated buffers were unused; donation
            # is best-effort there by design.
            warnings.filterwarnings(
                "ignore", message=".*donated.*", category=UserWarning)
            raw = fn(*ext)
        dispatch = time.perf_counter() - t0
        outs = tuple(Buffer(r) for r in raw)
        if queue_events:
            for i, node in enumerate(self.nodes):
                node_outs = outs if i == len(self.nodes) - 1 else ()
                per_node = dispatch if i == 0 else 0.0
                self.queue._events.append(Event(
                    node.kernel, node_outs, node.modeled, node.energy_j,
                    per_node))
        return outs

    def launch_prefix(self, inputs: Sequence[Any],
                      **launch_kwargs: Any) -> Tuple[Buffer, ...]:
        """Launch with only the first ``len(inputs)`` externals replaced.

        The remaining externals keep the arrays captured at record time —
        for a pipeline graph these are the per-stage constant buffers
        (weights, coefficients), so a serving layer can feed fresh request
        data without re-threading the pipeline's parameters (this is the
        entry point ``repro.serve.GraphCache`` launches through).
        """
        inputs = list(inputs)
        if len(inputs) > len(self._ext_values):
            raise ValueError(
                f"launch_prefix got {len(inputs)} inputs but the graph has "
                f"only {len(self._ext_values)} externals")
        donate = launch_kwargs.get("donate", ())
        if any(int(i) >= len(inputs) for i in donate):
            # Positions beyond the replaced prefix are filled from the
            # graph's own captured arrays — donating one would consume a
            # buffer every later launch still needs (same hazard the
            # donate-without-inputs guard in launch() exists for).
            raise ValueError(
                "launch_prefix can only donate caller-supplied positions "
                f"(< {len(inputs)}); the rest are captured externals")
        return self.launch(*inputs, *self._ext_values[len(inputs):],
                           **launch_kwargs)


class Device:
    """One compute device: an e-GPU instance or the scalar host baseline."""

    def __init__(self, config: EGPUConfig = EGPU_16T):
        self.config = config

    @property
    def is_host(self) -> bool:
        return self.config.name == HOST.name


class Context:
    def __init__(self, device: Device):
        self.device = device

    def create_buffer(self, data, flags: str = "rw") -> Buffer:
        return Buffer(jnp.asarray(data), flags)
