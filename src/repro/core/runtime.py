"""TinyCL — the host-side Tiny-OpenCL runtime (paper §V / §VI-C), in JAX.

The paper's runtime is a subset of the OpenCL host API that works without an
OS, file system, or multithreading: create buffers, set kernel args, enqueue
an NDRange, wait for the completion interrupt.  We reproduce that API shape
with JAX semantics:

* a :class:`Buffer` wraps a ``jax.Array`` living in the *unified* memory
  (host HBM == device global memory, exactly the paper's §IV-B model);
* a :class:`Kernel` couples an executor (a pure JAX callable — either the
  pure-jnp reference or the Pallas TPU implementation) with a ``counts``
  function that derives the structural :class:`~repro.core.machine.WorkCounts`
  for the analytic machine model;
* ``CommandQueue.enqueue_nd_range`` jit-executes the kernel and returns an
  :class:`Event` carrying both the functional results and the modeled
  :class:`~repro.core.machine.PhaseBreakdown` / energy for the queue's device
  configuration — the numbers behind Figs 3 & 4;
* events chain: kernels consuming a prior event's outputs execute after it
  (JAX dataflow gives this for free, matching in-order OpenCL queues).

Kernels are executed functionally (outputs are fresh buffers); this is the
one semantic departure from OpenCL's in-place buffer writes and is what makes
every kernel jit/grad/vmap-compatible.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .device import EGPUConfig, EGPU_16T, HOST
from .machine import PhaseBreakdown, WorkCounts, egpu_time, host_time
from .ndrange import NDRange
from .power import egpu_energy_j, host_energy_j


class Buffer:
    """A unified-memory buffer (CL_MEM-style flags kept for API fidelity)."""

    def __init__(self, data: jax.Array, flags: str = "rw"):
        self.data = jnp.asarray(data)
        self.flags = flags

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def read(self) -> jax.Array:
        """clEnqueueReadBuffer — a no-op copy under unified memory."""
        return self.data


@dataclasses.dataclass(frozen=True)
class Kernel:
    """An OpenCL kernel: executor + structural work counts.

    ``executor(*arrays, **params) -> array | tuple[array]`` must be pure.
    ``counts(**params) -> WorkCounts`` derives the machine-model inputs from
    the problem size (shapes are passed through ``params`` by the caller).
    """

    name: str
    executor: Callable[..., Any]
    counts: Optional[Callable[..., WorkCounts]] = None


class Event:
    """Kernel-completion event: functional results + modeled time/energy."""

    def __init__(self, kernel: Kernel, outputs: Tuple[Buffer, ...],
                 modeled: Optional[PhaseBreakdown], energy_j: Optional[float],
                 wall_s: float):
        self.kernel = kernel
        self.outputs = outputs
        self.modeled = modeled
        self.energy_j = energy_j
        self.wall_s = wall_s

    def wait(self) -> Tuple[Buffer, ...]:
        for b in self.outputs:
            b.data.block_until_ready()
        return self.outputs


class Device:
    """One compute device: an e-GPU instance or the scalar host baseline."""

    def __init__(self, config: EGPUConfig = EGPU_16T):
        self.config = config

    @property
    def is_host(self) -> bool:
        return self.config.name == HOST.name


class Context:
    def __init__(self, device: Device):
        self.device = device

    def create_buffer(self, data, flags: str = "rw") -> Buffer:
        return Buffer(jnp.asarray(data), flags)


class CommandQueue:
    """An in-order command queue bound to one device."""

    def __init__(self, ctx: Context, profile: bool = True):
        self.ctx = ctx
        self.profile = profile
        self._events: list[Event] = []
        self._jit_cache: Dict[str, Callable] = {}

    # -- the OpenCL-subset entry point -------------------------------------
    def enqueue_nd_range(self, kernel: Kernel, ndr: NDRange,
                         args: Sequence[Buffer],
                         params: Optional[Dict[str, Any]] = None,
                         counts_params: Optional[Dict[str, Any]] = None,
                         _resident: bool = False) -> Event:
        """Launch ``kernel`` over ``ndr`` with buffer ``args``.

        ``params`` are executor kwargs (the paper's kernel-args region);
        ``counts_params`` are the problem sizes handed to the kernel's
        ``counts()`` for the machine model (defaults to ``params``).
        ``_resident=True`` marks a stage whose inputs are already resident
        in the unified memory / D$ (paper §IV-B pipeline chaining): the
        modeled host<->D$ transfer is waived for it.
        """
        params = params or {}
        fn = self._jit_cache.get(kernel.name)
        if fn is None:
            fn = jax.jit(kernel.executor, static_argnames=tuple(
                k for k, v in params.items() if not isinstance(v, (jax.Array, jnp.ndarray))))
            self._jit_cache[kernel.name] = fn
        t0 = time.perf_counter()
        raw = fn(*[b.data for b in args], **params)
        jax.block_until_ready(raw)
        wall = time.perf_counter() - t0
        outs = tuple(Buffer(r) for r in (raw if isinstance(raw, tuple) else (raw,)))

        modeled = energy = None
        if self.profile and kernel.counts is not None:
            counts = kernel.counts(**(counts_params if counts_params
                                      is not None else params))
            if _resident:
                counts = dataclasses.replace(counts, host_bytes=0.0)
            cfg = self.ctx.device.config
            if self.ctx.device.is_host:
                modeled = host_time(counts, cfg)
                energy = host_energy_j(modeled)
            else:
                modeled = egpu_time(cfg, counts, ndr)
                energy = egpu_energy_j(cfg, modeled)
        ev = Event(kernel, outs, modeled, energy, wall)
        self._events.append(ev)
        return ev

    def finish(self) -> None:
        """Block until every enqueued kernel completed (clFinish)."""
        for ev in self._events:
            ev.wait()

    @property
    def events(self) -> Tuple[Event, ...]:
        return tuple(self._events)

    def total_modeled_s(self) -> float:
        return sum(e.modeled.total_s for e in self._events if e.modeled)

    def total_energy_j(self) -> float:
        return sum(e.energy_j for e in self._events if e.energy_j)
