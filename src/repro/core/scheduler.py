"""Tiny-OpenCL scheduling model (paper §V-B, §VIII-B).

The paper's runtime executes a kernel in three phases:

1. **startup**  — each CU in single-thread mode: activate threads/warps, set
   up per-thread stacks;
2. **scheduling** — read global/local sizes from the kernel-args region,
   combine with CSR-reported hardware resources, and iterate work-items onto
   (CU × warp × thread) slots;
3. **processing** — the user kernel runs.

§VIII-B reports scheduling time is ~25 µs and *constant* when the number of
work-items equals the number of hardware threads, growing with the number of
scheduling iterations (= ceil(work_items / total_threads)); startup is part of
the same fixed cost.  We model exactly that and calibrate the constants to the
paper's 300 MHz numbers.

This model is what `benchmarks/bench_gemm_overhead.py` uses to reproduce
Fig. 3, and `core/runtime.py` attaches it to every launched kernel's Event.
"""

from __future__ import annotations

import dataclasses
import math

from .device import EGPUConfig
from .ndrange import NDRange

# Calibration (cycles @ 300 MHz).  25 us = 7500 cycles for one scheduling
# iteration (paper: work-items == total threads -> constant ~25 us).
STARTUP_CYCLES_BASE = 2200       # single-thread init: stacks, warp activation
STARTUP_CYCLES_PER_WARP = 120    # per (warp x CU) resource activation
SCHED_CYCLES_BASE = 2540         # read kernel args region + CSRs, set-up loop
SCHED_CYCLES_PER_ITER = 1800     # one pass distributing items over all slots


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Static schedule of an NDRange onto an e-GPU configuration."""

    ndrange: NDRange
    config: EGPUConfig
    # derived
    iterations: int            # scheduling passes over the thread slots
    groups_per_cu: int         # work-groups each CU executes (ceil)
    occupancy: float           # fraction of thread slots doing real work

    @property
    def startup_cycles(self) -> int:
        c = self.config
        return STARTUP_CYCLES_BASE + STARTUP_CYCLES_PER_WARP * c.warps_per_cu * c.compute_units

    @property
    def scheduling_cycles(self) -> int:
        return SCHED_CYCLES_BASE + SCHED_CYCLES_PER_ITER * self.iterations

    @property
    def overhead_cycles(self) -> int:
        return self.startup_cycles + self.scheduling_cycles

    @property
    def overhead_s(self) -> float:
        return self.overhead_cycles * self.config.cycle_s


def schedule(ndr: NDRange, config: EGPUConfig) -> Schedule:
    """Distribute ``ndr``'s work-items over ``config``'s thread slots.

    Mirrors the paper's scheduler: work-groups go to CUs round-robin; within a
    CU, work-items pack onto (warp x thread) slots; leftover slots are
    deactivated (for power). ``iterations`` counts how many times the
    scheduling loop must refill the slots.
    """
    total_items = ndr.total_work_items
    slots = config.total_threads
    iterations = max(1, math.ceil(total_items / slots))
    groups_per_cu = max(1, math.ceil(ndr.total_groups / config.compute_units))
    # Occupancy of the last iteration's slots; earlier iterations are full.
    tail = total_items - (iterations - 1) * slots
    occupancy = (min(total_items, slots) if iterations == 1 else
                 (slots * (iterations - 1) + tail) / iterations) / slots
    return Schedule(ndrange=ndr, config=config, iterations=iterations,
                    groups_per_cu=groups_per_cu, occupancy=min(1.0, occupancy))


def optimal_ndrange(total_items_hint: int, config: EGPUConfig) -> NDRange:
    """The paper's §VIII-B trick: pick work-items == hardware threads so the
    scheduling cost is a single constant iteration; each work-item then loops
    over ``ceil(total/slots)`` elements internally."""
    slots = config.total_threads
    return NDRange(global_size=(slots,), local_size=(config.threads_per_cu,))
