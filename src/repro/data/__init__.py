"""repro.data — deterministic synthetic sharded token pipeline."""

from .pipeline import DataConfig, SyntheticLMData, make_batch_struct

__all__ = ["DataConfig", "SyntheticLMData", "make_batch_struct"]
