"""Deterministic synthetic data pipeline, sharded and replayable.

Fault-tolerance contract: batches are a pure function of (seed, step), so a
restart from step k reproduces the exact stream without any pipeline
checkpoint — the data-side half of exact-replay recovery.  Each host
materializes only its addressable shard (``local_batch``) in a real
multi-host launch; in this single-process environment the global batch is
placed under the mesh sharding directly.

The synthetic LM stream is a structured Markov-ish sequence (token t+1
depends on token t and a per-sequence key) rather than iid noise, so a ~100M
model trained for a few hundred steps shows a cleanly decreasing loss
(examples/train_lm.py) — iid tokens would pin the loss at log(V).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.frontends import feature_dim


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0


def _mix(state: np.ndarray, key: np.ndarray, vocab: int) -> np.ndarray:
    """Cheap integer hash step: next = h(cur, key) mod vocab."""
    x = (state.astype(np.uint64) * np.uint64(6364136223846793005)
         + key.astype(np.uint64) + np.uint64(1442695040888963407))
    x ^= x >> np.uint64(33)
    return (x % np.uint64(vocab)).astype(np.int32)


class SyntheticLMData:
    """Iterable of {"tokens", "labels"} with exact (seed, step) replay."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(np.uint64(c.seed) * np.uint64(1_000_003)
                                    + np.uint64(step))
        # ONE successor key per dataset (seed), shared by all sequences and
        # steps: the (token -> successor) table is globally learnable (a
        # noisy bigram LM), so short training runs show real loss movement
        key = np.full((c.global_batch, 1),
                      (c.seed * 2_654_435_761 + 97) % (2**31), np.int64)
        toks = np.empty((c.global_batch, c.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, c.vocab, size=c.global_batch)
        # structured stream: 75% deterministic successor, 25% resample
        noise = rng.random((c.global_batch, c.seq_len)) < 0.25
        fresh = rng.integers(0, c.vocab, size=(c.global_batch, c.seq_len),
                             dtype=np.int64)
        for t in range(c.seq_len):
            nxt = _mix(toks[:, t], key[:, 0], c.vocab)
            toks[:, t + 1] = np.where(noise[:, t], fresh[:, t], nxt)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        mc = self.model_cfg
        if mc is not None and mc.frontend == "vision":
            batch["patches"] = rng.standard_normal(
                (c.global_batch, mc.n_prefix_embed, feature_dim(mc)),
                dtype=np.float32)
        if mc is not None and mc.frontend == "audio":
            batch.pop("tokens")
            batch["frames"] = rng.standard_normal(
                (c.global_batch, c.seq_len, feature_dim(mc)),
                dtype=np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_struct(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract version of one batch (for AOT lowering)."""
    b, s = cfg.global_batch, cfg.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    mc = model_cfg
    if mc is not None and mc.frontend == "vision":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, mc.n_prefix_embed, feature_dim(mc)), jnp.float32)
    if mc is not None and mc.frontend == "audio":
        out.pop("tokens")
        out["frames"] = jax.ShapeDtypeStruct((b, s, feature_dim(mc)),
                                             jnp.float32)
    return out
