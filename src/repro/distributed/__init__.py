"""repro.distributed — sharding rules, activation constraints, collectives.

The e-GPU paper's Tiny-OpenCL scheduler distributes work-groups over compute
units; at datacenter scale the same role is played by GSPMD sharding over a
device mesh.  This package is the "scheduler" of the scaled-up system:

* :mod:`.sharding` — logical-axis → mesh-axis rules (DP/FSDP/TP/EP/SP),
  activation sharding constraints, parameter PartitionSpec derivation with
  divisibility fallback;
* :mod:`.compression` — int8 gradient compression with error feedback,
  wrapped around the DP reduction;
* :mod:`.elastic` — cross-mesh resharding used by checkpoint restore when
  the device count changed (elastic scaling / failure recovery).
"""

from .sharding import (ShardingRules, TRAIN_RULES, TRAIN_FSDP_RULES,
                       SERVE_RULES, activate, active_rules, constrain,
                       param_specs, batch_spec, spec_for, train_rules_for)
from .compression import compress_int8, decompress_int8, compressed_psum
from .elastic import reshard_arrays

__all__ = [
    "ShardingRules", "TRAIN_RULES", "TRAIN_FSDP_RULES", "SERVE_RULES",
    "activate", "active_rules", "constrain", "param_specs", "batch_spec",
    "spec_for", "train_rules_for",
    "compress_int8", "decompress_int8", "compressed_psum", "reshard_arrays",
]
