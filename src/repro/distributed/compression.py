"""Int8 gradient compression with error feedback, around the DP reduction.

At 1000+-node scale the cross-pod (DCI) gradient all-reduce is the scarcest
bandwidth in the system.  We compress gradients to int8 with per-tensor
scales before the reduction and decompress after, carrying the quantization
residual forward as *error feedback* (Seide et al.; 1-bit Adam lineage) so
the compression is unbiased over time and SGD convergence is preserved.

Two entry points:

* :func:`compress_int8` / :func:`decompress_int8` — the pure codec (+error
  state), used by the trainer around ``psum_scatter`` in shard_map form;
* :func:`compressed_psum` — a drop-in reduction for a gradient pytree inside
  ``shard_map``: quantize → all-reduce int8 (4x fewer bytes on the wire) →
  dequantize, returning the new error state.

The codec is exact-shape-preserving and jit-friendly; tests verify the
error-feedback telescoping property (mean compressed-sum error → 0 over
steps) and byte counts.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def compress_int8(g: jax.Array, err: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize ``g + err`` to int8.  Returns (q, scale, new_err).

    scale is per-tensor (amax / 127); new_err is the quantization residual
    to be fed back into the next step's gradient.
    """
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax / INT8_MAX, 1e-20)
    q = jnp.clip(jnp.round(gf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(grads, errs, axis_name: str):
    """Mean-reduce a gradient pytree over ``axis_name`` on an int8 wire
    (inside ``shard_map``).

    Wire format: each participant quantizes (grad + error) to int8 with its
    own scale, ALL-GATHERS the int8 payload (+ fp32 scales), and sums the
    dequantized contributions locally.  For the cross-pod hop (N = 2 pods)
    this moves (N-1)·bytes_int8 per device vs 2·(N-1)/N·bytes_f32 for a
    ring all-reduce — a 4x wire reduction.  Per-participant scales keep the
    quantization unbiased per sender; error feedback carries each sender's
    residual to its next step (telescoping — tests/test_substrate.py).

    Returns (mean_grads fp32, new_errs).
    """
    def one(g, e):
        q, scale, new_e = compress_int8(g, e)
        all_q = jax.lax.all_gather(q, axis_name)            # (N, ...) int8
        all_s = jax.lax.all_gather(scale, axis_name)        # (N,) f32
        n = all_q.shape[0]
        shaped = all_s.reshape((n,) + (1,) * (all_q.ndim - 1))
        total = jnp.sum(all_q.astype(jnp.float32) * shaped, axis=0)
        return total / n, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = (jax.tree_util.tree_leaves(errs) if errs is not None
              else [None] * len(flat_g))
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, ne = one(g, e)
        out_g.append(rg)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_e))


def init_error_state(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
