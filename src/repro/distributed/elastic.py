"""Elastic resharding: move a sharded pytree between meshes of any size.

Failure recovery and elastic scaling both reduce to the same primitive: a
checkpoint written under mesh A (N devices) must restore under mesh B
(M devices, possibly different axis shapes).  Because checkpoints store
*global* shapes plus logical axes (see repro.checkpoint), restore just
rebuilds each global array under the new mesh's NamedSharding — device
placement is re-derived, not replayed.

:func:`reshard_arrays` is the in-memory variant (live mesh change without a
checkpoint round-trip): it pulls each array to host as a global view and
re-places it under the target sharding.  On a real multi-host system the
same call pattern works per-host on addressable shards via
``jax.make_array_from_single_device_arrays``; here (single process) the
fully-addressable path is exact and is what the elasticity tests exercise.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _to_global(x: jax.Array) -> np.ndarray:
    """Gather a (possibly sharded) jax.Array to a host ndarray."""
    return np.asarray(jax.device_get(x))


def reshard_arrays(tree: Any, shardings_tree: Any) -> Any:
    """Re-place every array in ``tree`` under the matching NamedSharding.

    Works across meshes (source sharding is irrelevant); shapes must match.
    """
    def one(x, sh):
        host = _to_global(x)
        return jax.device_put(host, sh)

    return jax.tree_util.tree_map(one, tree, shardings_tree)


def replicate(tree: Any, mesh: Mesh) -> Any:
    """Fully replicate a pytree over a mesh (small states, rng, schedules)."""
    sh = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)
