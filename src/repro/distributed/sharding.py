"""Sharding rules: logical parameter/activation axes → mesh axes.

This is the distribution analogue of the paper's Tiny-OpenCL scheduler: a
single declarative table decides where every tensor dimension lives, and the
models stay sharding-agnostic (they tag dimensions with *logical* names via
``ParamSpec.axes`` and call :func:`constrain` on activations).

Mesh layout (launch/mesh.py):

* single-pod: ``(data=16, model=16)`` — 256 chips (one v5e pod)
* multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips

Rules (train):

========  =================  =============================================
logical    mesh axes          meaning
========  =================  =============================================
embed      data               FSDP/ZeRO-3: weights sharded along d_model;
                              GSPMD all-gathers per scan step, reduce-
                              scatters grads (overlapped with compute)
mlp        model              Megatron TP (column/row parallel pairs)
heads      model              TP over the *flattened* q-heads dim (always
                              divisible: H*hd % 16 == 0 for all 10 archs)
kv         model              TP over the flattened kv dim
vocab      model              sharded embedding + logits matmul
expert     model              expert parallelism (EP): 160/64/16 experts
                              over 16 shards; tokens all-to-all in/out
layers     (never sharded)    the scan axis of stacked weights
batch      (pod, data)        activations: DP over pod x data
seq        model (SP mode)    sequence parallelism for long-context cells
========  =================  =============================================

Parameters are *not* sharded over ``pod``: within a pod FSDP gathers ride the
fast ICI; across pods only gradient all-reduces cross the DCI (hierarchical
reduction — GSPMD emits reduce-scatter in-pod + all-reduce across pods from
these specs automatically).

Divisibility fallback: any dim not divisible by its mesh axes falls back to
replication for that dim (checked against the actual mesh), so odd shapes
(e.g. minicpm's 36 heads) degrade gracefully instead of failing to lower.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def _is_spec(x) -> bool:
    # late import: models imports this module (avoid the cycle)
    from ..models.params import is_spec
    return is_spec(x)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → mesh-axis mapping for one execution regime."""

    name: str
    table: Dict[str, MeshAxes]
    seq_sharded: bool = False    # SP: shard activation seq dim over "model"

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        if logical == "seq" and not self.seq_sharded:
            return None
        return self.table.get(logical)

    def with_seq_sharding(self, on: bool = True) -> "ShardingRules":
        return dataclasses.replace(self, name=self.name + ("+sp" if on else ""),
                                   seq_sharded=on)


TRAIN_RULES = ShardingRules(
    name="train",
    table={
        "embed": "data",
        "mlp": "model",
        "heads": "model",
        "kv": "model",
        "kv_heads": "model",     # unflattened kv-head axis (falls back when
                                 # kv_heads < 16, e.g. GQA kv=8)
        "vocab": "model",
        "expert": "model",
        "layers": None,
        "batch": ("pod", "data"),
        "seq": "model",
        "kv_seq": "model",       # decode KV-cache sequence axis
    },
)

#: Small-model training (< ~20B): no tensor parallelism — Megatron TP
#: all-reduces two full activation tensors per layer per pass, which for a
#: 1.6B model at batch 256 is 30x the gradient bytes (measured: 197 GB/step
#: link traffic on stablelm train_4k under TRAIN_RULES vs 6.6 GB of grads —
#: EXPERIMENTS §Perf).  Instead: batch spans ("pod","data","model") (pure
#: DP, progressive fallback drops "model" when B doesn't divide), weights
#: ZeRO-3-shard over "data", and only vocab/expert tables keep "model".
TRAIN_FSDP_RULES = ShardingRules(
    name="train-fsdp",
    table={
        "embed": "data",
        "mlp": None,
        "heads": None,
        "kv": None,
        "kv_heads": None,
        "vocab": "model",
        "expert": "model",
        "layers": None,
        # ("data","model") first so the progressive fallback drops "pod"
        # (2x pod-replicated compute) rather than "model" (16x) when B=256
        # doesn't divide 512.
        "batch": ("data", "model", "pod"),
        "seq": None,
        "kv_seq": "model",
    },
)

#: Params above which training uses TP (TRAIN_RULES) instead of pure FSDP.
TP_PARAM_THRESHOLD = 2e10


def train_rules_for(param_count: int) -> ShardingRules:
    return (TRAIN_RULES if param_count >= TP_PARAM_THRESHOLD
            else TRAIN_FSDP_RULES)


#: Serving: no pod axis in the batch (requests stay in-pod); weights keep the
#: same 2-D (data x model) layout so big models fit; KV cache seq-sharded
#: over "model" (flash-decoding combine comes out of GSPMD's partial softmax
#: reductions).
SERVE_RULES = ShardingRules(
    name="serve",
    table={
        "embed": "data",
        "mlp": "model",
        "heads": "model",
        "kv": "model",
        "kv_heads": "model",
        "vocab": "model",
        "expert": "model",
        "layers": None,
        "batch": ("pod", "data"),
        "seq": "model",
        "kv_seq": "model",
    },
)


# ---------------------------------------------------------------------------
# Active-rules context (thread-local so tests stay single-device no-ops)
# ---------------------------------------------------------------------------
class _State(threading.local):
    rules: Optional[ShardingRules] = None
    mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def activate(rules: ShardingRules, mesh: Mesh):
    """Enable :func:`constrain` inside this block (dry-run / real launch)."""
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def active_rules() -> Optional[ShardingRules]:
    return _STATE.rules


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def active_axis_size(axis: str) -> int:
    """Size of a mesh axis under the active rules (1 when inactive)."""
    mesh = _STATE.mesh
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def _prune(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes the active mesh does not have (e.g. 'pod' single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    return kept if kept else None


def spec_for(logical_axes: Sequence[Optional[str]],
             rules: Optional[ShardingRules] = None,
             mesh: Optional[Mesh] = None,
             shape: Optional[Tuple[int, ...]] = None) -> P:
    """PartitionSpec for a tensor whose dims carry ``logical_axes`` names.

    Robustness rules that keep every odd shape lowering:

    * divisibility fallback — a dim not divisible by its mesh-axis product
      progressively drops trailing mesh axes (e.g. batch ("pod","data",
      "model") → ("pod","data") when B=256 on 512 chips) and replicates if
      nothing divides;
    * dedup — a mesh axis may appear only once per spec; later dims lose it
      (e.g. batch already on "model" ⇒ vocab falls back for that tensor).
    """
    rules = rules or _STATE.rules
    mesh = mesh or _STATE.mesh
    if rules is None:
        return P()
    used: set = set()
    out = []
    for i, name in enumerate(logical_axes):
        axes = rules.mesh_axes(name)
        if mesh is not None:
            axes = _prune(mesh, axes)
        if axes is not None:
            tup = (axes,) if isinstance(axes, str) else tuple(axes)
            tup = tuple(a for a in tup if a not in used)
            if shape is not None and mesh is not None:
                while tup and shape[i] % _axis_size(mesh, tup) != 0:
                    tup = tup[:-1]
            used.update(tup)
            axes = (None if not tup else
                    tup[0] if len(tup) == 1 else tup)
        out.append(axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` via the active rules; no-op when inactive.

    Models sprinkle these at block boundaries; they are the only sharding
    hook inside model code.
    """
    if _STATE.rules is None or _STATE.mesh is None:
        return x
    spec = spec_for(logical_axes, shape=tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter / batch specs (used by launch + checkpoint, outside jit)
# ---------------------------------------------------------------------------
def param_specs(spec_tree, rules: ShardingRules, mesh: Mesh):
    """Tree of PartitionSpecs for a ParamSpec tree (divisibility-checked)."""
    return jax.tree_util.tree_map(
        lambda s: spec_for(s.axes, rules, mesh, s.shape),
        spec_tree, is_leaf=_is_spec)


def param_shardings(spec_tree, rules: ShardingRules, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, rules, mesh, s.shape)),
        spec_tree, is_leaf=_is_spec)


def batch_spec(rules: ShardingRules, mesh: Mesh, ndim: int = 2) -> P:
    """(B, S, ...) batch: B over (pod, data); S per the SP flag."""
    axes: list = [_prune(mesh, rules.mesh_axes("batch"))]
    if ndim > 1:
        axes.append(_prune(mesh, rules.mesh_axes("seq")))
    axes += [None] * (ndim - len(axes))
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)
