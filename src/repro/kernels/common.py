"""Shared helpers for the Pallas TPU kernels.

Every kernel in this package targets TPU (``pl.pallas_call`` with explicit
``BlockSpec`` VMEM tiling, MXU-aligned tiles) and is *validated* on CPU via
``interpret=True``, which executes the kernel body in Python.  ``INTERPRET``
is resolved once from the actual backend so the same ops work on both.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.lru_cache(None)
def use_interpret() -> bool:
    """Pallas interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_dim(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to a multiple (kernels mask the tail)."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=fill)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
