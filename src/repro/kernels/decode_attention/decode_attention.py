"""Flash-decoding Pallas TPU kernel.

One new token per sequence against a long KV cache.  The grid is
(B, KVH, T/bk) with the cache axis innermost: each step streams one KV block
through VMEM and updates the online-softmax state for the *group* of q heads
sharing that kv head (GQA), so the MXU sees a (group x bk) logits tile
instead of a vector — bandwidth-bound by the KV read, exactly at the memory
roofline.

The kernel optionally emits the partial (acc, m, l) instead of the
normalized output; the model layer psum-combines partials across
sequence-sharded cache shards (flash-decoding across the `model` mesh axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, steps: int,
                   partial: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (g, dk)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, dk)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # (g, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)                   # (bk, dv)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == steps - 1)
    def _store():
        l = l_ref[...]
        if partial:
            o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
            m_out_ref[0, 0] = m_ref[...]
            l_out_ref[0, 0] = l
        else:
            o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                           ).astype(o_ref.dtype)
            m_out_ref[0, 0] = m_ref[...]
            l_out_ref[0, 0] = l


@functools.partial(jax.jit, static_argnames=("scale", "bk", "partial"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            scale: float | None = None, bk: int = 512,
                            partial: bool = False):
    """q (B, H, Dk) x k (B, KVH, T, Dk) x v (B, KVH, T, Dv).

    Returns (out (B,H,Dv), m (B,H,1), l (B,H,1)); ``out`` is normalized
    unless ``partial``.  T % bk == 0 (ops pads with masked keys is NOT done
    here — decode caches are always block-aligned by the serving layer).
    """
    b, h, dk = q.shape
    kvh, t = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    assert t % bk == 0, (t, bk)
    scale = (dk ** -0.5) if scale is None else scale
    steps = t // bk
    grid = (b, kvh, steps)
    qg = q.reshape(b, kvh, g, dk)
    out, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, steps=steps,
                          partial=partial),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dk), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, dk), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda b_, h_, j: (b_, h_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dv), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, g, dv),
                                 jnp.float32 if partial else q.dtype),
            jax.ShapeDtypeStruct((b, kvh, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=use_interpret(),
    )(qg, k, v)
    return (out.reshape(b, h, dv), m.reshape(b, h, 1), l.reshape(b, h, 1))
