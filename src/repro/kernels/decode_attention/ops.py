"""Public decode-attention op with seq-sharded flash-decoding combine."""

from __future__ import annotations

import functools

import jax

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import use_interpret
from .decode_attention import decode_attention_pallas
from .ref import (combine_partials, counts,
                  decode_attention_partial_ref, decode_attention_ref)

__all__ = ["decode_attention", "combine_partials", "counts",
           "decode_attention_partial_ref", "decode_attention_ref",
           "build_kernel"]


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     scale: float | None = None,
                     impl: str = "auto") -> jax.Array:
    """One-token attention q (B,H,Dk) against cache k/v (B,KVH,T,D*).

    On TPU this is the Pallas flash-decoding kernel; elsewhere the jnp
    partial form (identical math, fp32 softmax).  When the cache's sequence
    axis is sharded over a mesh axis, jit/GSPMD turns the max/sum/weighted-sum
    reductions of the jnp form into the all-reduce combine of flash-decoding
    automatically — the Pallas path is combined explicitly by the serving
    layer via :func:`combine_partials`.
    """
    if impl == "auto":
        impl = "xla" if use_interpret() else "pallas"
    if impl == "pallas":
        t = k.shape[2]
        bk = 512 if t % 512 == 0 else (128 if t % 128 == 0 else t)
        out, _, _ = decode_attention_pallas(q, k, v, scale=scale, bk=bk)
        return out
    return decode_attention_ref(q, k, v, scale=scale)


@kernel_family("decode_attention")
def build_kernel(config: EGPUConfig = EGPU_16T, *, use_pallas: bool = True,
                 scale: float | None = None) -> Kernel:
    """TinyCL kernel object: one-token attention q (B,H,Dk) x cache k/v
    (B,KVH,T,D*) -> (B,H,Dv)."""
    impl = "auto" if use_pallas else "xla"
    exe = lambda q, k, v: decode_attention(q, k, v, scale=scale, impl=impl)
    return Kernel(
        name="decode_attention",
        executor=exe,
        counts=lambda b, h, t, dk, dv, itemsize=2: counts(b, h, t, dk, dv,
                                                          itemsize),
        jitted=True,   # `decode_attention` is already jax.jit-wrapped
    )
