"""Pure-jnp oracle for single-token (decode) attention.

Decode attends one new query per sequence against the full KV cache:
q (B, H, Dk) x k/v (B, KVH, T, D*) -> (B, H, Dv).  The oracle also exposes
the *partial-softmax* form (out, m, l) used to combine seq-sharded shards
(flash-decoding): each shard reduces its KV slice, then shards merge with
:func:`combine_partials` — an exact algebraic identity, tested as such.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts
from ..flash_attention.ref import repeat_kv


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         scale: float | None = None) -> jnp.ndarray:
    out, m, l = decode_attention_partial_ref(q, k, v, scale=scale)
    return (out / l).astype(q.dtype)


def decode_attention_partial_ref(q, k, v, *, scale=None):
    """Unnormalized partial: returns (acc (B,H,Dv) f32, m (B,H,1), l (B,H,1))."""
    b, h, dk = q.shape
    kvh = k.shape[1]
    group = h // kvh
    k = repeat_kv(k, group)
    v = repeat_kv(v, group)
    scale = (dk ** -0.5) if scale is None else scale
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bht,bhtd->bhd", p, v.astype(jnp.float32))
    return acc, m, l


def combine_partials(parts):
    """Merge [(acc, m, l), ...] partials from seq shards — exact."""
    acc, m, l = parts[0]
    for acc2, m2, l2 in parts[1:]:
        mn = jnp.maximum(m, m2)
        w1, w2 = jnp.exp(m - mn), jnp.exp(m2 - mn)
        acc = acc * w1 + acc2 * w2
        l = l * w1 + l2 * w2
        m = mn
    return acc / l, m, l


def counts(b: int, h: int, t: int, dk: int, dv: int,
           itemsize: int = 2) -> WorkCounts:
    macs = float(b) * h * t * (dk + dv)
    io = float(b) * t * (dk + dv) * itemsize      # the KV-cache read dominates
    return WorkCounts(ops=2.0 * macs, dcache_bytes=io, host_bytes=io,
                      working_set=io)
