"""Branch-free peak/trough delineation Pallas kernel.

Each grid step (work-group) flags one block of samples; the predicate needs
x[i-1] and x[i+1], so the kernel receives three BlockSpec views of the same
input — previous, current and next block (index maps clamp at the edges).
Every lane evaluates *both* the peak and the trough predicates and selects
with a mask: that is the TPU rendering of the e-GPU's SIMT thread masking
for divergent branches (§VIII-C), made explicit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import use_interpret


def _delineate_kernel(xp_ref, xc_ref, xn_ref, o_ref, *, block: int, n: int,
                      thr, blocks: int):
    i = pl.program_id(0)
    xp = xp_ref[...]
    xc = xc_ref[...]
    xn = xn_ref[...]
    # previous sample of lane j: window[j + block - 1] over [prev | cur]
    wprev = jnp.concatenate([xp, xc], axis=1)
    prev = jax.lax.slice_in_dim(wprev, block - 1, 2 * block - 1, axis=1)
    # first block has no real predecessor: clamp to x[0]
    prev = jnp.where((i == 0), jnp.concatenate([xc[:, :1], xc[:, :-1]], axis=1),
                     prev)
    wnext = jnp.concatenate([xc, xn], axis=1)
    nxt = jax.lax.slice_in_dim(wnext, 1, block + 1, axis=1)
    nxt = jnp.where((i == blocks - 1),
                    jnp.concatenate([xc[:, 1:], xc[:, -1:]], axis=1), nxt)

    gid = i * block + jax.lax.broadcasted_iota(jnp.int32, xc.shape, 1)
    interior = (gid > 0) & (gid < n - 1)
    t = jnp.asarray(thr, xc.dtype)
    is_peak = (xc > prev) & (xc >= nxt) & (xc > t) & interior
    is_trough = (xc < prev) & (xc <= nxt) & (xc < -t) & interior
    o_ref[...] = is_peak.astype(jnp.int8) - is_trough.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "thr", "true_n"))
def delineate_pallas(x: jax.Array, thr, *, block: int = 512,
                     true_n: int | None = None) -> jax.Array:
    """Flags (+1 peak / -1 trough / 0) for a 1-D signal; ``len(x)`` must be a
    multiple of ``block`` (ops.delineate pads and crops).  ``thr`` is a
    compile-time scalar (it lands in the kernel as an immediate); ``true_n``
    is the unpadded length (endpoints are never extrema)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    blocks = n // block
    x2 = x.reshape(1, n)
    true_n = n if true_n is None else true_n
    return pl.pallas_call(
        functools.partial(_delineate_kernel, block=block, n=true_n, thr=thr,
                          blocks=blocks),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, jnp.maximum(i - 1, 0))),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, jnp.minimum(i + 1, blocks - 1))),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int8),
        interpret=use_interpret(),
    )(x2, x2, x2)[0]
