"""jit'd public wrapper for the delineation kernel + TinyCL registration."""

from __future__ import annotations

import functools

import jax

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import pad_dim
from .delineate import delineate_pallas
from .ref import counts as delineate_counts, delineate_ref


@functools.partial(jax.jit, static_argnames=("block", "thr"))
def delineate(x: jax.Array, thr=0, block: int = 512) -> jax.Array:
    """Peak/trough flags for any-length 1-D signal via the Pallas kernel.

    The tail pad uses the last sample value, so no spurious extrema appear at
    the padded boundary (a constant run is never a strict rise).
    """
    n = x.shape[0]
    xp = pad_dim(x, 0, block, fill=0)
    if xp.shape[0] != n:
        xp = xp.at[n:].set(x[n - 1])
    flags = delineate_pallas(xp, thr, block=block, true_n=n)
    return flags[:n]


@kernel_family("delineate")
def build_kernel(config: EGPUConfig = EGPU_16T, *,
                 use_pallas: bool = True) -> Kernel:
    knobs = config.tpu_knobs()
    block = max(512, knobs.lane_tile)
    exe = (lambda x, thr=0: delineate(x, thr, block)) if use_pallas else delineate_ref
    return Kernel(
        name="delineate",
        executor=exe,
        counts=lambda n, itemsize=4: delineate_counts(n, itemsize),
        jitted=use_pallas,   # `delineate` is already jax.jit-wrapped
    )
