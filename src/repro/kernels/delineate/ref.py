"""Pure-jnp oracle + counts for delineation (TinyBio stage 2).

The paper's delineation detects the peaks and troughs of the filtered
respiration signal to determine inspiration/expiration times (§VII-B).  It is
the *control-intensive* stage: on the e-GPU, divergent branches serialize
under thread masking (§VIII-C), which is why its speed-up (3.1-13.1x) trails
the FIR's (3.6-15.1x).

We implement it branch-free — the TPU/VPU analogue of SIMT thread masking is
a masked select, so the "divergent" both-sides cost is explicit in the code
itself: every lane evaluates both the peak and the trough predicate.

Output encoding (int8): +1 = peak, -1 = trough, 0 = neither.  Endpoints are
never extrema (they lack a neighbour).  A plateau credits its first sample
(strict rise before, non-strict fall after), matching the usual biosignal
delineator convention.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts


def delineate_ref(x: jnp.ndarray, thr: float | int = 0) -> jnp.ndarray:
    """Flags[i] = +1 if x[i] is a local max above ``thr``, -1 if a local min
    below ``-thr``, else 0.  x: 1-D float or integer signal."""
    prev = jnp.concatenate([x[:1], x[:-1]])
    nxt = jnp.concatenate([x[1:], x[-1:]])
    n = x.shape[0]
    idx = jnp.arange(n)
    interior = (idx > 0) & (idx < n - 1)
    is_peak = (x > prev) & (x >= nxt) & (x > thr) & interior
    is_trough = (x < prev) & (x <= nxt) & (x < -thr) & interior
    return (is_peak.astype(jnp.int8) - is_trough.astype(jnp.int8))


def extrema_times(flags: jnp.ndarray):
    """Host-side post-processing: indices of peaks / troughs (inspiration /
    expiration onsets).  Fixed-size outputs (padded with -1) so it stays
    jit-friendly."""
    n = flags.shape[0]
    idx = jnp.arange(n)
    peak_t = jnp.where(flags > 0, idx, n)
    trough_t = jnp.where(flags < 0, idx, n)
    peaks = jnp.sort(peak_t)
    troughs = jnp.sort(trough_t)
    return jnp.where(peaks < n, peaks, -1), jnp.where(troughs < n, troughs, -1)


def counts(n: int, itemsize: int = 4) -> WorkCounts:
    # ~8 compare/select ops per sample, both predicate paths always evaluated
    ops = 8.0 * n
    dcache = 3.0 * n * itemsize + n  # x, prev, next reads + int8 flags out
    host = n * itemsize + n
    # streaming 3-point stencil: live working set is a few cache lines
    return WorkCounts(ops=ops, dcache_bytes=dcache, host_bytes=host,
                      working_set=1024.0 * itemsize,
                      divergence=1.0)  # fully control-dominated stage
