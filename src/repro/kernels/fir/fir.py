"""FIR filter Pallas kernel with halo blocks.

Work decomposition follows the Tiny-OpenCL NDRange: each grid step (work-
group) produces one block of outputs.  The causal window needs ``taps - 1``
samples of history, so the kernel receives the *previous* block as a second
BlockSpec view of the same input (index map ``max(i-1, 0)``) — the TPU
version of the paper's observation that FIR's sequential accesses coalesce
perfectly (§VIII-C): every sample is DMA'd into VMEM exactly once per block
role, and the taps loop runs from VMEM/registers.

The taps loop is unrolled statically (taps is a compile-time constant), so
each iteration is a shifted static slice — the VPU analogue of the e-GPU's
register sliding window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import use_interpret


def _fir_kernel(x_prev_ref, x_cur_ref, h_ref, o_ref, *, taps: int, block: int,
                fxp_shift: int | None):
    i = pl.program_id(0)
    # (1, block) layout: TPU wants >=2-D; lane dim = block
    prev = x_prev_ref[...]
    cur = x_cur_ref[...]
    # zero history for the first block (index map clamps i-1 to 0)
    prev = jnp.where(i == 0, jnp.zeros_like(prev), prev)
    w = jnp.concatenate([prev, cur], axis=-1)      # (1, 2*block)
    acc = jnp.zeros(cur.shape, jnp.int32 if fxp_shift is not None else jnp.float32)
    for t in range(taps):
        # y[j] += h[t] * x[j - t]  ->  w[block + j - t]
        sl = jax.lax.slice_in_dim(w, block - t, 2 * block - t, axis=1)
        acc = acc + h_ref[0, t] * sl.astype(acc.dtype)
    if fxp_shift is not None:
        acc = jnp.right_shift(acc, fxp_shift)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "fxp_shift"))
def fir_pallas(x: jax.Array, h: jax.Array, *, block: int = 512,
               fxp_shift: int | None = None) -> jax.Array:
    """Causal FIR via Pallas.  ``x`` length must be a multiple of ``block``
    and ``block >= taps`` (ops.fir pads & validates)."""
    n = x.shape[0]
    taps = h.shape[0]
    assert n % block == 0 and block >= taps, (n, block, taps)
    x2 = x.reshape(1, n)
    h2 = h.reshape(1, taps)
    grid = (n // block,)
    out = pl.pallas_call(
        functools.partial(_fir_kernel, taps=taps, block=block, fxp_shift=fxp_shift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, jnp.maximum(i - 1, 0))),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, taps), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype if fxp_shift is not None
                                       else jnp.float32),
        interpret=use_interpret(),
    )(x2, x2, h2)
    return out[0]
