"""jit'd public wrapper for the FIR kernel + TinyCL registration."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import pad_dim, round_up
from .fir import fir_pallas
from .ref import FXP_SHIFT, counts as fir_counts, fir_ref


@functools.partial(jax.jit, static_argnames=("block",))
def fir(x: jax.Array, h: jax.Array, block: int = 512) -> jax.Array:
    """Causal FIR filter of any length/dtype via the Pallas kernel."""
    n = x.shape[0]
    taps = h.shape[0]
    block = max(block, round_up(taps, 128))
    fixed = jnp.issubdtype(x.dtype, jnp.integer)
    xp = pad_dim(x, 0, block)
    y = fir_pallas(xp, h, block=block,
                   fxp_shift=FXP_SHIFT if fixed else None)
    return y[:n]


@kernel_family("fir")
def build_kernel(config: EGPUConfig = EGPU_16T, *,
                 use_pallas: bool = True) -> Kernel:
    knobs = config.tpu_knobs()
    block = max(512, knobs.lane_tile)
    exe = (lambda x, h: fir(x, h, block)) if use_pallas else fir_ref
    return Kernel(
        name="fir",
        executor=exe,
        counts=lambda n, taps, itemsize=4: fir_counts(n, taps, itemsize),
        jitted=use_pallas,   # `fir` is already jax.jit-wrapped
    )
