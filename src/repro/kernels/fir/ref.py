"""Pure-jnp oracle + counts for the FIR filter (TinyBio pre-processing).

The paper's pipeline filters the raw biosignal with a causal FIR filter.
The e-GPU runs integer/fixed-point arithmetic (no FPU, §IV-A); we provide
both a Q15-style int32 fixed-point path (paper-faithful) and a float path
(TPU-native).
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts

FXP_SHIFT = 15  # Q1.15 coefficients


def fir_ref(x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Causal FIR: y[n] = sum_t h[t] * x[n - t] (zero-padded history).

    Float inputs use float accumulation; integer inputs use int32 MACs with a
    Q15 renormalizing shift — the e-GPU fixed-point discipline.
    """
    taps = h.shape[0]
    fixed = jnp.issubdtype(x.dtype, jnp.integer)
    acc_dtype = jnp.int32 if fixed else jnp.float32
    xp = jnp.concatenate([jnp.zeros((taps - 1,), x.dtype), x]).astype(acc_dtype)
    ha = h.astype(acc_dtype)
    n = x.shape[0]
    # stacked sliding windows, contracted against the taps (pure jnp oracle)
    idx = jnp.arange(n)[:, None] + jnp.arange(taps)[None, :]
    windows = xp[idx]                       # (n, taps); windows[i, j] = x[i - (taps-1) + j]
    y = windows @ ha[::-1]
    if fixed:
        y = jnp.right_shift(y, FXP_SHIFT)
    return y.astype(x.dtype if fixed else acc_dtype)


def counts(n: int, taps: int, itemsize: int = 4) -> WorkCounts:
    macs = float(n) * taps
    # each input sample is loaded from the D$ once (register sliding window);
    # outputs stream back
    dcache = 2.0 * n * itemsize
    host = 2.0 * n * itemsize            # raw signal in, filtered signal out
    # streaming kernel: the *live* working set is the tap window + the
    # current cache lines, not the whole signal (which is read once) — so
    # the D$-capacity traffic inflation must not trigger.
    return WorkCounts(ops=macs, dcache_bytes=dcache, host_bytes=host,
                      working_set=float(taps + 256) * itemsize)
