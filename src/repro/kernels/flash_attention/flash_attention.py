"""FlashAttention-2 Pallas TPU kernel (GQA-aware, mixed Dk/Dv).

Grid (B, H, S/bq, T/bk) with the kv axis innermost (sequential on TPU), so
each (b, h, i) output tile streams kv blocks through VMEM while the online
softmax state (m, l, acc) lives in VMEM scratch — the e-GPU paper's
cache-residency discipline (§IV-B) applied to the attention working set.
GQA is expressed in the k/v index maps (kv head = q head // group), so no
repeated kv ever materializes.

Causal masking is block-sparse: fully-masked kv blocks are skipped with
``pl.when`` (no MXU work, the DMA is still scheduled by the grid — Mosaic
elides stores), halving effective FLOPs at S == T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, kv_steps: int,
                  q_offset: int):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # first absolute q row of this tile vs first kv col: skip if block fully
    # above the diagonal
    q_lo = q_offset + i * bq
    run = (not causal) or (q_lo + bq - 1 >= j * bk)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dk)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, dk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= kj, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)             # (bk, dv)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _store():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "q_offset"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           q_offset: int = 0) -> jax.Array:
    """q (B,H,S,Dk), k (B,KVH,T,Dk), v (B,KVH,T,Dv) -> (B,H,S,Dv).
    S % bq == 0 and T % bk == 0 (ops.flash_attention pads)."""
    b, h, s, dk = q.shape
    kvh, t = k.shape[1], k.shape[2]
    dv = v.shape[3]
    group = h // kvh
    assert s % bq == 0 and t % bk == 0, (q.shape, k.shape, bq, bk)
    scale = (dk ** -0.5) if scale is None else scale
    kv_steps = t // bk
    grid = (b, h, s // bq, kv_steps)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        kv_steps=kv_steps, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dk), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, dk),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=use_interpret(),
    )(q, k, v)
