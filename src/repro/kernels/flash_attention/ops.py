"""Public fused-attention op: Pallas on TPU, triangle-scan XLA elsewhere.

The XLA path is not a naive softmax: causal attention is computed as a
``lax.scan`` over the *static* list of (q-chunk, kv-chunk) pairs on or below
the diagonal, with online-softmax state carried in full-sequence buffers.
This keeps HLO size O(1) in sequence length, bounds live memory to
O(S * Dv + bq * bk) instead of O(S * T), and — because the pair list is
static — performs exactly the causal half of the FLOPs, so the dry-run
roofline matches what the TPU kernel would do.  Non-causal (encoder)
attention scans kv chunks only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_dim, use_interpret
from .flash_attention import flash_attention_pallas
from .ref import counts, mha_ref, repeat_kv

__all__ = ["flash_attention", "counts", "mha_ref", "repeat_kv"]

NEG_INF = -1e30


def _causal_pairs(nq: int, nk: int, bq: int, bk: int, q_offset: int):
    """Static (i, j) kv-visibility pairs for causal chunked attention."""
    pairs = []
    for i in range(nq):
        hi = q_offset + (i + 1) * bq - 1          # last absolute q row
        jmax = min(nk - 1, hi // bk)
        pairs.extend((i, j) for j in range(jmax + 1))
    return pairs


def _block(q, k, v, scale, causal, qi0, kj0, bq, bk):
    """One online-softmax block: returns (m, l, acc) contributions."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qi = qi0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = kj0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where((qi >= kj)[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return m, l, acc


def _merge(m0, l0, a0, m1, l1, a1):
    m = jnp.maximum(m0, m1)
    w0 = jnp.exp(m0 - m)
    w1 = jnp.exp(m1 - m)
    return m, l0 * w0 + l1 * w1, a0 * w0 + a1 * w1


def _xla_causal(q, k, v, scale, bq, bk, q_offset):
    b, h, s, dk = q.shape
    kvh, t, dv = k.shape[1], k.shape[2], v.shape[3]
    group = h // kvh
    k = repeat_kv(k, group)
    v = repeat_kv(v, group)
    nq, nk = s // bq, t // bk
    pairs = jnp.asarray(_causal_pairs(nq, nk, bq, bk, q_offset), jnp.int32)

    m0 = jnp.full((b, h, s, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)
    a0 = jnp.zeros((b, h, s, dv), jnp.float32)

    def body(carry, ij):
        m_all, l_all, acc_all = carry
        i, j = ij[0], ij[1]
        qc = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=2)
        kc = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=2)
        mb, lb, ab = _block(qc, kc, vc, scale, True,
                            q_offset + i * bq, j * bk, bq, bk)
        mp = jax.lax.dynamic_slice_in_dim(m_all, i * bq, bq, axis=2)
        lp = jax.lax.dynamic_slice_in_dim(l_all, i * bq, bq, axis=2)
        ap = jax.lax.dynamic_slice_in_dim(acc_all, i * bq, bq, axis=2)
        mn, ln, an = _merge(mp, lp, ap, mb, lb, ab)
        m_all = jax.lax.dynamic_update_slice_in_dim(m_all, mn, i * bq, axis=2)
        l_all = jax.lax.dynamic_update_slice_in_dim(l_all, ln, i * bq, axis=2)
        acc_all = jax.lax.dynamic_update_slice_in_dim(acc_all, an, i * bq, axis=2)
        return (m_all, l_all, acc_all), None

    (m_all, l_all, acc_all), _ = jax.lax.scan(body, (m0, l0, a0), pairs)
    out = acc_all / jnp.where(l_all == 0.0, 1.0, l_all)
    return out.astype(q.dtype)


def _xla_full(q, k, v, scale, causal, bk, q_offset):
    """Non-causal (or decode-suffix) attention: scan over kv chunks only."""
    b, h, s, dk = q.shape
    kvh, t, dv = k.shape[1], k.shape[2], v.shape[3]
    group = h // kvh
    k = repeat_kv(k, group)
    v = repeat_kv(v, group)
    nk = t // bk

    m0 = jnp.full((b, h, s, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s, 1), jnp.float32)
    a0 = jnp.zeros((b, h, s, dv), jnp.float32)

    def body(carry, j):
        m_all, l_all, acc_all = carry
        kc = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=2)
        mb, lb, ab = _block(q, kc, vc, scale, causal, q_offset, j * bk, s, bk)
        return _merge(m_all, l_all, acc_all, mb, lb, ab), None

    (m_all, l_all, acc_all), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32))
    out = acc_all / jnp.where(l_all == 0.0, 1.0, l_all)
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_offset",
                                             "bq", "bk", "impl"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, bq: int = 512, bk: int = 512,
                    impl: str = "auto") -> jax.Array:
    """Fused attention, any (B,H,S,Dk) x (B,KVH,T,Dk) x (B,KVH,T,Dv).

    impl: "auto" (pallas on TPU, xla otherwise), "pallas", "xla".
    Sequence lengths are padded up to the block sizes internally; padded kv
    positions are masked out via the causal/validity mask.
    """
    b, h, s, dk = q.shape
    t = k.shape[2]
    scale = (dk ** -0.5) if scale is None else scale
    if impl == "auto":
        impl = "xla" if use_interpret() else "pallas"

    # Block sizes clamp to the (rounded) problem; TPU wants >= (8, 128) tiles.
    def _round_up(x, m):
        return -(-x // m) * m
    if impl == "pallas":
        bq_ = min(bq, _round_up(s, 8))
        bk_ = min(bk, _round_up(t, 128))
    else:
        bq_, bk_ = min(bq, s), min(bk, t)
    qp = pad_dim(q, 2, bq_)
    kp = pad_dim(k, 2, bk_)
    vp = pad_dim(v, 2, bk_)
    # Padded kv columns: under causal masking they sit above the diagonal of
    # every real q row (kj >= t > qi), so they are always hidden.  Non-causal
    # callers must pass a dividing block size (checked below).

    if impl == "pallas":
        out = flash_attention_pallas(qp, kp, vp, causal=causal, scale=scale,
                                     bq=bq_, bk=bk_, q_offset=q_offset)
    elif causal:
        out = _xla_causal(qp, kp, vp, scale, bq_, bk_, q_offset)
    else:
        if kp.shape[2] != t:
            raise ValueError("non-causal attention requires T % bk == 0 "
                             f"(T={t}, bk={bk_}) — pick a dividing block")
        out = _xla_full(qp, kp, vp, scale, False, bk_, q_offset)
    return out[:, :, :s]
