"""Pure-jnp oracle for fused (flash) attention.

Layout convention across the repo: q (B, H, S, Dk), k (B, KVH, T, Dk),
v (B, KVH, T, Dv) with grouped-query sharing (KVH divides H).  Dk and Dv may
differ (MLA uses Dk = 192 = nope 128 + rope 64 against Dv = 128).
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts


def repeat_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """(B, KVH, T, D) -> (B, KVH * group, T, D) by repeating each kv head."""
    if group == 1:
        return x
    b, kvh, t, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, kvh, group, t, d)).reshape(
        b, kvh * group, t, d)


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, scale: float | None = None,
            q_offset: int = 0) -> jnp.ndarray:
    """Plain softmax attention oracle (fp32 softmax).

    ``q_offset`` is the absolute position of q[…, 0, :] — used when q is a
    suffix of a longer sequence (decode / chunked prefill): causal masking
    compares (q_offset + i) >= j.
    """
    b, h, s, dk = q.shape
    kvh, t = k.shape[1], k.shape[2]
    group = h // kvh
    k = repeat_kv(k, group)
    v = repeat_kv(v, group)
    scale = (dk ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = q_offset + jnp.arange(s)[:, None]
        kj = jnp.arange(t)[None, :]
        logits = jnp.where(qi >= kj, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def counts(b: int, h: int, s: int, t: int, dk: int, dv: int,
           causal: bool = True, itemsize: int = 2) -> WorkCounts:
    frac = 0.5 if causal and s == t else 1.0
    macs = b * h * s * t * (dk + dv) * frac
    io = b * (h * s * (dk + dv) + h * s * dv) * itemsize
    return WorkCounts(ops=2.0 * macs, dcache_bytes=2.0 * macs * itemsize / 8,
                      host_bytes=io, working_set=io)
