"""MXU-tiled GeMM Pallas kernel, knob-driven (e-GPU Table-II discipline).

Grid ``(M/bm, N/bn, K/bk)`` with a VMEM accumulator scratch: the K dimension
is the innermost (sequential on TPU) grid axis, so each (i, j) output tile
accumulates across K steps while Pallas double-buffers the A/B tiles —
exactly the warp-style latency hiding the paper gets from 4 concurrent warps
over a 4-cycle D$ (§VII-A), transplanted to HBM->VMEM DMAs.

Tile shapes come from :class:`repro.core.KernelKnobs` (the TPU projection of
the e-GPU's threads / warps / D$ knobs) and are validated against the VMEM
budget with :func:`repro.core.check_vmem_budget`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.device import KernelKnobs, check_vmem_budget
from ..common import use_interpret


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype"))
def gemm_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 128, out_dtype=None) -> jax.Array:
    """C = A @ B.  Shapes must already be padded to multiples of the tiles
    (``ops.gemm`` handles padding/cropping)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    acc_dtype = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    out_dtype = out_dtype or acc_dtype
    k_steps = k // bk

    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=use_interpret(),
    )(a, b)


def tiles_from_knobs(knobs: KernelKnobs, m: int, n: int, k: int,
                     itemsize: int = 4) -> tuple[int, int, int]:
    """Pick (bm, bn, bk) from the e-GPU knob projection, MXU-aligned, within
    the VMEM budget (the D$-size knob)."""
    bn = min(knobs.lane_tile, max(128, n))
    bm = min(max(knobs.sublane_tile * 16, 128), max(128, m))
    bk = 128
    # shrink bm until A+B+acc blocks (x pipeline depth) fit the budget
    while True:
        blocks = (bm * bk * itemsize, bk * bn * itemsize, bm * bn * 4)
        try:
            check_vmem_budget(knobs, *blocks)
            break
        except ValueError:
            if bm > 128:
                bm //= 2
            elif bn > 128:
                bn //= 2
            else:
                break
    return bm, bn, bk
