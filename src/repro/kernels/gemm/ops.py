"""jit'd public wrapper for the GeMM kernel: padding, knob plumbing, TinyCL
kernel registration."""

from __future__ import annotations

import functools

import jax

from ...core.device import EGPU_16T, EGPUConfig, KernelKnobs
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import pad_dim, round_up
from .gemm import gemm_pallas, tiles_from_knobs
from .ref import counts as gemm_counts
from .ref import gemm_ref


@functools.partial(jax.jit, static_argnames=("knobs",))
def gemm(a: jax.Array, b: jax.Array, knobs: KernelKnobs | None = None) -> jax.Array:
    """C = A @ B via the Pallas kernel, any (m, k) x (k, n) shapes/dtypes."""
    knobs = knobs or EGPU_16T.tpu_knobs()
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = tiles_from_knobs(knobs, m, n, k, a.dtype.itemsize)
    bm, bn, bk = min(bm, round_up(m, 8)), min(bn, round_up(n, 128)), min(bk, round_up(k, 128))
    ap = pad_dim(pad_dim(a, 0, bm), 1, bk)
    bp = pad_dim(pad_dim(b, 0, bk), 1, bn)
    out = gemm_pallas(ap, bp, bm=bm, bn=bn, bk=bk)
    return out[:m, :n]


@kernel_family("gemm")
def build_kernel(config: EGPUConfig = EGPU_16T, *,
                 use_pallas: bool = True) -> Kernel:
    """TinyCL kernel object for queue dispatch (registry builder)."""
    knobs = config.tpu_knobs()
    exe = (lambda a, b: gemm(a, b, knobs)) if use_pallas else gemm_ref
    return Kernel(
        name="gemm",
        executor=exe,
        counts=lambda m, n, k, itemsize=4: gemm_counts(m, n, k, itemsize),
        jitted=use_pallas,   # `gemm` is already jax.jit-wrapped
    )
