"""Pure-jnp oracle + structural work counts for the GeMM kernel.

The GeMM benchmark is the paper's vehicle for quantifying Tiny-OpenCL
overheads (Fig 3): matrix sizes 32x32 .. 256x256, integer arithmetic (the
e-GPU has no FPU).  ``counts()`` feeds the analytic machine model.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts

# Register-blocking reuse factor of the tuned Tiny-OpenCL GeMM kernel: each
# thread computes a 4x1 strip of C keeping A values in registers, so each
# loaded word is used ~4 times before returning to the D$.
REGISTER_REUSE = 4
# D$ tile edge used by the blocked kernel (3 * 32*32 * 4B = 12 KiB < 16 KiB).
DCACHE_TILE = 32


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B with an accumulator wide enough for the input dtype."""
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    return jnp.matmul(a.astype(acc), b.astype(acc),
                      preferred_element_type=acc).astype(
                          a.dtype if jnp.issubdtype(a.dtype, jnp.integer) else acc)


def counts(m: int, n: int, k: int, itemsize: int = 4) -> WorkCounts:
    macs = float(m) * n * k
    # core <-> D$ traffic of the register-blocked inner loop
    dcache = (2.0 * macs / REGISTER_REUSE + m * n) * itemsize
    # host <-> D$ traffic of the two-level blocked kernel: compulsory
    # (all three matrices once) + capacity re-streams of A/B panels, one
    # reload per (register x tile) block — the kernel tiles to FIT the D$,
    # so working_set stays under 16 KiB by construction.
    compulsory = float(m * k + k * n + m * n) * itemsize
    capacity = 2.0 * macs / (REGISTER_REUSE * DCACHE_TILE) * itemsize
    host = compulsory + capacity
    ws = 3.0 * DCACHE_TILE * DCACHE_TILE * itemsize
    return WorkCounts(ops=macs, dcache_bytes=dcache, host_bytes=host,
                      working_set=ws, barriers=0, divergence=0.0)
