"""Chunked Mamba (S6) selective-scan Pallas TPU kernel.

Grid (B, Dm/bd, T/C) — time innermost (sequential), channel blocks parallel.
The (bd, N) state lives in VMEM scratch across time steps.  Within a chunk
the recurrence runs as a fori_loop of VPU FMAs on the (bd, N) plane; the
chunk's x/delta/B/C tiles are VMEM-resident (the D$-discipline of the
paper), so the sequential loop never touches HBM.

N = 16 keeps the state plane at bd x 16 fp32 = 8 KiB for bd = 128 — the
working set is firmly VMEM-resident and the kernel is bound by the
(B T Dm) x itemsize activation stream, i.e. the memory roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _mamba_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                  *, chunk: int, steps: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    f32 = jnp.float32
    x = x_ref[0].astype(f32)             # (C, bd)
    dt = dt_ref[0].astype(f32)           # (C, bd)
    a = a_ref[...].astype(f32)           # (bd, N)
    bmat = b_ref[0].astype(f32)          # (C, N)
    cmat = c_ref[0].astype(f32)          # (C, N)

    def step(i, carry):
        h, y = carry
        da = jnp.exp(dt[i][:, None] * a)                 # (bd, N)
        inc = (dt[i] * x[i])[:, None] * bmat[i][None, :]
        h = da * h + inc
        yt = jnp.sum(h * cmat[i][None, :], axis=1)       # (bd,)
        y = jax.lax.dynamic_update_slice_in_dim(y, yt[None, :], i, axis=0)
        return h, y

    y0 = jnp.zeros((chunk, x.shape[1]), f32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_ref[...], y0))
    h_ref[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(t_idx == steps - 1)
    def _store_state():
        hout_ref[0] = h

@functools.partial(jax.jit, static_argnames=("chunk", "bd"))
def mamba_scan_pallas(x: jax.Array, delta: jax.Array, a: jax.Array,
                      b: jax.Array, c: jax.Array, *, chunk: int = 64,
                      bd: int = 128):
    """x/delta (B, T, Dm), a (Dm, N), b/c (B, T, N).

    Returns (y (B, T, Dm) — WITHOUT the skip D*x term, added by ops —
    and final state (B, Dm, N) fp32).  T % chunk == 0, Dm % bd == 0.
    """
    bsz, t, dm = x.shape
    n = a.shape[1]
    assert t % chunk == 0 and dm % bd == 0, (x.shape, chunk, bd)
    steps = t // chunk
    grid = (bsz, dm // bd, steps)
    y, h = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=chunk, steps=steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d_, i: (b_, i, d_)),
            pl.BlockSpec((1, chunk, bd), lambda b_, d_, i: (b_, i, d_)),
            pl.BlockSpec((bd, n), lambda b_, d_, i: (d_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d_, i: (b_, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d_, i: (b_, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, d_, i: (b_, i, d_)),
            pl.BlockSpec((1, bd, n), lambda b_, d_, i: (b_, d_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, dm), x.dtype),
            jax.ShapeDtypeStruct((bsz, dm, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=use_interpret(),
    )(x, delta, a, b, c)
    return y, h
