"""Public Mamba selective-scan op: Pallas on TPU, chunked assoc-scan on XLA.

The XLA path runs a lax.scan over time chunks carrying the (B, Dm, N)
state; within each chunk a jax.lax.associative_scan (O(log C) depth)
expands the linear recurrence.  Live memory is O(B * C * Dm * N) per chunk
instead of O(B * T * Dm * N) — the same VMEM-bounded discipline as the
Pallas kernel, so the dry-run's memory_analysis reflects the real design.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import pad_dim, use_interpret
from .mamba_scan import mamba_scan_pallas
from .ref import counts, mamba_scan_ref, mamba_step_ref

__all__ = ["mamba_scan", "counts", "mamba_scan_ref", "mamba_step_ref",
           "build_kernel"]


def _combine(p, q):
    (pa, pb), (qa, qb) = p, q
    return pa * qa, qb + qa * pb


def _chunked_assoc(x, delta, a, b, c, state0, chunk):
    """lax.scan over chunks; associative scan inside each chunk."""
    f32 = jnp.float32
    bsz, t, dm = x.shape
    n = a.shape[1]
    nc = t // chunk
    a32 = a.astype(f32)
    h0 = (jnp.zeros((bsz, dm, n), f32) if state0 is None
          else state0.astype(f32))

    def body(h, xs):
        xc, dtc, bc, cc = xs                                 # (B, C, ...)
        xc, dtc, bc, cc = (z.astype(f32) for z in (xc, dtc, bc, cc))
        da = jnp.exp(dtc[..., None] * a32[None, None])       # (B, C, Dm, N)
        inc = (dtc * xc)[..., None] * bc[:, :, None, :]
        inc = inc.at[:, 0].add(da[:, 0] * h)                 # fold carry in
        _, hc = jax.lax.associative_scan(_combine, (da, inc), axis=1)
        y = jnp.einsum("btdn,btn->btd", hc, cc)
        return hc[:, -1], y

    def split(z):
        return jnp.moveaxis(z.reshape(bsz, nc, chunk, *z.shape[2:]), 1, 0)

    h, ys = jax.lax.scan(body, h0, (split(x), split(delta), split(b), split(c)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, dm)
    return y, h


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def mamba_scan(x: jax.Array, delta: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array, d: jax.Array, state0: jax.Array | None = None,
               *, chunk: int = 64, impl: str = "auto"):
    """Selective scan: x/delta (B,T,Dm), a (Dm,N), b/c (B,T,N), d (Dm,).

    Returns (y (B,T,Dm) including the D*x skip, final state (B,Dm,N) fp32).
    """
    bsz, t, dm = x.shape
    if impl == "auto":
        impl = "xla" if use_interpret() else "pallas"
    if impl == "pallas" and state0 is None:
        xp = pad_dim(x, 1, chunk)
        dp = pad_dim(delta, 1, chunk)      # delta=0 pad: exp(0*A)=1, inc=0
        bp = pad_dim(b, 1, chunk)
        cp = pad_dim(c, 1, chunk)
        # state after padded (identity) steps equals the state at t — exact
        y, h = mamba_scan_pallas(xp, dp, a, bp, cp, chunk=chunk)
        y = y[:, :t]
    else:
        xp = pad_dim(x, 1, chunk)
        dp = pad_dim(delta, 1, chunk)
        bp = pad_dim(b, 1, chunk)
        cp = pad_dim(c, 1, chunk)
        y, h = _chunked_assoc(xp, dp, a, bp, cp, state0, chunk)
        y = y[:, :t].astype(x.dtype)
    y = y + (x.astype(jnp.float32) * d[None, None].astype(jnp.float32)
             ).astype(y.dtype)
    return y, h


@kernel_family("mamba_scan")
def build_kernel(config: EGPUConfig = EGPU_16T, *, use_pallas: bool = True,
                 chunk: int = 64) -> Kernel:
    """TinyCL kernel object: selective scan x/delta (B,T,Dm), a (Dm,N),
    b/c (B,T,N), d (Dm,) -> (y, final_state)."""
    impl = "auto" if use_pallas else "xla"
    exe = (lambda x, delta, a, b, c, d:
           mamba_scan(x, delta, a, b, c, d, chunk=chunk, impl=impl))
    return Kernel(
        name="mamba_scan",
        executor=exe,
        counts=lambda bsz, t, dm, n, itemsize=4: counts(bsz, t, dm, n,
                                                        itemsize),
        jitted=True,   # `mamba_scan` is already jax.jit-wrapped
    )
