"""Pure-jnp oracle for the Mamba (S6) selective scan.

Diagonal state-space recurrence per channel d with state size N:

    h_t = exp(delta_t * A) * h_{t-1} + delta_t * x_t * B_t      (d, N)
    y_t = C_t . h_t + D * x_t                                    (d,)

A (d, N) is the (negative) continuous-time transition, B_t/C_t (N,) are
input-dependent projections, delta_t (d,) the input-dependent step size.
The oracle is the exact sequential lax.scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.machine import WorkCounts


def mamba_scan_ref(x, delta, a, b, c, d, state0=None):
    """x/delta (B, T, Dm), a (Dm, N), b/c (B, T, N), d (Dm,).

    Returns (y (B, T, Dm), final state (B, Dm, N) fp32).
    """
    bsz, t, dm = x.shape
    n = a.shape[1]
    f32 = jnp.float32
    x, delta, b, c = (z.astype(f32) for z in (x, delta, b, c))
    a = a.astype(f32)
    h0 = (jnp.zeros((bsz, dm, n), f32) if state0 is None
          else state0.astype(f32))

    def step(h, xs):
        xt, dt, bt, ct = xs                     # (B,Dm) (B,Dm) (B,N) (B,N)
        da = jnp.exp(dt[..., None] * a[None])   # (B, Dm, N)
        inc = (dt * xt)[..., None] * bt[:, None, :]
        h = da * h + inc
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x * d[None, None].astype(f32)
    return y.astype(x.dtype), h


def mamba_step_ref(x, delta, a, b, c, d, state):
    """Single decode step: x/delta (B, Dm), b/c (B, N), state (B, Dm, N)."""
    y, h = mamba_scan_ref(x[:, None], delta[:, None], a, b[:, None],
                          c[:, None], d, state)
    return y[:, 0], h


def counts(bsz: int, t: int, dm: int, n: int, itemsize: int = 4) -> WorkCounts:
    # per step per channel: exp+mul (2N), increment (2N), readout (2N)
    ops = 6.0 * bsz * t * dm * n
    io = (2.0 * bsz * t * dm + 2.0 * bsz * t * n) * itemsize
    return WorkCounts(ops=ops, dcache_bytes=ops / 3 * itemsize,
                      host_bytes=io, working_set=bsz * dm * n * itemsize)
