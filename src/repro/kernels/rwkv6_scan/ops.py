"""Public RWKV-6 WKV op: Pallas on TPU, chunked-einsum XLA elsewhere.

The XLA path mirrors the kernel's chunked math inside a lax.scan over
chunks (O(1) HLO in T, exact same numerics discipline), so the dry-run
compiles the same algorithm the TPU executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_dim, use_interpret
from .ref import counts, rwkv6_scan_ref, rwkv6_step_ref

__all__ = ["rwkv6_scan", "counts", "rwkv6_scan_ref", "rwkv6_step_ref"]
from .rwkv6_scan import rwkv6_scan_pallas


def _chunk_body(u, chunk):
    f32 = jnp.float32

    def body(s0, xs):
        r, k, v, w = xs                                  # (B, H, C, D)
        lw = jnp.cumsum(jnp.log(w), axis=2)
        lw_prev = lw - jnp.log(w)
        diff = lw_prev[:, :, :, None, :] - lw[:, :, None, :, :]  # (B,H,C,C,D)
        ti = jnp.arange(chunk)[:, None]
        si = jnp.arange(chunk)[None, :]
        strict = (ti > si)[None, None, :, :, None]
        decay = jnp.where(strict, jnp.exp(jnp.where(strict, diff, 0.0)), 0.0)
        a = jnp.einsum("bhti,bhtsi,bhsi->bhts", r, decay, k)
        a_diag = jnp.einsum("bhti,hi,bhti->bht", r, u, k)
        eye = (ti == si)[None, None].astype(f32)
        a = a + a_diag[..., None] * eye
        y = jnp.einsum("bhts,bhsd->bhtd", a, v)
        y = y + jnp.einsum("bhti,bhij->bhtj", r * jnp.exp(lw_prev), s0)
        w_total = jnp.exp(lw[:, :, -1])                  # (B, H, D)
        k_scaled = k * jnp.exp(lw[:, :, -1:, :] - lw)
        s = (w_total[..., :, None] * s0
             + jnp.einsum("bhti,bhtd->bhid", k_scaled, v))
        return s, y

    return body


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state0: jax.Array | None = None, *,
               chunk: int = 32, impl: str = "auto"):
    """RWKV-6 WKV over a sequence: r/k/v/w (B,H,T,D), u (H,D).

    Returns (y (B,H,T,D), final_state (B,H,D,D) fp32).  T is padded to the
    chunk size internally (w=1, k=0 padding is exact: it neither decays the
    state nor contributes outputs).
    """
    b, h, t, d = r.shape
    if impl == "auto":
        impl = "xla" if use_interpret() else "pallas"
    tp = -(-t // chunk) * chunk
    if tp != t:
        r = pad_dim(r, 2, chunk)
        k = pad_dim(k, 2, chunk)
        v = pad_dim(v, 2, chunk)
        w = pad_dim(w, 2, chunk, fill=1)
    f32 = jnp.float32
    if impl == "pallas" and state0 is None:
        y, s = rwkv6_scan_pallas(r, k, v, w, u, chunk=chunk)
    else:
        s0 = (jnp.zeros((b, h, d, d), f32) if state0 is None
              else state0.astype(f32))
        xs = tuple(
            jnp.moveaxis(x.astype(f32).reshape(b, h, tp // chunk, chunk, d),
                         2, 0) for x in (r, k, v, w))
        s, ys = jax.lax.scan(_chunk_body(u.astype(f32), chunk), s0, xs)
        y = jnp.moveaxis(ys, 0, 2).reshape(b, h, tp, d).astype(r.dtype)
    return y[:, :, :t], s
