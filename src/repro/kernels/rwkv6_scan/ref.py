"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with dims (D_k = D_v = D), data-dependent per-channel decay
w_t in (0, 1) and per-channel bonus u:

    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i, j] + u[i] * k_t[i] * v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i, j] + k_t[i] * v_t[j]

The oracle is the exact sequential scan (lax.scan over time, O(1) HLO).
All math in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.machine import WorkCounts


def rwkv6_scan_ref(r, k, v, w, u, state0=None):
    """r/k/v/w (B, H, T, D), u (H, D); returns (y (B,H,T,D), state (B,H,D,D)).

    ``state0`` (B, H, D, D) seeds the recurrence (decode / chunk chaining).
    """
    b, h, t, d = r.shape
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    s0 = (jnp.zeros((b, h, d, d), f32) if state0 is None
          else state0.astype(f32))

    def step(s, xs):
        rt, kt, vt, wt = xs                       # (B, H, D) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (r, k, v, w))
    state, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), state


def rwkv6_step_ref(r, k, v, w, u, state):
    """Single decode step: r/k/v/w (B, H, D), state (B, H, D, D)."""
    y, s = rwkv6_scan_ref(r[:, :, None], k[:, :, None], v[:, :, None],
                          w[:, :, None], u, state)
    return y[:, :, 0], s


def counts(b: int, h: int, t: int, d: int, itemsize: int = 4) -> WorkCounts:
    # per step: kv outer (D^2), state update (2 D^2), readout (2 D^2)
    ops = 5.0 * b * h * t * d * d
    io = 4.0 * b * h * t * d * itemsize
    return WorkCounts(ops=ops, dcache_bytes=ops / 5 * itemsize,
                      host_bytes=io, working_set=b * h * d * d * itemsize)
