"""Chunked RWKV-6 WKV Pallas TPU kernel.

The recurrence is linear in the state, so a chunk of C steps reduces to
matmuls (the chunked linear-attention form), with the (D x D) state carried
across chunks in VMEM scratch — the grid is (B, H, T/C) with the time axis
innermost (sequential on TPU).

Numerical safety: all decay products are expressed relative to the *later*
timestep, i.e. every exponential is exp(negative cumulative log-decay) <= 1,
so nothing overflows regardless of chunk length:

    Lw[t]  = sum_{s<=t} log w_s                     (<= 0, per channel)
    intra  A[t,s] = sum_i r_t[i] k_s[i] e^{Lw[t-1,i] - Lw[s,i]}   (s < t)
    diag   A[t,t] = sum_i r_t[i] u[i] k_t[i]
    y      = A @ v + (r * e^{Lw_prev}) @ S
    S'     = e^{Lw[C-1]} (x) S + sum_s (e^{Lw[C-1] - Lw[s]} * k_s) (x) v_s

The (C, C, D) pairwise-decay tensor stays tiny (C = 32, D = 64 → 512 KiB of
fp32 in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref,
                  *, chunk: int, steps: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    f32 = jnp.float32
    r = r_ref[0, 0].astype(f32)          # (C, D)
    k = k_ref[0, 0].astype(f32)
    v = v_ref[0, 0].astype(f32)
    w = w_ref[0, 0].astype(f32)
    u = u_ref[0].astype(f32)             # (1, D)

    lw = jnp.cumsum(jnp.log(w), axis=0)              # (C, D), <= 0
    lw_prev = lw - jnp.log(w)                        # exclusive cumsum
    # pairwise decay e^{Lw[t-1] - Lw[s]} for s < t, strictly causal
    diff = lw_prev[:, None, :] - lw[None, :, :]      # (C, C, D)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (ti > si)[:, :, None]
    decay = jnp.where(strict, jnp.exp(jnp.where(strict, diff, 0.0)), 0.0)
    a = jnp.einsum("ti,tsi,si->ts", r, decay, k)     # strictly-lower triangle
    a_diag = jnp.sum(r * u * k, axis=1)              # (C,)
    a = a + a_diag[:, None] * (ti == si).astype(f32)
    y_intra = jnp.dot(a, v, preferred_element_type=f32)

    s0 = s_ref[...]                                  # (D, D)
    y_state = jnp.dot(r * jnp.exp(lw_prev), s0, preferred_element_type=f32)
    y_ref[0, 0] = (y_intra + y_state).astype(y_ref.dtype)

    w_total = jnp.exp(lw[-1])                        # (D,)
    k_scaled = k * jnp.exp(lw[-1][None, :] - lw)     # (C, D), <= k
    s_ref[...] = w_total[:, None] * s0 + jnp.dot(
        k_scaled.T, v, preferred_element_type=f32)

    @pl.when(t_idx == steps - 1)
    def _store_state():
        sout_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array,
                      w: jax.Array, u: jax.Array, *, chunk: int = 32):
    """r/k/v/w (B, H, T, D), u (H, D); T % chunk == 0.

    Returns (y (B, H, T, D), final state (B, H, D, D) fp32).
    """
    b, h, t, d = r.shape
    assert t % chunk == 0, (t, chunk)
    steps = t // chunk
    grid = (b, h, steps)
    y, s = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk, steps=steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, i: (h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, i: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=use_interpret(),
    )(r, k, v, w, u)
    return y, s
