"""jit'd public wrapper for the Stockham FFT + TinyCL registration."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from .ref import counts as fft_counts, stockham_fft_ref
from .stockham_fft import fft_pallas


@jax.jit
def fft(re: jax.Array, im: jax.Array | None = None):
    """FFT of a 1-D (or batched 2-D) signal; returns (re, im)."""
    if im is None:
        im = jnp.zeros_like(re)
    squeeze = re.ndim == 1
    if squeeze:
        re, im = re[None, :], im[None, :]
    ore, oim = fft_pallas(re, im)
    return (ore[0], oim[0]) if squeeze else (ore, oim)


def power_spectrum(x: jax.Array) -> jax.Array:
    """|FFT|^2 — the frequency-domain features of the TinyBio pipeline."""
    re, im = fft(x.astype(jnp.float32))
    return re * re + im * im


@kernel_family("stockham_fft")
def build_kernel(config: EGPUConfig = EGPU_16T, *,
                 use_pallas: bool = True) -> Kernel:
    def ref_exec(re, im=None):
        if im is None:
            im = jnp.zeros_like(re)
        return stockham_fft_ref(re, im)
    return Kernel(
        name="stockham_fft",
        executor=fft if use_pallas else ref_exec,
        counts=lambda n, itemsize=4: fft_counts(n, itemsize),
        jitted=use_pallas,   # `fft` is already jax.jit-wrapped
    )
