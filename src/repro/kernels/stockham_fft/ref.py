"""Pure-jnp Stockham FFT oracle + counts (TinyBio feature extraction).

The paper motivates Stockham (§VIII-C): no bit-reversal permutation, a
ping-pong buffer between stages, regular sequential accesses at every stage,
output already in order.  The vectorized recurrence (Van Loan form):

view X as (2r, l)  [initially (n, 1)]:
    a, b = X[:r], X[r:]
    w_j  = exp(-i * pi * j / l),  j = 0..l-1
    X'   = concat([a + w*b, a - w*b], axis=1)      # shape (r, 2l)

After log2(n) stages X has shape (1, n) and *is* the DFT, in order.  Real and
imaginary parts are kept as separate float32 arrays (TPU-native; Pallas has
no complex dtype).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from ...core.machine import WorkCounts


def stockham_stage(re, im, wr, wi):
    """One radix-2 Stockham stage on (2r, l)-shaped re/im planes."""
    r = re.shape[0] // 2
    ar, ai = re[:r], im[:r]
    br, bi = re[r:], im[r:]
    tr = wr * br - wi * bi
    ti = wr * bi + wi * br
    out_re = jnp.concatenate([ar + tr, ar - tr], axis=1)
    out_im = jnp.concatenate([ai + ti, ai - ti], axis=1)
    return out_re, out_im


def twiddles(l: int):
    j = jnp.arange(l, dtype=jnp.float32)
    ang = -math.pi * j / l
    return jnp.cos(ang), jnp.sin(ang)


def stockham_fft_ref(re: jnp.ndarray, im: jnp.ndarray):
    """Full FFT; re/im are 1-D float arrays of power-of-two length."""
    n = re.shape[0]
    stages = n.bit_length() - 1
    assert 1 << stages == n, f"n={n} must be a power of two"
    re = re.astype(jnp.float32).reshape(n, 1)
    im = im.astype(jnp.float32).reshape(n, 1)
    for _ in range(stages):
        l = re.shape[1]
        wr, wi = twiddles(l)
        re, im = stockham_stage(re, im, wr, wi)
    return re.reshape(n), im.reshape(n)


OPS_PER_BUTTERFLY = 10  # 4 mul + 6 add/sub (complex twiddle + butterfly)


# Power-of-two butterfly strides hit the line-interleaved banks
# periodically: ~1.5x effective D$ traffic from serialized conflicts.
BANK_CONFLICT = 1.5


def counts(n: int, itemsize: int = 4) -> WorkCounts:
    stages = int(math.log2(n))
    ops = (n / 2) * stages * OPS_PER_BUTTERFLY
    # ping-pong: every stage reads and writes both planes
    dcache = stages * (2.0 * n * itemsize) * 2 * BANK_CONFLICT
    host = 4.0 * n * itemsize           # re/im in + re/im out
    return WorkCounts(ops=ops, dcache_bytes=dcache, host_bytes=host,
                      working_set=4.0 * n * itemsize, barriers=stages)
