"""Stockham FFT Pallas kernel — the whole transform VMEM-resident.

TPU adaptation of the paper's §VIII-C kernel: the e-GPU ping-pongs between
two D$-resident buffers with a barrier per stage; on TPU the natural
equivalent is to keep both planes in VMEM for the entire transform and unroll
the log2(n) stages inside a single pallas_call — the "barrier" becomes the
SSA dependency between stages, and the ping-pong becomes value renaming.
This removes every HBM round-trip between stages (the optimization the paper
gets from cache residency, §IV-B).

The grid runs over a batch of independent signals; each grid step transforms
one signal of length ``n`` (n * 16 B of VMEM for re/im + twiddles — up to
n = 64k fits comfortably).  Twiddles are computed in-kernel from iota, so the
kernel has no side tables to DMA.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import use_interpret


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, n: int):
    stages = n.bit_length() - 1
    re = re_ref[...].reshape(n, 1)
    im = im_ref[...].reshape(n, 1)
    for _ in range(stages):
        l = re.shape[1]
        r = re.shape[0] // 2
        # twiddles from 2-D iota (TPU requires >= 2-D): angle = -pi * j / l
        j = jax.lax.broadcasted_iota(jnp.float32, (1, l), 1)
        ang = (-math.pi / l) * j
        wr, wi = jnp.cos(ang), jnp.sin(ang)
        ar, ai = re[:r], im[:r]
        br, bi = re[r:], im[r:]
        tr = wr * br - wi * bi
        ti = wr * bi + wi * br
        re = jnp.concatenate([ar + tr, ar - tr], axis=1)
        im = jnp.concatenate([ai + ti, ai - ti], axis=1)
    ore_ref[...] = re.reshape(1, n)
    oim_ref[...] = im.reshape(1, n)


@functools.partial(jax.jit, static_argnames=())
def fft_pallas(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched FFT: re/im shaped (batch, n), n a power of two."""
    b, n = re.shape
    assert 1 << (n.bit_length() - 1) == n, f"n={n} must be a power of two"
    grid = (b,)
    kernel = functools.partial(_fft_kernel, n=n)
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                   pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, n), jnp.float32),
                   jax.ShapeDtypeStruct((b, n), jnp.float32)],
        interpret=use_interpret(),
    )(re.astype(jnp.float32), im.astype(jnp.float32))
    return ore, oim
