"""jit'd public wrapper for the SVM kernel + TinyCL registration."""

from __future__ import annotations

import functools

import jax

from ...core.device import EGPU_16T, EGPUConfig
from ...core.program import kernel_family
from ...core.runtime import Kernel
from ..common import pad_dim
from .ref import counts as svm_counts, svm_decision_ref
from .svm import svm_pallas


@functools.partial(jax.jit, static_argnames=("gamma",))
def svm_decision(x: jax.Array, sv: jax.Array, alpha: jax.Array, b,
                 gamma: float | None = None) -> jax.Array:
    """Decision values for any (q, d) x (m, d); pads q to 8 and m to 128
    (padded support vectors carry alpha = 0, so they contribute nothing)."""
    q, d = x.shape
    m = sv.shape[0]
    xp = pad_dim(x, 0, 8)
    svp = pad_dim(sv, 0, 128)
    ap = pad_dim(alpha, 0, 128)
    out = svm_pallas(xp, svp, ap, bq=xp.shape[0], bm=128, gamma=gamma)
    return out[:q] + b


@kernel_family("svm")
def build_kernel(config: EGPUConfig = EGPU_16T, *,
                 use_pallas: bool = True) -> Kernel:
    exe = svm_decision if use_pallas else svm_decision_ref
    return Kernel(
        name="svm",
        executor=exe,
        counts=lambda q, m, d, itemsize=4, rbf=True: svm_counts(q, m, d, itemsize, rbf),
        jitted=use_pallas,   # `svm_decision` is already jax.jit-wrapped
    )
