"""Pure-jnp oracle + counts for the SVM decision function (TinyBio stage 4).

MBio-Tracker's final stage classifies cognitive workload from the extracted
features with a support vector machine.  We implement the kernelized decision
function

    f(x) = sum_i alpha_i * K(sv_i, x) + b

for linear (K = <sv, x>) and RBF (K = exp(-gamma * ||sv - x||^2)) kernels.
The distance matrix is computed MXU-style: ||a-b||^2 = |a|^2 + |b|^2 - 2 a.b,
so the hot loop is a GEMM — the same compute structure the Pallas kernel
tiles.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.machine import WorkCounts


def svm_decision_ref(x: jnp.ndarray, sv: jnp.ndarray, alpha: jnp.ndarray,
                     b, gamma: float | None = None) -> jnp.ndarray:
    """Decision values for queries ``x`` (q, d) against support vectors
    ``sv`` (m, d) with dual coefficients ``alpha`` (m,).  ``gamma=None``
    selects the linear kernel."""
    x = x.astype(jnp.float32)
    sv = sv.astype(jnp.float32)
    dots = x @ sv.T                                    # (q, m) — the GEMM
    if gamma is None:
        k = dots
    else:
        d2 = (jnp.sum(x * x, axis=1, keepdims=True)
              + jnp.sum(sv * sv, axis=1)[None, :] - 2.0 * dots)
        k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    return k @ alpha.astype(jnp.float32) + b


def svm_predict_ref(x, sv, alpha, b, gamma=None) -> jnp.ndarray:
    return (svm_decision_ref(x, sv, alpha, b, gamma) > 0).astype(jnp.int32)


def counts(q: int, m: int, d: int, itemsize: int = 4,
           rbf: bool = True) -> WorkCounts:
    macs = float(q) * m * d                      # the distance/dot GEMM
    extra = float(q) * m * (6 if rbf else 1)     # norms, exp, alpha reduce
    host = (q * d + m * (d + 1) + q) * itemsize
    return WorkCounts(ops=macs + extra, dcache_bytes=2.0 * macs / 4 * itemsize,
                      host_bytes=host, working_set=host)
