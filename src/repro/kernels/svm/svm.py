"""SVM decision-function Pallas kernel (RBF / linear).

Grid over support-vector blocks (the reduction axis): each step computes a
(q_block x m_block) kernel-matrix tile from a dots GEMM on the MXU plus VPU
exp, then accumulates ``K_tile @ alpha_tile`` into a VMEM scratch — so the
full kernel matrix never materializes in HBM, mirroring the D$-resident
discipline of the paper's kernels (§IV-B).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import use_interpret


def _svm_kernel(x_ref, xsq_ref, sv_ref, svsq_ref, a_ref, o_ref, acc_ref, *,
                steps: int, gamma: float | None):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                       # (bq, d)
    sv = sv_ref[...]                     # (bm, d)
    dots = jnp.dot(x, sv.T, preferred_element_type=jnp.float32)
    if gamma is None:
        k = dots
    else:
        d2 = xsq_ref[...] + svsq_ref[...] - 2.0 * dots   # (bq,1)+(1,bm)
        k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    # masked alpha (padding rows carry alpha = 0) folds the tail for free
    acc_ref[...] += jnp.dot(k, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == steps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bq", "bm", "gamma"))
def svm_pallas(x: jax.Array, sv: jax.Array, alpha: jax.Array,
               *, bq: int = 8, bm: int = 128,
               gamma: float | None = None) -> jax.Array:
    """Sum_i alpha_i K(sv_i, x) for padded shapes: x (q, d), sv (m, d),
    alpha (m, 1); q % bq == 0, m % bm == 0."""
    q, d = x.shape
    m, _ = sv.shape
    assert q % bq == 0 and m % bm == 0, (x.shape, sv.shape, bq, bm)
    steps = m // bm
    xsq = jnp.sum(x * x, axis=1, keepdims=True)          # (q, 1)
    svsq = jnp.sum(sv * sv, axis=1)[None, :]             # (1, m)
    out = pl.pallas_call(
        functools.partial(_svm_kernel, steps=steps, gamma=gamma),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda s: (0, 0)),
            pl.BlockSpec((bq, 1), lambda s: (0, 0)),
            pl.BlockSpec((bm, d), lambda s: (s, 0)),
            pl.BlockSpec((1, bm), lambda s: (0, s)),
            pl.BlockSpec((bm, 1), lambda s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=use_interpret(),
    )(x.astype(jnp.float32), xsq, sv.astype(jnp.float32), svsq,
      alpha.reshape(m, 1).astype(jnp.float32))
    return out[:, 0]
