"""repro.launch — production mesh, multi-pod dry-run, trainer."""
