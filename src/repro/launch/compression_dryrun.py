import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Cross-pod gradient-compression dry-run: int8 wire vs f32 all-reduce.

Lowers two versions of the cross-pod gradient mean on the multi-pod
(pod=2, data=16, model=16) mesh for a representative sharded gradient
bundle (64M params ~ one jamba layer-group shard):

* plain:      psum(grads) / 2 over "pod" (f32 ring all-reduce)
* compressed: repro.distributed.compression.compressed_psum (int8 gather
              + per-sender scales + error feedback)

and compares the per-device link bytes from the HLO.  Writes
artifacts/dryrun/grad_compression__multipod.json — referenced by
EXPERIMENTS §Perf (jamba O3).
"""

import json

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.compression import compressed_psum
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh

OUT = "artifacts/dryrun/grad_compression__multipod.json"


def main():
    mesh = make_production_mesh(multi_pod=True)
    # one gradient bundle: (8192, 8192) sharded (data, model) per pod
    g = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)
    e = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)
    spec = P("data", "model")
    sh = NamedSharding(mesh, spec)

    def plain(gg, ee):
        def body(x):
            return jax.lax.pmean(x, "pod")
        fn = shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                       out_specs=P("data", "model"), check_rep=False)
        return fn(gg), ee

    def compressed(gg, ee):
        def body(x, err):
            out, new_err = compressed_psum({"g": x}, {"g": err}, "pod")
            return out["g"], new_err["g"]
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P("data", "model"), P("data", "model")),
                       out_specs=(P("data", "model"), P("data", "model")),
                       check_rep=False)
        return fn(gg, ee)

    rec = {}
    for name, fn in (("plain_f32_allreduce", plain),
                     ("int8_gather_error_feedback", compressed)):
        with mesh:
            compiled = jax.jit(fn, in_shardings=(sh, sh)).lower(g, e).compile()
        cost = analyze_hlo(compiled.as_text())
        rec[name] = {
            "link_bytes_per_device": cost["total_link_bytes"],
            "by_kind": cost["collective_link_bytes"],
        }
        print(f"{name:30s} link bytes/device: "
              f"{cost['total_link_bytes']/1e6:9.2f} MB "
              f"{cost['collective_link_bytes']}")
    ratio = (rec["plain_f32_allreduce"]["link_bytes_per_device"]
             / max(rec["int8_gather_error_feedback"]
                   ["link_bytes_per_device"], 1))
    rec["wire_reduction_x"] = ratio
    print(f"cross-pod wire reduction: {ratio:.2f}x")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
