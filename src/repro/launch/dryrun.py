import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(*abstract_args).compile()`` must succeed on
the single-pod (16, 16) mesh AND the multi-pod (2, 16, 16) mesh for every
live cell, and the compiled artifact yields the roofline inputs:

* ``compiled.memory_analysis()``  — per-device bytes (proves it fits);
* ``compiled.cost_analysis()``    — FLOPs / bytes for the compute & memory
  roofline terms;
* ``compiled.as_text()``          — collective ops parsed by
  :mod:`repro.launch.hlo_stats` for the collective term.

Artifacts are cached to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``;
``benchmarks/bench_roofline.py`` and EXPERIMENTS.md read from there, so
nothing ever recompiles twice.

NOTE the two lines above this docstring: 512 placeholder host devices MUST
be requested before jax (transitively) initializes — and must NOT leak into
conftest/pyproject, where smoke tests expect 1 device.
"""

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, cells, input_specs
from ..distributed.sharding import (SERVE_RULES, ShardingRules,
                                    activate, param_specs, spec_for,
                                    train_rules_for)
from ..models.config import ModelConfig
from ..models.params import abstract_params
from ..models.transformer import cache_axes, cache_struct, model_spec
from ..optim import wsd_schedule
from ..train.serve import make_decode_step, make_prefill_step
from ..train.step import TrainConfig, make_train_step
from .hlo_cost import analyze_hlo, cpu_f32_shadow_bytes
from .mesh import make_production_mesh

DEFAULT_OUT = "artifacts/dryrun"

# TPU v5e constants (per chip) for the roofline terms recorded alongside.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


# ---------------------------------------------------------------------------
# Per-cell execution regime (remat / microbatching / dtypes)
# ---------------------------------------------------------------------------
def cell_train_config(cfg: ModelConfig) -> TrainConfig:
    """Scale remat & microbatching with model size so every cell fits v5e.

    remat="full" per layer group everywhere: the only fwd→bwd residual is
    the per-group carry (B_local x S x D bf16 per group), and microbatching
    bounds even that ("dots" saves every projection output across the scan —
    measured 3x the temp bytes on stablelm train_4k, see EXPERIMENTS §Perf).
    jamba-398B additionally stores params/grads in bf16 (DESIGN.md §5:
    12 B/param fp32-Adam does not fit 16 GiB at 398B/256 chips; bf16 params
    + bf16 moments = 6 B/param does).
    """
    n = cfg.param_count()
    if n > 3e11:
        # ub=8 (not 16): grads reduce-scatter once per microbatch, so fewer
        # microbatches halve the dominant gradient-reduction traffic
        # (jamba train_4k: 178s -> measured below in §Perf) while remat
        # carries stay ~1.2 GiB
        return TrainConfig(remat="full", microbatches=8,
                           param_dtype="bfloat16")
    if n > 1e11:
        # deep stacks (88L mistral / 60L deepseek): per-layer remat carries
        # are n_groups x (B_ub/16, S, D) bf16 — 1 seq/device per microbatch
        # keeps them under ~9 GiB
        return TrainConfig(remat="full", microbatches=16)
    if n > 2e10:
        return TrainConfig(remat="full", microbatches=4)
    # small models run pure-DP over all chips: the per-microbatch batch must
    # stay >= the 256-way batch sharding, so no microbatching here (B=256)
    return TrainConfig(remat="full", microbatches=1)


def _axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def _ns(mesh, logical, shape) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical, None, mesh, tuple(shape)))


def _batch_shardings(batch_struct, mesh, rules, *, shard_seq: bool):
    out = {}
    for k, st in batch_struct.items():
        if st.ndim == 1:
            logical = ("batch",)
        elif k in ("patches",):
            logical = ("batch", None, None)
        elif st.ndim == 3:          # frames
            logical = ("batch", "seq" if shard_seq else None, None)
        else:                       # tokens / labels (B, S)
            logical = ("batch", "seq" if shard_seq else None)
        with activate(rules, mesh):
            out[k] = _ns(mesh, logical, st.shape)
    return out


def _tree_shardings(axes_tree, struct_tree, mesh, rules):
    with activate(rules, mesh):
        return jax.tree_util.tree_map(
            lambda ax, st: _ns(mesh, ax, st.shape),
            axes_tree, struct_tree, is_leaf=_axes_leaf)


def _replicated_like(struct_tree, mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), struct_tree)


# ---------------------------------------------------------------------------
# Cell builders: return (jitted_fn, args_structs)
# ---------------------------------------------------------------------------
def build_train_cell(cfg: ModelConfig, shape, mesh, rules: ShardingRules,
                     tcfg: Optional[TrainConfig] = None):
    tcfg = tcfg or cell_train_config(cfg)
    spec_tree = model_spec(cfg)
    pdtype = jnp.dtype(tcfg.param_dtype)
    params_struct = abstract_params(spec_tree, dtype=pdtype)
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), params_struct)
    state_struct = {"params": params_struct,
                    "opt": {"m": mom, "v": mom,
                            "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    p_specs = param_specs(spec_tree, rules, mesh)
    p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), p_specs)
    state_sh = {"params": p_sh,
                "opt": {"m": p_sh, "v": p_sh,
                        "step": NamedSharding(mesh, P())}}
    batch_struct = input_specs(cfg.name, shape.name)
    batch_sh = _batch_shardings(batch_struct, mesh, rules, shard_seq=False)

    step = make_train_step(cfg, tcfg, wsd_schedule(3e-4, 10_000))

    def wrapped(state, batch):
        with activate(rules, mesh):
            return step(state, batch)

    _, metrics_struct = jax.eval_shape(wrapped, state_struct, batch_struct)
    out_sh = (state_sh, _replicated_like(metrics_struct, mesh))
    jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                     out_shardings=out_sh, donate_argnums=(0,))
    return jitted, (state_struct, batch_struct), tcfg


def build_prefill_cell(cfg: ModelConfig, shape, mesh, rules: ShardingRules):
    spec_tree = model_spec(cfg)
    params_struct = abstract_params(spec_tree, dtype=jnp.bfloat16)
    p_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(spec_tree, rules, mesh))
    batch_struct = input_specs(cfg.name, shape.name)
    batch_sh = _batch_shardings(batch_struct, mesh, rules, shard_seq=True)

    fn = make_prefill_step(cfg, max_len=shape.seq_len)

    def wrapped(params, inputs):
        with activate(rules, mesh):
            return fn(params, inputs)

    out_struct = jax.eval_shape(wrapped, params_struct, batch_struct)
    with activate(rules, mesh):
        if cfg.is_encoder:
            out_sh = _ns(mesh, ("batch", "seq", "vocab"), out_struct.shape)
        else:
            logits_struct, cache_out_struct = out_struct
            logits_sh = _ns(mesh, ("batch", "vocab"), logits_struct.shape)
            cache_sh = _tree_shardings(cache_axes(cfg), cache_out_struct,
                                       mesh, rules)
            out_sh = (logits_sh, cache_sh)
    jitted = jax.jit(wrapped, in_shardings=(p_sh, batch_sh),
                     out_shardings=out_sh)
    return jitted, (params_struct, batch_struct), None


def build_decode_cell(cfg: ModelConfig, shape, mesh, rules: ShardingRules):
    spec_tree = model_spec(cfg)
    params_struct = abstract_params(spec_tree, dtype=jnp.bfloat16)
    p_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(spec_tree, rules, mesh))
    b, t = shape.global_batch, shape.seq_len
    c_struct = cache_struct(cfg, b, t)
    c_sh = _tree_shardings(cache_axes(cfg), c_struct, mesh, rules)
    tok_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    fn = make_decode_step(cfg)

    def wrapped(params, cache, tokens, pos):
        with activate(rules, mesh):
            return fn(params, cache, tokens, pos)

    with activate(rules, mesh):
        tok_sh = _ns(mesh, ("batch",), (b,))
        _, logits_struct, _ = jax.eval_shape(
            wrapped, params_struct, c_struct, tok_struct, pos_struct)
        logits_sh = _ns(mesh, ("batch", "vocab"), logits_struct.shape)
    out_sh = (tok_sh, logits_sh, c_sh)
    jitted = jax.jit(wrapped,
                     in_shardings=(p_sh, c_sh, tok_sh,
                                   NamedSharding(mesh, P())),
                     out_shardings=out_sh, donate_argnums=(1,))
    return jitted, (params_struct, c_struct, tok_struct, pos_struct), None


BUILDERS = {"train": build_train_cell, "prefill": build_prefill_cell,
            "decode": build_decode_cell}


# ---------------------------------------------------------------------------
# Run one cell: lower, compile, extract roofline inputs
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = DEFAULT_OUT, rules: Optional[ShardingRules] = None,
             tag: str = "", force: bool = False,
             keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    if rules is None:
        rules = (train_rules_for(cfg.param_count())
                 if shape.kind == "train" else SERVE_RULES)

    t0 = time.perf_counter()
    builder = BUILDERS[shape.kind]
    jitted, arg_structs, tcfg = builder(cfg, shape, mesh, rules)
    with mesh:
        lowered = jitted.lower(*arg_structs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"[{cell_id}] memory_analysis: {mem}", flush=True)   # proves fit
    xla_cost = compiled.cost_analysis()
    xla_cost = xla_cost[0] if isinstance(xla_cost, list) else xla_cost
    print(f"[{cell_id}] cost_analysis: flops={xla_cost.get('flops')} "
          f"bytes={xla_cost.get('bytes accessed')} (raw XLA; scan-aware "
          "figures in the artifact)", flush=True)
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)       # scan-aware (cost_analysis counts a
    #                               while body ONCE — see hlo_cost.py)

    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    n_active = cfg.active_param_count()
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens

    record: Dict[str, Any] = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "n_devices": n_dev, "kind": shape.kind,
        "rules": rules.name,
        "train_config": (dataclass_dict(tcfg) if tcfg else None),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            # CPU-only f32 twins of bf16 buffers (no native bf16 dot on
            # this host); they do not exist on the TPU target:
            "cpu_f32_shadow_bytes": cpu_f32_shadow_bytes(hlo),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"flops": cost["flops"],
                 "bytes_accessed": cost["bytes_accessed"],
                 "transcendentals": cost["transcendentals"],
                 "unknown_trip_counts": cost["unknown_trip_counts"]},
        "xla_cost_raw": {"flops": xla_cost.get("flops"),
                         "bytes_accessed": xla_cost.get("bytes accessed")},
        "collectives": {
            "total_link_bytes": cost["total_link_bytes"],
            "by_kind": cost["collective_link_bytes"],
            "by_group_size": cost["collective_by_group_size"]},
        "model_flops_global": model_flops,
        "tokens": tokens,
        "params_total": cfg.param_count(),
        "params_active": n_active,
    }
    m = record["memory"]
    if m["argument_bytes"] is not None:
        m["tpu_projected_bytes"] = (m["argument_bytes"] + m["temp_bytes"]
                                    - m["cpu_f32_shadow_bytes"])
    record["memory_budget"] = analytic_memory_budget(
        cfg, shape, mesh, rules, tcfg)
    record["roofline"] = roofline_terms(record)
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if keep_hlo:
        with open(os.path.join(out_dir, cell_id + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return record


def dataclass_dict(tcfg: TrainConfig) -> Dict[str, Any]:
    return {"remat": tcfg.remat, "microbatches": tcfg.microbatches,
            "param_dtype": tcfg.param_dtype}


def analytic_memory_budget(cfg: ModelConfig, shape, mesh, rules,
                           tcfg: Optional[TrainConfig]) -> Dict[str, float]:
    """Exact per-device HBM budget from configs + sharding rules.

    ``compiled.memory_analysis()`` on this CPU host includes f32 shadows of
    every bf16 dot operand and backend scheduling transients that do not
    exist on the TPU target, so the deployment budget is computed
    analytically: each parameter leaf's bytes are divided by its actual
    shard count (via param_specs), optimizer/grads follow the params, and
    the activation terms follow the remat/microbatch policy.  This is the
    "fits in 16 GiB" evidence in EXPERIMENTS §Dry-run.
    """
    import numpy as np
    from ..models.params import is_spec

    spec_tree = model_spec(cfg)
    specs = param_specs(spec_tree, rules, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def shards(pspec):
        n = 1
        for entry in pspec:
            if entry is None:
                continue
            for ax in ((entry,) if isinstance(entry, str) else entry):
                n *= sizes.get(ax, 1)
        return n

    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    pspecs = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: hasattr(x, "index") or x.__class__.__name__ == "PartitionSpec")
    param_elems_sharded = sum(float(np.prod(s.shape)) / shards(ps)
                              for s, ps in zip(leaves, pspecs))
    nonexpert_group = sum(
        float(np.prod(s.shape)) / max(1, sizes.get("model", 1))
        for s, ps in zip(leaves, pspecs)
        if "expert" not in (s.axes or ()) and "layers" in (s.axes or ())
    ) / max(1, cfg.n_groups)

    out: Dict[str, float] = {}
    gib = 2.0 ** 30
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.kind == "train":
        pbytes = 4 if tcfg.param_dtype == "float32" else 2
        out["params"] = param_elems_sharded * pbytes / gib
        out["adam_moments"] = param_elems_sharded * 2 * 2 / gib
        out["grads"] = param_elems_sharded * pbytes / gib
        b_local = max(1, shape.global_batch // tcfg.microbatches // dp)
        out["remat_carries"] = (cfg.n_groups * b_local * shape.seq_len
                                * cfg.d_model * 2) / gib
        out["gathered_group_weights_x2"] = nonexpert_group * 2 * 2 / gib
        out["logits_ub"] = (b_local * shape.seq_len
                            * cfg.vocab_padded / max(1, sizes.get("model", 1))
                            * 4) / gib
    else:
        out["params"] = param_elems_sharded * 2 / gib
        if shape.kind == "decode":
            cache = cache_struct(cfg, shape.global_batch, shape.seq_len)
            c_leaves = jax.tree_util.tree_leaves(cache)
            ax_leaves = jax.tree_util.tree_leaves(cache_axes(cfg),
                                                  is_leaf=_axes_leaf)
            total = 0.0
            for st, ax in zip(c_leaves, ax_leaves):
                ps = spec_for(ax, rules, mesh, st.shape)
                total += (float(np.prod(st.shape)) * st.dtype.itemsize
                          / shards(ps))
            out["cache"] = total / gib
        else:
            b_local = max(1, shape.global_batch // dp)
            s_local = shape.seq_len // max(1, sizes.get("model", 1))
            out["activations"] = (4 * b_local * s_local * cfg.d_model * 2
                                  ) / gib
        if shape.kind == "prefill" and not cfg.is_encoder:
            cache = cache_struct(cfg, shape.global_batch, shape.seq_len)
            c_leaves = jax.tree_util.tree_leaves(cache)
            ax_leaves = jax.tree_util.tree_leaves(cache_axes(cfg),
                                                  is_leaf=_axes_leaf)
            total = 0.0
            for st, ax in zip(c_leaves, ax_leaves):
                ps = spec_for(ax, rules, mesh, st.shape)
                total += (float(np.prod(st.shape)) * st.dtype.itemsize
                          / shards(ps))
            out["cache_out"] = total / gib
    out["total_gib"] = round(sum(out.values()), 2)
    return {k: round(v, 3) for k, v in out.items()}


def roofline_terms(rec: Dict[str, Any]) -> Dict[str, Any]:
    """The three roofline terms in seconds (per §Roofline).

    ``cost_analysis`` FLOPs/bytes are per-device post-SPMD, so the per-chip
    division is already applied; collective link bytes are per device too.
    """
    n = rec["n_devices"]
    flops = rec["cost"]["flops"] or 0.0
    bytes_acc = rec["cost"]["bytes_accessed"] or 0.0
    link = rec["collectives"]["total_link_bytes"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = link / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    useful = rec["model_flops_global"] / max(flops * n, 1.0)
    bound = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_ratio": useful,
        "roofline_fraction": t_compute / bound if bound else 0.0,
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, help="shape name (or all)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    grid = [(a, s) for a, s, _ in cells()
            if (args.arch in (None, "all", a))
            and (args.shape in (None, "all", s))]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}
    failures = []
    for arch, shape in grid:
        for mp in meshes[args.mesh]:
            name = f"{arch} x {shape} x {'multipod' if mp else 'pod'}"
            try:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                               force=args.force, keep_hlo=args.keep_hlo)
                r = rec["roofline"]
                print(f"[ok] {name}: compile={rec['compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"t=({r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                      f"{r['t_collective_s']:.3e})s", flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((name, repr(e)))
                print(f"[FAIL] {name}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} cell(s) failed:")
        for n, e in failures:
            print(" -", n, e)
        raise SystemExit(1)
    print(f"\nall {len(grid) * len(meshes[args.mesh])} cells compiled")


if __name__ == "__main__":
    main()
