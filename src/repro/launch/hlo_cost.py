"""Scan-aware HLO cost analysis (flops / bytes / collectives).

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program (every model here — that is what keeps HLO O(1) in
depth) is undercounted by the trip count (verified: a 10-step scanned matmul
reports the flops of one).  This module re-derives the roofline inputs from
``compiled.as_text()`` with the call graph walked properly:

* computations are parsed into symbol tables (every op line defines
  ``%name = shape opcode(operands), attrs``);
* ``while`` call sites multiply their body/condition cost by the
  ``known_trip_count`` XLA attaches after loop analysis;
* ``fusion`` call sites add the fused computation's *flops* but only the
  call-site operand/result *bytes* (fused intermediates never touch HBM —
  the same convention XLA's own model uses);
* dots count 2·numel(out)·K (K = contracted extent read from
  ``lhs_contracting_dims`` + the lhs operand's shape); elementwise and
  transcendental ops count 1/element; reduces count the operand;
* ``dynamic-(update-)slice`` count the slice twice (in-place aliasing), not
  the whole buffer — otherwise every KV-cache update would look like a full
  cache rewrite;
* collectives convert to per-device link bytes with ring accounting
  (all-gather (N-1)/N·out; reduce-scatter (N-1)/N·in; all-reduce 2×;
  all-to-all (N-1)/N·in; collective-permute 1×), with N parsed per op from
  ``replica_groups`` — in-pod (N=16) and cross-pod (N=2) hops are separated
  — and each multiplied by its enclosing loops' trip counts.

The result is the profile the §Perf loop iterates on (this container has no
TPU wall clock; the lowered IR *is* the profile).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP_JSON_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_TRIP_PLAIN_RE = re.compile(r"known_trip_count=\{n=(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "not", "negate", "abs", "select", "compare", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "atan2", "is-finite",
}
TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sqrt", "rsqrt", "power", "logistic", "sine", "cosine", "cbrt", "erf",
    "erf-inv", "expm1", "log1p",
}
ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "iota", "after-all", "reshape", "transpose", "broadcast", "copy",
    "convert", "reverse", "rng-bit-generator", "rng", "partition-id",
    "replica-id", "opt-barrier", "custom-call", "domain", "slice", "pad",
    "concatenate", "reduce", "reduce-window", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "sort", "map", "clz",
    "popcnt", "stochastic-convert", "cholesky", "triangular-solve", "fft",
    "get-dimension-size", "bitcast-convert", "real", "imag", "complex",
}
# ops whose bytes we skip (views / control / handled at child level)
NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "opt-barrier", "domain",
    "get-dimension-size", "partition-id", "replica-id",
}
COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"}


def _split_shape_opcode(rhs: str) -> Tuple[str, str, str]:
    """'(f32[..],..) tuple(%a)' | 'f32[..]{1,0} dot(%a, %b), attrs'
    -> (shape_text, opcode, rest_after_open_paren)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                shape, rest = rhs[:i + 1], rhs[i + 1:]
                break
        else:
            return rhs, "", ""
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, "", ""
        shape, rest = rhs[:sp], rhs[sp:]
    rest = rest.strip()
    par = rest.find("(")
    if par < 0:
        return shape, rest, ""
    return shape, rest[:par].strip(), rest[par + 1:]


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_numel(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _operands(rest: str) -> List[str]:
    """%names inside the top-level call parens (rest starts after '(')."""
    depth = 1
    out = []
    i = 0
    while i < len(rest) and depth > 0:
        ch = rest[i]
        depth += ch == "("
        depth -= ch == ")"
        i += 1
    return re.findall(r"%([\w.\-]+)", rest[:i - 1]), rest[i:]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    link_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_by_group: Dict[int, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", k: float = 1.0,
            bytes_too: bool = True) -> None:
        self.flops += k * other.flops
        self.transcendentals += k * other.transcendentals
        if bytes_too:
            self.bytes += k * other.bytes
        for kk, v in other.link_bytes.items():
            self.link_bytes[kk] += k * v
        for kk, v in other.coll_by_group.items():
            self.coll_by_group[kk] += k * v

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())


class HloModuleCost:
    """Parse once; cost computed by a memoized call-graph walk."""

    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._cache: Dict[Tuple[str, bool], Cost] = {}
        self.unknown_trip: List[str] = []

    # -- parsing ----------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        ops: List[Op] = []
        for raw in text.splitlines():
            line = raw.rstrip()
            if current is None:
                m = _COMP_HEADER_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    current = m.group(1)
                    if line.strip().startswith("ENTRY"):
                        self.entry = current
                    ops = []
                continue
            if line.strip() == "}" or line.strip().startswith("} "):
                self.computations[current] = ops
                current = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            shape, opcode, rest = _split_shape_opcode(rhs)
            if not opcode:
                continue
            operands, attrs = _operands(rest) if rest else ([], "")
            ops.append(Op(name, shape, opcode, operands, attrs))
        if self.entry is None and self.computations:
            # entry is by convention the last computation in the module
            self.entry = list(self.computations)[-1]

    # -- costing ----------------------------------------------------------
    def cost(self) -> Cost:
        return self._comp_cost(self.entry, as_fusion=False)

    def _comp_cost(self, name: str, as_fusion: bool) -> Cost:
        key = (name, as_fusion)
        if key in self._cache:
            return self._cache[key]
        self._cache[key] = Cost()        # cycle guard
        ops = self.computations.get(name, [])
        table = {op.name: op.shape for op in ops}
        c = Cost()
        for op in ops:
            self._op_cost(op, table, c, as_fusion)
        self._cache[key] = c
        return c

    def _op_cost(self, op: Op, table: Dict[str, str], c: Cost,
                 as_fusion: bool) -> None:
        code = op.opcode
        base = code[:-6] if code.endswith("-start") else code
        numel = _shape_numel(op.shape)

        # ---- control flow ------------------------------------------------
        if code == "while":
            trip = self._trip_count(op.attrs)
            body = _BODY_RE.search(op.attrs)
            cond = _COND_RE.search(op.attrs)
            if body:
                c.add(self._comp_cost(body.group(1), False), trip)
            if cond:
                c.add(self._comp_cost(cond.group(1), False), trip)
            return
        if code == "fusion":
            m = _CALLS_RE.search(op.attrs)
            if m:
                c.add(self._comp_cost(m.group(1), True), 1.0)
                if not as_fusion:
                    c.bytes += self._fusion_bytes(op, table, m.group(1))
            return
        if code in ("call", "async-start"):
            m = _CALLS_RE.search(op.attrs)
            if m:
                c.add(self._comp_cost(m.group(1), False), 1.0)
            return
        if code == "conditional":
            for sub in re.findall(r"%([\w.\-]+)",
                                  op.attrs.split("metadata")[0]):
                if sub in self.computations:
                    c.add(self._comp_cost(sub, False), 1.0)
            return

        # ---- collectives ---------------------------------------------------
        if base in COLLECTIVES and not code.endswith("-done"):
            out_bytes = self._collective_result_bytes(op, table)
            n = self._group_size(op.attrs)
            frac = (n - 1) / n if n > 1 else 0.0
            if base == "all-gather":
                link = frac * out_bytes
            elif base == "reduce-scatter":
                link = frac * out_bytes * n
            elif base == "all-reduce":
                link = 2 * frac * out_bytes
            elif base == "all-to-all":
                link = frac * out_bytes
            else:                         # collective-permute
                link = out_bytes
            c.link_bytes[base] += link
            c.coll_by_group[n] += link
            self._add_bytes(op, table, c, as_fusion)
            return

        # ---- compute -------------------------------------------------------
        if code == "dot":
            k = 1
            mcontract = _CONTRACT_RE.search(op.attrs)
            if mcontract and op.operands:
                lhs_dims = _shape_dims(table.get(op.operands[0], ""))
                for d in mcontract.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            c.flops += 2.0 * numel * k
        elif code == "convolution":
            c.flops += 2.0 * numel      # depthwise convs only (K folded)
        elif code in TRANSCENDENTAL:
            c.flops += numel
            c.transcendentals += numel
        elif code in ELEMENTWISE:
            c.flops += numel
        elif code in ("reduce", "reduce-window"):
            if op.operands:
                c.flops += _shape_numel(table.get(op.operands[0], ""))
        self._add_bytes(op, table, c, as_fusion)

    def _add_bytes(self, op: Op, table: Dict[str, str], c: Cost,
                   as_fusion: bool) -> None:
        if as_fusion or op.opcode in NO_BYTES:
            return
        if op.opcode in ("dynamic-update-slice", "dynamic-slice"):
            # in-place slice traffic: the slice in and out, not the buffer
            if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
                c.bytes += 2.0 * _shape_bytes(table.get(op.operands[1], ""))
            else:
                c.bytes += 2.0 * _shape_bytes(op.shape)
            return
        total = _shape_bytes(op.shape)
        for o in op.operands:
            total += _shape_bytes(table.get(o, ""))
        c.bytes += total

    def _fusion_bytes(self, op: Op, table: Dict[str, str],
                      called: str) -> float:
        """Effective HBM traffic of one fusion call site.

        Big buffers that the fused computation only *slices* (dynamic-slice
        reads) or *updates in place* (dynamic-update-slice outputs, aliased)
        must be costed at the slice size, not the buffer size — otherwise a
        scan that DUS-accumulates into a (trip, ...) stack looks like it
        rewrites the whole stack every iteration (multiplying to absurd
        totals).  Parameter uses are analyzed per fused computation and
        memoized.
        """
        eff = self._fusion_effective(called)
        total = 0.0
        # result: if the root is (a tuple of) DUS, count update sizes
        total += eff.get("root", _shape_bytes(op.shape))
        for i, o in enumerate(op.operands):
            full = _shape_bytes(table.get(o, ""))
            total += min(full, eff.get(i, full))
        return total

    def _fusion_effective(self, called: str) -> Dict[object, float]:
        key = ("__fusion_eff__", called)
        if key in self._cache:
            return self._cache[key]       # type: ignore[return-value]
        ops = self.computations.get(called, [])
        table = {op.name: op.shape for op in ops}
        param_of: Dict[str, int] = {}
        uses: Dict[int, List[Tuple[str, str]]] = defaultdict(list)
        root_shape = ""
        dus_updates: Dict[str, float] = {}
        for op in ops:
            if op.opcode == "parameter":
                m = re.match(r"(\d+)", op.attrs or "")
                idx = int(m.group(1)) if m else len(param_of)
                param_of[op.name] = idx
            if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
                dus_updates[op.name] = _shape_bytes(
                    table.get(op.operands[1], ""))
            root_shape = op.shape
            for o in op.operands:
                if o in param_of:
                    uses[param_of[o]].append((op.opcode, op.shape))
        eff: Dict[object, float] = {}
        for idx, ulist in uses.items():
            if all(u[0] in ("dynamic-slice", "dynamic-update-slice")
                   for u in ulist):
                eff[idx] = sum(_shape_bytes(u[1]) if u[0] == "dynamic-slice"
                               else 0.0 for u in ulist)
        if ops and ops[-1].opcode == "dynamic-update-slice":
            eff["root"] = dus_updates.get(ops[-1].name, 0.0)
        self._cache[key] = eff            # type: ignore[assignment]
        return eff

    def _collective_result_bytes(self, op: Op, table: Dict[str, str]) -> int:
        shape = op.shape
        if op.opcode.endswith("-start") and shape.startswith("("):
            # (operand_shapes, result_shapes) tuple: take the second half
            comps = _SHAPE_RE.findall(shape)
            if len(comps) >= 2:
                half = comps[len(comps) // 2:]
                return sum(
                    _DTYPE_BYTES.get(dt, 0) * math.prod(
                        [int(d) for d in dims.split(",") if d] or [1])
                    for dt, dims in half)
        return _shape_bytes(shape)

    def _trip_count(self, attrs: str) -> float:
        m = _TRIP_JSON_RE.search(attrs) or _TRIP_PLAIN_RE.search(attrs)
        if m:
            return float(m.group(1))
        self.unknown_trip.append(attrs[:120])
        return 1.0

    def _group_size(self, attrs: str) -> int:
        m = _GROUPS_IOTA_RE.search(attrs)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(attrs)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip()])
        return 2


def cpu_f32_shadow_bytes(hlo_text: str, floor: int = 1 << 26) -> int:
    """Bytes of whole-buffer f32 *shadows* of bf16 tensors.

    XLA's CPU backend has no native bf16 dot: it hoists convert(bf16→f32)
    of big loop-carried operands (e.g. the whole KV-cache stack) out of the
    scan, keeping an f32 twin alive.  On TPU these buffers do not exist, so
    the dry-run reports arg+temp minus this as ``tpu_projected_bytes``.
    Counted once per distinct shape, only over actual ``convert`` results
    ≥ ``floor`` bytes whose shape also exists in bf16 (i.e. real twins).
    """
    converts = set(re.findall(r"= f32\[([\d,]+)\]\S* convert\(", hlo_text))
    bf16 = set(re.findall(r"= bf16\[([\d,]+)\]", hlo_text))
    total = 0
    for dims in converts & bf16:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= floor:
            total += n * 4
    return total


def analyze_hlo(hlo_text: str) -> Dict[str, object]:
    mod = HloModuleCost(hlo_text)
    c = mod.cost()
    return {
        "flops": c.flops,
        "bytes_accessed": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_link_bytes": dict(c.link_bytes),
        "collective_by_group_size": {str(k): v
                                     for k, v in c.coll_by_group.items()},
        "total_link_bytes": c.total_link_bytes,
        "unknown_trip_counts": len(mod.unknown_trip),
    }
