"""Parse collective traffic out of lowered/compiled HLO text.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but NOT
collective bytes, so the roofline's third term is derived here: every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op is extracted from the HLO text together with its
result shape and replica-group size, and converted to *per-device link
bytes* with the standard ring-algorithm accounting:

    all-gather          (N-1)/N x result_bytes
    reduce-scatter      (N-1)/N x operand_bytes   (= N x result)
    all-reduce        2 (N-1)/N x operand_bytes   (RS + AG)
    all-to-all          (N-1)/N x operand_bytes
    collective-permute  operand_bytes

N is the replica-group size parsed per op, so in-pod (N=16) and cross-pod
(N=2) collectives are costed separately.  The parser works on both
``lowered.as_text()`` (pre-SPMD: partition counts symbolic) and
``compiled.as_text()`` (post-SPMD partitioner: concrete per-device shapes) —
the dry-run uses the compiled form.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind per-device link-byte totals for one HLO module."""

    ops: List[dict]

    @property
    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for op in self.ops:
            out[op["kind"]] += op["link_bytes"]
        return dict(out)

    @property
    def total_link_bytes(self) -> float:
        return sum(op["link_bytes"] for op in self.ops)

    def summary(self) -> Dict[str, float]:
        return {"total_link_bytes": self.total_link_bytes,
                "n_ops": len(self.ops), **self.by_kind}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_shape, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(result_shape)

        n = _group_size(line)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-gather":
            link = frac * result_bytes
        elif kind == "reduce-scatter":
            link = frac * result_bytes * n
        elif kind == "all-reduce":
            link = 2 * frac * result_bytes
        elif kind == "all-to-all":
            link = frac * result_bytes
        else:  # collective-permute
            link = result_bytes
        ops.append({"kind": kind, "result_bytes": result_bytes,
                    "group_size": n, "link_bytes": link})
    return CollectiveStats(ops)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).strip("{}")
        return len([x for x in first.split(",") if x.strip() != ""])
    return 2
