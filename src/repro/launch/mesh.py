"""Production meshes.  Functions, not constants: importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); 2 pods = 512 chips multi-pod.

    Axis semantics: ``data`` carries DP/FSDP, ``model`` carries TP/EP/SP,
    ``pod`` carries cross-pod DP (gradient all-reduce over DCI only).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh with the production axis names — lets the
    examples run the exact same step code on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))
