"""Straggler mitigation: timeout-and-backup dispatch for train steps.

At 1000+-node scale the slowest participant sets the step time; hosts also
stall on preemption, page faults or flaky NICs.  Because this framework's
data pipeline is a pure function of (seed, step) and steps are functional,
a straggling dispatch can simply be RACED by a backup dispatch of the SAME
step — whichever completes first wins, and determinism guarantees they are
identical (the TPU-side analogue is re-queuing the program on a healthy
slice; the host-side mechanics are what we can exercise here).

:class:`BackupStepRunner` wraps a jitted step:

* per-step wall time keeps an EMA;
* a dispatch exceeding ``threshold x EMA`` (or ``hard_timeout_s``) gets a
  backup dispatch; the first completion wins;
* stragglers are counted and reported for the ops dashboard.

Tests inject an artificial delay to exercise the backup path and verify
result equality (tests/test_straggler.py).
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    backups_fired: int = 0
    backups_won: int = 0
    ema_s: float = 0.0


class BackupStepRunner:
    """Races a backup dispatch when the primary step straggles."""

    def __init__(self, step_fn: Callable[..., Any], *,
                 threshold: float = 3.0, warmup_steps: int = 2,
                 hard_timeout_s: float = 120.0,
                 delay_hook: Optional[Callable[[int], float]] = None):
        """``delay_hook(step) -> seconds`` injects artificial straggle into
        the PRIMARY dispatch (test/simulation only)."""
        self.step_fn = step_fn
        self.threshold = threshold
        self.warmup = warmup_steps
        self.hard_timeout_s = hard_timeout_s
        self.delay_hook = delay_hook
        self.stats = StragglerStats()
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=2)

    def _dispatch(self, args, kwargs, delay: float = 0.0):
        if delay:
            time.sleep(delay)
        out = self.step_fn(*args, **kwargs)
        jax.block_until_ready(out)
        return out

    def __call__(self, *args, **kwargs):
        st = self.stats
        step_idx = st.steps
        delay = self.delay_hook(step_idx) if self.delay_hook else 0.0
        t0 = time.perf_counter()
        primary = self._pool.submit(self._dispatch, args, kwargs, delay)

        budget = (self.hard_timeout_s if st.steps < self.warmup
                  else min(self.hard_timeout_s,
                           max(self.threshold * st.ema_s, 1e-3)))
        backup = None
        try:
            out = primary.result(timeout=budget)
        except concurrent.futures.TimeoutError:
            st.backups_fired += 1
            backup = self._pool.submit(self._dispatch, args, kwargs, 0.0)
            done, _ = concurrent.futures.wait(
                (primary, backup),
                return_when=concurrent.futures.FIRST_COMPLETED)
            winner = done.pop()
            if winner is backup:
                st.backups_won += 1
            out = winner.result()
        dt = time.perf_counter() - t0
        st.ema_s = dt if st.steps == 0 else 0.8 * st.ema_s + 0.2 * dt
        st.steps += 1
        return out

    def close(self):
        self._pool.shutdown(wait=False, cancel_futures=True)
