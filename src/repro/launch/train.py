"""The trainer: data pipeline → sharded train step → async checkpoints.

Runs the exact production step code at any scale:

* ``--arch <id> --smoke`` — reduced config on host CPU (the per-arch smoke
  path; also what examples/train_lm.py drives);
* full configs under a mesh — the same builder the dry-run uses.

Fault-tolerance loop (DESIGN.md §5): deterministic (seed, step)-keyed data,
async rotating checkpoints every ``--ckpt-every``, restore-on-start from the
latest checkpoint (elastic: the restoring mesh re-derives shardings from
logical axes, so N→M device restarts just work).  ``--simulate-failure k``
kills the process at step k to let tests exercise the restart path.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..data import DataConfig, SyntheticLMData
from ..checkpoint import CheckpointManager
from ..models.params import init_params
from ..models.transformer import model_spec
from ..optim import adamw_init, wsd_schedule
from ..train.step import TrainConfig, make_train_step


def build_host_trainer(cfg, tcfg: TrainConfig, seed: int = 0):
    """Single-device trainer (smoke / examples): plain jit, no mesh."""
    step_fn = jax.jit(make_train_step(cfg, tcfg,
                                      wsd_schedule(tcfg.peak_lr,
                                                   tcfg.total_steps)),
                      donate_argnums=(0,))
    spec = model_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(seed),
                         dtype=jnp.dtype(tcfg.param_dtype))
    state = {"params": params, "opt": adamw_init(params)}
    return step_fn, state, spec


def train_loop(cfg, tcfg: TrainConfig, *, steps: int, global_batch: int,
               seq_len: int, seed: int = 0, ckpt_dir: str | None = None,
               ckpt_every: int = 50, log_every: int = 10,
               simulate_failure: int = 0):
    step_fn, state, spec = build_host_trainer(cfg, tcfg, seed)
    data = SyntheticLMData(
        DataConfig(global_batch, seq_len, cfg.vocab, seed=seed), cfg)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            restored, manifest = mgr.restore_latest(like=state)
            state = jax.tree_util.tree_map(jnp.asarray, restored)
            start = manifest["step"]
            print(f"[train] restored step {start} from {ckpt_dir}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)",
                  flush=True)
        if mgr and step > start and step % ckpt_every == 0:
            # tag with step+1: the saved state has THIS step applied, so a
            # restore resumes at the next step (no double-apply)
            mgr.save_async(state, step + 1,
                           meta={"arch": cfg.name, "seed": seed})
        if simulate_failure and step == simulate_failure:
            print(f"[train] simulating failure at step {step}", flush=True)
            if mgr:
                mgr.wait()
            sys.exit(42)
    if mgr:
        mgr.save_async(state, steps, meta={"arch": cfg.name, "seed": seed})
        mgr.wait()
    return state, losses


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    tcfg = TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                       remat=args.remat, microbatches=args.microbatches)
    _, losses = train_loop(
        cfg, tcfg, steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, seed=args.seed, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, simulate_failure=args.simulate_failure)
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
