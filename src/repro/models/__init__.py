"""repro.models — the architecture zoo for the 10 assigned configs."""

from .config import ModelConfig
from .params import (ParamSpec, abstract_params, init_params, logical_axes,
                     param_bytes)
from .transformer import (cache_struct, decode_step, forward, init_cache,
                          model_spec, prefill, train_loss)

__all__ = [
    "ModelConfig", "ParamSpec", "abstract_params", "init_params",
    "logical_axes", "param_bytes", "model_spec", "forward", "train_loss",
    "prefill", "decode_step", "init_cache", "cache_struct",
]
