"""GQA/MQA/MHA attention block with rotary embedding and a KV cache.

Three call modes share one parameter tree:

* :func:`attend_full`    — training / prefill over a whole sequence (flash
  attention kernel; causal or bidirectional for encoders);
* :func:`attend_decode`  — one new token against the cache (flash-decoding
  math in jnp: when the cache's T axis is sharded over ``model``, GSPMD turns
  the masked max/sum reductions into the partial-softmax all-reduce combine);
* cache init/update helpers used by the serving layer.

Projection weights keep *flattened* head dims — (d_model, H*hd) — so the TP
logical axes "heads"/"kv" are divisible by the 16-wide model axis for every
assigned arch (even minicpm's 36 heads: 36*64 = 2304 = 16*144).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import (active_axis_size, active_mesh,
                                    active_rules, constrain, spec_for)
from ..kernels.flash_attention.ops import _xla_full, flash_attention
from .config import ModelConfig
from .layers import apply_rotary, cdtype
from .params import ParamSpec, dense_spec

NEG_INF = -1e30

#: min sequence length for the context-parallel shard_map attention path
CP_MIN_SEQ = 8192


def _context_parallel_attention(q, k, v, cfg: ModelConfig) -> jax.Array:
    """Causal attention with q sequence-sharded over the ``model`` axis.

    For archs whose head count does not divide the 16-wide model axis
    (minicpm 36, paligemma 8), head-TP attention is impossible and naive
    GSPMD propagation all-gathers q/k/v INSIDE the flash pair-scan — 47.9 TB
    of link traffic on minicpm prefill_32k (EXPERIMENTS §Perf).  Instead:
    shard_map over "model" with q's S axis sharded; k/v are gathered ONCE
    per layer (they enter replicated); each shard runs chunked online-
    softmax attention over its q rows with a *traced* causal row offset
    (axis_index * S_local).

    Trade-off: no triangle skipping (a shard's chunk visibility depends on
    its dynamic offset) — 2x the minimal causal FLOPs, but distributed over
    16x more devices and with ~500x less traffic.  Zigzag CP would fix the
    imbalance; documented as future work in DESIGN.md.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = active_mesh()
    rules = active_rules()
    b, hq, s, d = q.shape
    batch_axes = spec_for(("batch",), rules, mesh, (b,))
    bspec = batch_axes[0] if len(batch_axes) else None
    q_spec = P(bspec, None, "model", None)
    kv_spec = P(bspec, None, None, None)
    scale = d ** -0.5

    def body(ql, kf, vf):
        offset = jax.lax.axis_index("model") * ql.shape[2]
        return _xla_full(ql, kf, vf, scale, True, bk=512, q_offset=offset)

    fn = shard_map(body, mesh=mesh, in_specs=(q_spec, kv_spec, kv_spec),
                   out_specs=q_spec, check_rep=False)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attn_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": dense_spec(d, h * hd, ("embed", "heads"), stacked=stacked),
        "wk": dense_spec(d, kvh * hd, ("embed", "kv"), stacked=stacked),
        "wv": dense_spec(d, kvh * hd, ("embed", "kv"), stacked=stacked),
        "wo": dense_spec(h * hd, d, ("heads", "embed"), stacked=stacked),
    }
    if cfg.qkv_bias:
        for name, width in (("bq", h * hd), ("bk", kvh * hd), ("bv", kvh * hd)):
            shape = (stacked, width) if stacked else (width,)
            axes = (("layers", "heads") if name == "bq" else ("layers", "kv")
                    ) if stacked else (("heads",) if name == "bq" else ("kv",))
            out[name] = ParamSpec(shape, axes, "zeros")
    return out


def _project_qkv(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (B, S, D) -> q (B, H, S, hd), k/v (B, KVH, S, hd), rotary applied."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cdtype(cfg)
    xq = jnp.dot(x.astype(dt), p["wq"].astype(dt))
    xk = jnp.dot(x.astype(dt), p["wk"].astype(dt))
    xv = jnp.dot(x.astype(dt), p["wv"].astype(dt))
    if cfg.qkv_bias:
        xq = xq + p["bq"].astype(dt)
        xk = xk + p["bk"].astype(dt)
        xv = xv + p["bv"].astype(dt)
    q = xq.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = xk.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    v = xv.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    if not cfg.is_encoder:   # encoders use additive positions at embed time
        q = apply_rotary(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rotary(k, positions, cfg.rope_theta, cfg.rotary_pct)
    return q, k, v


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------
def attend_full(p, x: jax.Array, cfg: ModelConfig, *,
                positions: Optional[jax.Array] = None,
                return_kv: bool = False):
    """(B, S, D) -> (B, S, D); optionally also the (k, v) for cache build."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    causal = cfg.causal and not cfg.is_encoder
    model_tp = active_axis_size("model")
    if (causal and s >= CP_MIN_SEQ and model_tp > 1
            and cfg.n_heads % model_tp != 0):
        # context parallelism for non-head-divisible archs at long seq
        out = _context_parallel_attention(q, k, v, cfg)
    else:
        q = constrain(q, "batch", "heads", "seq", None)
        out = flash_attention(q, k, v, causal=causal)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    dt = cdtype(cfg)
    y = jnp.dot(out.astype(dt), p["wo"].astype(dt))
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, kvh, max_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, kvh, max_len, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def cache_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                       max_len: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Pad prefill (B, KVH, S, hd) K/V out to max_len cache arrays."""
    s = k.shape[2]
    pad = [(0, 0), (0, 0), (0, max_len - s), (0, 0)]
    return {"k": jnp.pad(k.astype(dtype), pad),
            "v": jnp.pad(v.astype(dtype), pad)}


# ---------------------------------------------------------------------------
# Decode (one token per sequence)
# ---------------------------------------------------------------------------
def attend_decode(p, x: jax.Array, cache: Dict[str, jax.Array], pos,
                  cfg: ModelConfig):
    """x (B, 1, D) + cache at absolute position ``pos`` (scalar int32).

    Returns (y (B, 1, D), updated cache).  The masked-softmax reduction over
    the cache's T axis is written so GSPMD's partial reductions implement
    flash-decoding when T is sharded (DESIGN.md §5).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((1,), 0, jnp.int32) + pos
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    dtype = cache["k"].dtype
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(dtype), pos, axis=2)
    k_cache = constrain(k_cache, "batch", None, "kv_seq", None)
    v_cache = constrain(v_cache, "batch", None, "kv_seq", None)

    group = h // kvh
    t = k_cache.shape[2]
    qd = q[:, :, 0].reshape(b, kvh, group, hd).astype(dtype)
    scale = hd ** -0.5
    # bf16 reads, f32 accumulation: never materialize an f32 cache copy
    # (an .astype(f32) on the cache doubles decode HBM — measured 5.6 GiB
    # on minicpm decode_32k before this, see EXPERIMENTS §Perf)
    s = jnp.einsum("bgqd,bgtd->bgqt", qd, k_cache,
                   preferred_element_type=jnp.float32) * scale  # (B,KVH,G,T)
    valid = (jnp.arange(t) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pexp = jnp.exp(s - m)
    l = jnp.sum(pexp, axis=-1, keepdims=True)
    o = jnp.einsum("bgqt,bgtd->bgqd", pexp.astype(dtype), v_cache,
                   preferred_element_type=jnp.float32) / l
    o = o.reshape(b, 1, h * hd)
    dt = cdtype(cfg)
    y = jnp.dot(o.astype(dt), p["wo"].astype(dt))
    return y, {"k": k_cache, "v": v_cache}
