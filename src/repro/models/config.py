"""ModelConfig — one dataclass describing every assigned architecture.

A model is a periodic stack of blocks.  ``block_pattern`` lists the block
kind at each position within one period (``attn`` / ``mla`` / ``mamba`` /
``rwkv``) and ``mlp_pattern`` the feed-forward kind (``dense`` / ``moe`` /
``none`` — rwkv blocks carry their own channel-mix, so they use ``none``).
The stack scans ``n_layers / len(block_pattern)`` groups of stacked weights
(HLO size is O(period), not O(depth) — essential for the 1-CPU dry-run).

``reduced()`` derives the family-preserving smoke-test configuration used by
tests (small widths/depths/experts, same block structure).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads

    # stack structure (one period)
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp_pattern: Tuple[str, ...] = ("dense",)
    first_layer_dense: bool = False        # deepseek: layer 0 is dense-MLP

    # attention
    attn_kind: str = "gqa"                 # "gqa" | "mla"
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rotary_pct: float = 1.0                # stablelm: 0.25
    causal: bool = True
    is_encoder: bool = False               # hubert: no decode path

    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    d_ff_dense: int = 0                    # dense-MLP width when mixed w/ MoE
    capacity_factor: float = 1.25

    # Mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0                 # 0 -> d_model // 16

    # RWKV-6
    rwkv_head_dim: int = 64

    # norms / embeddings / scaling
    norm: str = "rmsnorm"                  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    scale_emb: float = 1.0                 # minicpm: 12
    scale_depth: float = 0.0               # minicpm: 1.4 (0 -> off)
    logit_scale_base: int = 0              # minicpm dim_model_base: 256
    act: str = "silu"                      # "silu" | "gelu"
    gated_mlp: bool = True                 # False: classic 2-matmul MLP

    # modality frontend stub ("none" | "vision" | "audio")
    frontend: str = "none"
    n_prefix_embed: int = 256              # vision: patch tokens prepended

    # activation compute dtype
    dtype: str = "bfloat16"

    # activation-checkpoint policy applied to each scanned layer group
    # ("none" | "dots" | "full") — per-layer remat keeps only the carry
    # between groups; "dots" additionally saves non-batch matmul outputs.
    remat: str = "none"

    # FSDP strategy: True = all-gather the (embed-sharded) weights of each
    # scan group before use (weight traffic = params/n_groups per step);
    # False = let GSPMD partial-sum matmuls and all-reduce *activations*
    # (traffic = activations per matmul — 26x worse for stablelm train_4k,
    # see EXPERIMENTS §Perf).  Exposed as a knob so both lower.
    fsdp_gather_weights: bool = True

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank",
                               max(1, self.d_model // 16))
        period = len(self.block_pattern)
        if len(self.mlp_pattern) != period:
            raise ValueError("block_pattern and mlp_pattern lengths differ")
        scanned = self.n_layers - (1 if self.first_layer_dense else 0)
        if scanned % period:
            raise ValueError(
                f"{self.name}: {scanned} scanned layers not divisible by "
                f"period {period}")

    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        return (self.n_layers - (1 if self.first_layer_dense else 0)) // self.period

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so it shards over 16 (and stays 128-lane tidy)."""
        return -(-self.vocab // 256) * 256

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return any(b in ("attn", "mla") for b in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if per-token decode state is O(1) in context (SSM/linear)."""
        return not any(b in ("attn", "mla") for b in self.block_pattern) or (
            self.block_pattern.count("attn") + self.block_pattern.count("mla")
        ) < len(self.block_pattern)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, h, kvh, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = {b: 0 for b in set(self.block_pattern)}
        if "attn" in per:
            per["attn"] = d * (h * hd) + 2 * d * (kvh * hd) + (h * hd) * d
        if "mla" in per:
            ql, kvl = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vd = (self.qk_nope_head_dim, self.qk_rope_head_dim,
                              self.v_head_dim)
            per["mla"] = (d * ql + ql * h * (nope + rope) + d * (kvl + rope)
                          + kvl * h * (nope + vd) + h * vd * d)
        if "mamba" in per:
            di, n, dtr = self.mamba_d_inner, self.mamba_d_state, self.mamba_dt_rank
            per["mamba"] = (d * 2 * di + di * self.mamba_d_conv
                            + di * (dtr + 2 * n) + dtr * di + di * n + di
                            + di * d)
        if "rwkv" in per:
            per["rwkv"] = 5 * d * d + 2 * d * 32 + (d * self.d_ff + self.d_ff * d
                                                    + d * d)
        mlp = {"dense": (3 if self.gated_mlp else 2) * d * self.d_ff,
               "none": 0}
        if self.n_experts:
            ff = self.d_ff_expert or self.d_ff
            mlp["moe"] = (self.n_experts * 3 * d * ff + d * self.n_experts
                          + self.n_shared_experts * 3 * d * ff)
        layers = 0
        for b, m in zip(self.block_pattern, self.mlp_pattern):
            layers += per[b] + mlp[m]
        total += layers * self.n_groups
        if self.first_layer_dense:
            total += per.get("attn", per.get("mla", 0)) + 3 * d * (
                self.d_ff_dense or self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        ff = self.d_ff_expert or self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * ff
        n_moe = sum(1 for m in self.mlp_pattern if m == "moe") * self.n_groups
        return int(self.param_count() - n_moe * inactive)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Family-preserving smoke-test config (runs a step on 1 CPU)."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=(1 if self.first_layer_dense else 0) + self.period,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            qk_nope_head_dim=32 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=16 if self.qk_rope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            d_ff_expert=128 if self.d_ff_expert else 0,
            d_ff_dense=256 if self.d_ff_dense else 0,
            mamba_dt_rank=8,
            rwkv_head_dim=32,
            n_prefix_embed=8 if self.frontend == "vision" else self.n_prefix_embed,
            dtype="float32",
        )
        return dataclasses.replace(self, **changes)
