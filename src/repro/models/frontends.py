"""Modality frontends — STUBS per the assignment.

``[vlm]`` / ``[audio]`` archs specify the transformer *backbone* only; the
SigLIP vision tower (paligemma) and the CNN feature encoder (hubert) are
replaced by ``input_specs()`` handing the model *precomputed* patch/frame
embeddings.  The only learned pieces here are the linear adapters that map
frontend features into d_model (as both papers also have).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cdtype, sinusoidal_positions
from .params import ParamSpec, dense_spec

VISION_FEATURE_DIM = 1152     # SigLIP-So400m output width (stubbed)
AUDIO_FEATURE_DIM = 512       # wav2vec2/HuBERT CNN encoder output (stubbed)


def frontend_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    if cfg.frontend == "vision":
        return {"proj": dense_spec(VISION_FEATURE_DIM, cfg.d_model,
                                   (None, "embed"))}
    if cfg.frontend == "audio":
        return {"proj": dense_spec(AUDIO_FEATURE_DIM, cfg.d_model,
                                   (None, "embed")),
                "ln_scale": ParamSpec((cfg.d_model,), ("embed",), "ones"),
                "ln_bias": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    return {}


def feature_dim(cfg: ModelConfig) -> int:
    return VISION_FEATURE_DIM if cfg.frontend == "vision" else AUDIO_FEATURE_DIM


def embed_vision(p, patches: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Precomputed patch features (B, P, F) -> prefix embeddings (B, P, D)."""
    dt = cdtype(cfg)
    return jnp.dot(patches.astype(dt), p["proj"].astype(dt))


def embed_audio(p, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Precomputed frame features (B, S, F) -> (B, S, D) with sinusoidal
    positions (stand-in for hubert's conv positional encoder)."""
    dt = cdtype(cfg)
    x = jnp.dot(frames.astype(dt), p["proj"].astype(dt))
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    x = x + pos[None]
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    xn = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    return (xn * p["ln_scale"] + p["ln_bias"]).astype(dt)
