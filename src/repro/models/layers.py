"""Shared neural building blocks: norms, MLPs, rotary embedding, embeddings.

Convention: params are fp32 pytrees (see params.py); activations are cast to
the config compute dtype (bf16 in production) at the matmul boundary, with
norms and softmax in fp32.  Functions take (params_subtree, x, cfg) and are
pure.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec, dense_spec


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    shape = (stacked, cfg.d_model) if stacked else (cfg.d_model,)
    axes = ("layers", "embed") if stacked else ("embed",)
    out = {"scale": ParamSpec(shape, axes, "ones")}
    if cfg.norm == "layernorm":
        out["bias"] = ParamSpec(shape, axes, "zeros")
    return out


def apply_norm(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------
def matmul(x: jax.Array, w: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jnp.dot(x.astype(cdtype(cfg)), w.astype(cdtype(cfg)))


def act_fn(cfg: ModelConfig):
    return jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu


def mlp_spec(cfg: ModelConfig, d_ff: int, stacked: int = 0):
    d = cfg.d_model
    out = {
        "wi": dense_spec(d, d_ff, ("embed", "mlp"), stacked=stacked),
        "wo": dense_spec(d_ff, d, ("mlp", "embed"), stacked=stacked),
    }
    if cfg.gated_mlp:
        out["wg"] = dense_spec(d, d_ff, ("embed", "mlp"), stacked=stacked)
    return out


def apply_mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Gated MLP wo( act(x wg) * (x wi) ) — llama/gemma family — or the
    classic wo( act(x wi) ) two-matmul form (hubert/BERT lineage)."""
    if cfg.gated_mlp:
        g = act_fn(cfg)(matmul(x, p["wg"], cfg))
        h = g * matmul(x, p["wi"], cfg)
    else:
        h = act_fn(cfg)(matmul(x, p["wi"], cfg))
    return matmul(h, p["wo"], cfg)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rotary(x: jax.Array, positions: jax.Array, theta: float,
                 rotary_pct: float = 1.0) -> jax.Array:
    """x (..., S, D); positions (S,) or (B, S).  Rotates the first
    ``rotary_pct * D`` channels (pairwise halves convention)."""
    d = x.shape[-1]
    rd = int(d * rotary_pct)
    rd -= rd % 2
    if rd == 0:
        return x
    xr, xp = x[..., :rd], x[..., rd:]
    freqs = rope_frequencies(rd, theta)                       # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, rd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    while cos.ndim < xr.ndim:                                 # add head axis
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(seq: int, d: int, offset: int = 0) -> jax.Array:
    """Classic transformer sin/cos table (audio-encoder positional stub)."""
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------
def embed_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    # 1/sqrt(d) embeddings keep tied-head logits O(1) at init (gemma-style
    # scale_emb = sqrt(d) archs re-scale the lookup back up).
    out = {"embedding": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                  ("vocab", "embed"), "normal",
                                  cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        out["lm_head"] = dense_spec(cfg.d_model, cfg.vocab_padded,
                                    ("embed", "vocab"))
    return out


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = p["embedding"].astype(cdtype(cfg))[tokens]
    if cfg.scale_emb != 1.0:
        x = x * jnp.asarray(cfg.scale_emb, x.dtype)
    return x


def logits_from_hidden(p, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    logits = jnp.dot(h.astype(cdtype(cfg)), w.astype(cdtype(cfg)))
    logits = logits.astype(jnp.float32)
    if cfg.logit_scale_base:
        logits = logits / (cfg.d_model / cfg.logit_scale_base)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy; logits fp32 (B, S, Vp), labels (B, S)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def residual_scale(cfg: ModelConfig) -> float:
    """MiniCPM depth-scaled residuals: each block output is multiplied by
    scale_depth / sqrt(n_layers)."""
    if cfg.scale_depth:
        return cfg.scale_depth / math.sqrt(cfg.n_layers)
    return 1.0
