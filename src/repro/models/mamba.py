"""Mamba (S6) block — the SSM half of Jamba's 1:7 attn:mamba interleave.

Block structure (Mamba-1, as used by Jamba):

    x ->(in_proj) [xz | z] -> causal depthwise conv1d -> SiLU
      ->(x_proj) [dt_low | B | C] ; dt = softplus(dt_proj(dt_low) + bias)
      -> selective scan (kernels/mamba_scan) -> * SiLU(z) ->(out_proj) y

Decode keeps two states per layer: the conv window (B, d_conv-1, d_inner)
and the SSM state (B, d_inner, d_state) — O(1) in context length, which is
why jamba runs the long_500k cell.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels.mamba_scan.ops import mamba_scan, mamba_step_ref
from .config import ModelConfig
from .layers import cdtype
from .params import ParamSpec, dense_spec


def mamba_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    d, di = cfg.d_model, cfg.mamba_d_inner
    n, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank

    def p(shape, axes, init="normal", scale=1.0):
        if stacked:
            shape = (stacked,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, init, scale)

    return {
        "in_proj": dense_spec(d, 2 * di, ("embed", "mlp"), stacked=stacked),
        "conv_w": p((dc, di), (None, "mlp"), "normal", dc ** -0.5),
        "conv_b": p((di,), ("mlp",), "zeros"),
        "x_proj": dense_spec(di, dtr + 2 * n, ("mlp", None), stacked=stacked),
        "dt_proj": dense_spec(dtr, di, (None, "mlp"), stacked=stacked),
        "dt_bias": p((di,), ("mlp",), "constant"),     # softplus(0) ~ .69
        # A stored as -exp(a_log) < 0; init a_log = log(arange(1, N+1))
        "a_log": p((di, n), ("mlp", None), "constant"),
        "d_skip": p((di,), ("mlp",), "ones"),
        "out_proj": dense_spec(di, d, ("mlp", "embed"), stacked=stacked),
    }


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, T, Di), w (K, Di) -> (B, T, Di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                       # K = 4: unrolled, fuses to adds
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None]
    return out + b[None, None]


def _ssm_inputs(p, x: jax.Array, cfg: ModelConfig):
    """Post-conv activations -> (delta, B, C) for the scan."""
    n, dtr = cfg.mamba_d_state, cfg.mamba_dt_rank
    dt = cdtype(cfg)
    proj = jnp.dot(x.astype(dt), p["x_proj"].astype(dt))
    dt_low, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.dot(dt_low.astype(dt), p["dt_proj"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return delta, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def mamba_full(p, x: jax.Array, cfg: ModelConfig, *,
               return_state: bool = False):
    """x (B, S, D) -> (B, S, D)  [+ (conv_state, ssm_state) for cache]."""
    b, s, _ = x.shape
    di, dc = cfg.mamba_d_inner, cfg.mamba_d_conv
    dt = cdtype(cfg)

    xz = jnp.dot(x.astype(dt), p["in_proj"].astype(dt))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "mlp")
    xc = jax.nn.silu(_conv1d_causal(xs, p["conv_w"].astype(dt),
                                    p["conv_b"].astype(dt)))
    delta, bmat, cmat = _ssm_inputs(p, xc, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h = mamba_scan(xc, delta, a, bmat, cmat,
                      p["d_skip"].astype(jnp.float32))
    y = y.astype(dt) * jax.nn.silu(z)
    out = jnp.dot(y, p["out_proj"].astype(dt))
    if return_state:
        conv_state = xs[:, -(dc - 1):, :] if s >= dc - 1 else jnp.pad(
            xs, ((0, 0), (dc - 1 - s, 0), (0, 0)))
        return out, (conv_state.astype(dt), h)
    return out


def mamba_decode(p, x: jax.Array, state: Tuple[jax.Array, jax.Array],
                 cfg: ModelConfig):
    """x (B, 1, D), state (conv (B, dc-1, Di), ssm (B, Di, N)) -> (y, state')."""
    conv_state, ssm_state = state
    dc = cfg.mamba_d_conv
    dt = cdtype(cfg)

    xz = jnp.dot(x.astype(dt), p["in_proj"].astype(dt))
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B, 1, Di)
    window = jnp.concatenate([conv_state, xs], axis=1)  # (B, dc, Di)
    w = p["conv_w"].astype(dt)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w)
                     + p["conv_b"].astype(dt))          # (B, Di)
    delta, bmat, cmat = _ssm_inputs(p, xc[:, None], cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h = mamba_step_ref(xc, delta[:, 0], a, bmat[:, 0], cmat[:, 0],
                          p["d_skip"].astype(jnp.float32), ssm_state)
    y = y[:, None].astype(dt) * jax.nn.silu(z)
    out = jnp.dot(y, p["out_proj"].astype(dt))
    return out, (window[:, 1:], h)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return (jnp.zeros((batch, dc - 1, di), dtype),
            jnp.zeros((batch, di, n), jnp.float32))


def mamba_state_struct(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, n, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return (jax.ShapeDtypeStruct((batch, dc - 1, di), dtype),
            jax.ShapeDtypeStruct((batch, di, n), jnp.float32))
