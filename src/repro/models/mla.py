"""Multi-head Latent Attention (DeepSeek-V2) with the compressed KV cache.

MLA projects hidden states into a low-rank latent ``c_kv`` (kv_lora_rank) plus
a shared rotary key slice; per-head K/V are up-projected from the latent.
The cache stores only ``c_kv`` (512) + ``k_rope`` (64) per token — 576 floats
instead of 2*128*128 = 32768 for an equivalent MHA — the paper-claimed 93 %
KV-cache reduction, and the reason deepseek-v2's decode_32k cell fits.

* train/prefill: latents are expanded to full per-head K/V and run through
  the shared flash-attention kernel (dk = 192 = 128 nope + 64 rope, dv = 128);
* decode: the **absorbed** form — W_UK folds into the query, W_UV into the
  output — so attention runs MQA-style against the 576-wide latent cache
  directly, never materializing per-head K/V.  This is the production
  DeepSeek serving trick and what makes the decode roofline memory-light.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels.flash_attention.ops import flash_attention
from .config import ModelConfig
from .layers import apply_rotary, cdtype, rms_norm_1d
from .params import ParamSpec, dense_spec

NEG_INF = -1e30


def mla_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    def vec(width, axes):
        shape = (stacked, width) if stacked else (width,)
        ax = (("layers",) + axes) if stacked else axes
        return ParamSpec(shape, ax, "ones")

    out = {
        # query path: d -> q_lora -> per-head (nope + rope)
        "wq_a": dense_spec(d, ql, ("embed", None), stacked=stacked),
        "q_norm": vec(ql, (None,)),
        "wq_b": dense_spec(ql, h * (nope + rope), (None, "heads"),
                           stacked=stacked),
        # kv path: d -> (kv_lora | shared rope key)
        "wkv_a": dense_spec(d, kvl + rope, ("embed", None), stacked=stacked),
        "kv_norm": vec(kvl, (None,)),
        "wk_b": dense_spec(kvl, h * nope, (None, "heads"), stacked=stacked),
        "wv_b": dense_spec(kvl, h * vd, (None, "heads"), stacked=stacked),
        "wo": dense_spec(h * vd, d, ("heads", "embed"), stacked=stacked),
    }
    return out


def _latents(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """x (B,S,D) -> (c_kv (B,S,kvl) normed, k_rope (B,1,S,rope) rotated)."""
    b, s, _ = x.shape
    kvl, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dt = cdtype(cfg)
    kv_a = jnp.dot(x.astype(dt), p["wkv_a"].astype(dt))
    c_kv = rms_norm_1d(kv_a[..., :kvl], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., kvl:].reshape(b, s, 1, rope).transpose(0, 2, 1, 3)
    k_rope = apply_rotary(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """-> q_nope (B,H,S,nope), q_rope (B,H,S,rope)."""
    b, s, _ = x.shape
    h, nope, rope = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dt = cdtype(cfg)
    qa = rms_norm_1d(jnp.dot(x.astype(dt), p["wq_a"].astype(dt)),
                     p["q_norm"], cfg.norm_eps)
    qb = jnp.dot(qa.astype(dt), p["wq_b"].astype(dt))
    qb = qb.reshape(b, s, h, nope + rope).transpose(0, 2, 1, 3)
    q_nope, q_rope = qb[..., :nope], qb[..., nope:]
    q_rope = apply_rotary(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


# ---------------------------------------------------------------------------
# Train / prefill: expand latents, shared flash kernel
# ---------------------------------------------------------------------------
def mla_full(p, x: jax.Array, cfg: ModelConfig, *,
             positions: Optional[jax.Array] = None,
             return_cache: bool = False):
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    if positions is None:
        positions = jnp.arange(s)
    dt = cdtype(cfg)

    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)

    k_nope = jnp.dot(c_kv.astype(dt), p["wk_b"].astype(dt))
    k_nope = k_nope.reshape(b, s, h, nope).transpose(0, 2, 1, 3)
    v = jnp.dot(c_kv.astype(dt), p["wv_b"].astype(dt))
    v = v.reshape(b, s, h, vd).transpose(0, 2, 1, 3)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, h, s, rope))], axis=-1)
    q = constrain(q, "batch", "heads", "seq", None)
    out = flash_attention(q, k, v, causal=True,
                          scale=(nope + rope) ** -0.5)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * vd)
    y = jnp.dot(out.astype(dt), p["wo"].astype(dt))
    if return_cache:
        return y, (c_kv, k_rope[:, 0])     # (B,S,kvl), (B,S,rope)
    return y


# ---------------------------------------------------------------------------
# Compressed cache
# ---------------------------------------------------------------------------
def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct(
            (batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_cache_from_prefill(cfg: ModelConfig, c_kv, k_rope, max_len: int,
                           dtype=jnp.bfloat16):
    s = c_kv.shape[1]
    pad = [(0, 0), (0, max_len - s), (0, 0)]
    return {"c_kv": jnp.pad(c_kv.astype(dtype), pad),
            "k_rope": jnp.pad(k_rope.astype(dtype), pad)}


# ---------------------------------------------------------------------------
# Decode: absorbed MQA-style attention against the latent cache
# ---------------------------------------------------------------------------
def mla_decode(p, x: jax.Array, cache: Dict[str, jax.Array], pos,
               cfg: ModelConfig):
    """x (B,1,D) -> (y (B,1,D), cache').  Attention runs in latent space:

    score_h(t) = q_nope_h · W_UK_h c_kv[t]  +  q_rope_h · k_rope[t]
               = (W_UK_hᵀ q_nope_h) · c_kv[t] + q_rope_h · k_rope[t]

    so each head's query is *absorbed* to (kvl + rope) and the cache is the
    only per-token state read — one MQA pass over 576-wide latents.
    """
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    dt = cdtype(cfg)
    positions = jnp.full((1,), 0, jnp.int32) + pos

    q_nope, q_rope = _queries(p, x, cfg, positions)      # (B,H,1,·)
    c_new, k_rope_new = _latents(p, x, cfg, positions)   # (B,1,kvl),(B,1,1,rope)

    dtype = cache["c_kv"].dtype
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, 0].astype(dtype), pos, axis=1)
    c_kv = constrain(c_kv, "batch", "kv_seq", None)
    k_rope = constrain(k_rope, "batch", "kv_seq", None)

    # absorb W_UK into the query:  q_lat (B,H,kvl)
    wk_b = p["wk_b"].astype(jnp.float32).reshape(kvl, h, nope)
    q_lat = jnp.einsum("bhd,khd->bhk",
                       q_nope[:, :, 0].astype(jnp.float32), wk_b)
    # scores over the latent cache + shared rope key — bf16 cache reads
    # with f32 accumulation (no f32 cache copy; see attention.py note)
    t = c_kv.shape[1]
    scale = (nope + rope) ** -0.5
    s_lat = jnp.einsum("bhk,btk->bht", q_lat.astype(dtype), c_kv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhr,btr->bht", q_rope[:, :, 0].astype(dtype),
                        k_rope, preferred_element_type=jnp.float32)
    s = (s_lat + s_rope) * scale
    valid = (jnp.arange(t) <= pos)[None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    pexp = jnp.exp(s - m)
    l = jnp.sum(pexp, axis=-1, keepdims=True)
    o_lat = jnp.einsum("bht,btk->bhk", pexp.astype(dtype), c_kv,
                       preferred_element_type=jnp.float32) / l

    # absorb W_UV into the output:  (B,H,kvl) x (kvl,H,vd) -> (B,H,vd)
    wv_b = p["wv_b"].astype(jnp.float32).reshape(kvl, h, vd)
    o = jnp.einsum("bhk,khd->bhd", o_lat, wv_b)
    o = o.reshape(b, 1, h * vd)
    y = jnp.dot(o.astype(dt), p["wo"].astype(dt))
    return y, {"c_kv": c_kv, "k_rope": k_rope}
