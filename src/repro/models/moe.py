"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch, EP.

Production dispatch path (GSPMD/EP-friendly, flop-light):

1. tokens are viewed as (groups, g, D) with groups sharded over ``data`` —
   one routing group per data shard (the Tiny-OpenCL "work-group" of this
   layer, scheduled onto mesh shards exactly like the paper schedules
   work-groups onto CUs);
2. per-group: softmax router → top-k experts/weights per token;
3. **sort-based dispatch**: assignments are ordered by expert id; each
   token's position-in-expert comes from a stable argsort + running index,
   tokens beyond the per-expert capacity ``c`` are dropped (their combine
   weight is zeroed — standard GShard capacity semantics);
4. dispatched activations land in an (E, c, D) buffer per group via a
   one-hit scatter; expert weights are sharded E → ``model`` so GSPMD
   all-to-alls tokens from data shards to expert shards;
5. expert FFN (gated-SiLU) runs batched over its local experts;
6. combine scatters weighted outputs back to token order.

Aux losses: switch-style load-balance loss + router z-loss, returned to the
trainer (summed over scan groups).

Shared experts (deepseek-v2: 2) run densely on every token and add in.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .config import ModelConfig
from .layers import act_fn, cdtype
from .params import ParamSpec, dense_spec


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def moe_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    e = cfg.n_experts
    ff = cfg.d_ff_expert or cfg.d_ff

    def expert_w(din, dout, axes):
        shape = (e, din, dout)
        ax: Tuple = ("expert",) + axes
        if stacked:
            shape = (stacked,) + shape
            ax = ("layers",) + ax
        return ParamSpec(shape, ax, "normal", din ** -0.5)

    out = {
        "router": dense_spec(d, e, ("embed", None), stacked=stacked),
        "wi": expert_w(d, ff, ("embed", "mlp")),
        "wg": expert_w(d, ff, ("embed", "mlp")),
        "wo": expert_w(ff, d, ("mlp", "embed")),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        out["shared"] = {
            "wi": dense_spec(d, sff, ("embed", "mlp"), stacked=stacked),
            "wg": dense_spec(d, sff, ("embed", "mlp"), stacked=stacked),
            "wo": dense_spec(sff, d, ("mlp", "embed"), stacked=stacked),
        }
    return out


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    """Per-expert slots per routing group (multiple of 8 for TPU tiling)."""
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


# ---------------------------------------------------------------------------
# Routing + dispatch (per group, vmapped)
# ---------------------------------------------------------------------------
def _route_group(x: jax.Array, logits: jax.Array, cfg: ModelConfig, c: int):
    """x (g, D), logits (g, E) -> dispatched (E*c, D), combine info.

    Returns (buf (E*c, D), slot (g*k,), weight (g*k,), aux (2,)).
    ``slot == E*c`` marks dropped assignments (scattered to a dummy row).
    """
    g, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                       # (g, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                   # (g*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(g, dtype=jnp.int32), k)

    # position-in-expert via stable sort by expert id
    order = jnp.argsort(flat_e, stable=True)                     # (g*k,)
    sorted_e = flat_e[order]
    # index within the sorted run of each expert
    counts = jnp.bincount(flat_e, length=e)                      # (e,)
    starts = jnp.cumsum(counts) - counts                         # (e,)
    pos_sorted = jnp.arange(g * k, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)   # unsort

    kept = pos < c
    slot = jnp.where(kept, flat_e * c + pos, e * c)              # dummy last
    weight = jnp.where(kept, flat_w, 0.0)

    buf = jnp.zeros((e * c + 1, d), x.dtype)
    buf = buf.at[slot].add(x[flat_tok])                          # one-hit
    # load-balance loss (Switch): E * sum_e fraction_tokens_e * mean_prob_e
    frac_tok = counts.astype(jnp.float32) / (g * k)
    mean_prob = probs.mean(axis=0)
    lb = e * jnp.sum(frac_tok * mean_prob)
    z = jnp.mean(jax.scipy.special.logsumexp(
        logits.astype(jnp.float32), axis=-1) ** 2)
    return buf[:-1], slot, weight, flat_tok, jnp.stack([lb, z])


def _combine_group(y: jax.Array, slot, weight, flat_tok, g: int):
    """y (E*c, D) -> (g, D) weighted combine (scatter-add over tokens)."""
    yk = jnp.concatenate([y, jnp.zeros((1, y.shape[1]), y.dtype)], axis=0)
    gathered = yk[slot] * weight[:, None].astype(y.dtype)        # (g*k, D)
    out = jnp.zeros((g, y.shape[1]), y.dtype).at[flat_tok].add(gathered)
    return out


# ---------------------------------------------------------------------------
# The layer
# ---------------------------------------------------------------------------
def apply_moe(p, x: jax.Array, cfg: ModelConfig, *,
              group_size: Optional[int] = None):
    """x (B, S, D) -> (y (B, S, D), aux_losses (2,) [load_balance, z]).

    ``group_size`` defaults to S (one routing group per sequence), keeping
    groups aligned with the batch sharding so dispatch scatters stay local.
    """
    b, s, d = x.shape
    e = cfg.n_experts
    dt = cdtype(cfg)
    g = group_size or s
    n_groups = (b * s) // g
    c = capacity(cfg, g)

    xg = x.reshape(n_groups, g, d)
    xg = constrain(xg, "batch", None, None)
    logits = jnp.einsum("ngd,de->nge", xg.astype(dt), p["router"].astype(dt))

    route = jax.vmap(lambda xx, ll: _route_group(xx, ll, cfg, c))
    buf, slot, weight, flat_tok, aux = route(xg, logits)
    # buf: (n_groups, E*c, D) -> expert-major for EP
    he = buf.reshape(n_groups, e, c, d)
    he = constrain(he, "batch", "expert", None, None)   # all-to-all boundary

    wi, wg, wo = (p["wi"].astype(dt), p["wg"].astype(dt), p["wo"].astype(dt))
    hidden = act_fn(cfg)(jnp.einsum("necd,edf->necf", he.astype(dt), wg))
    hidden = hidden * jnp.einsum("necd,edf->necf", he.astype(dt), wi)
    y_exp = jnp.einsum("necf,efd->necd", hidden, wo)
    y_exp = constrain(y_exp, "batch", "expert", None, None)

    combine = jax.vmap(lambda yy, sl, w, tk: _combine_group(yy, sl, w, tk, g))
    y = combine(y_exp.reshape(n_groups, e * c, d), slot, weight, flat_tok)
    y = y.reshape(b, s, d)

    if cfg.n_shared_experts:
        sp = p["shared"]
        h = act_fn(cfg)(jnp.dot(x.astype(dt), sp["wg"].astype(dt)))
        h = h * jnp.dot(x.astype(dt), sp["wi"].astype(dt))
        y = y + jnp.dot(h, sp["wo"].astype(dt))

    return y, aux.mean(axis=0)
