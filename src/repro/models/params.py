"""Parameter-spec trees: one source of truth for shapes, sharding and init.

Every model module describes its parameters as a pytree of :class:`ParamSpec`
(shape + logical axis names + init law).  From that single tree we derive:

* ``init_params``     — concrete fp32 arrays (rng folded in by tree path);
* ``abstract_params`` — ShapeDtypeStructs for the dry-run (never allocates);
* ``partition_specs`` — jax PartitionSpecs via the distributed rules
  (see repro.distributed.sharding), mapping logical axes such as "embed",
  "mlp", "heads", "vocab", "expert" onto mesh axes.

Logical axis vocabulary (used by the sharding rules):
  "layers"  — stacked scan groups (never sharded)
  "embed"   — the d_model axis (FSDP axis in train regimes)
  "mlp"     — feed-forward hidden
  "heads"   — attention heads x head_dim flattened
  "kv"      — kv heads x head_dim flattened
  "vocab"   — vocabulary
  "expert"  — MoE expert axis
  None      — replicated
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # "normal" | "zeros" | "ones" | "constant"
    scale: float = 1.0            # stddev for normal (already fan-adjusted)
    constant: float = 0.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def dense_spec(in_dim: int, out_dim: int, axes=("embed", "mlp"),
               scale: float | None = None, stacked: int = 0) -> ParamSpec:
    """A (in, out) matmul weight with 1/sqrt(fan_in) init."""
    scale = in_dim ** -0.5 if scale is None else scale
    shape: Tuple[int, ...] = (in_dim, out_dim)
    ax: Tuple[Optional[str], ...] = tuple(axes)
    if stacked:
        shape = (stacked,) + shape
        ax = ("layers",) + ax
    return ParamSpec(shape, ax, "normal", scale)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def init_params(spec_tree, key: jax.Array, dtype=jnp.float32):
    """Concrete init: rng is folded in from the flattened tree path so that
    adding/removing parameters never perturbs unrelated weights."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)

    out = []
    for path, spec in leaves:
        name = jax.tree_util.keystr(path)
        # CRC-32, never builtin hash(): hash() is salted per process
        # (PYTHONHASHSEED), which would make param init differ across
        # processes for the same seed (the repro.analyze no-builtin-hash
        # rule; regression-pinned by a cross-process twin test)
        sub = jax.random.fold_in(key, zlib.crc32(name.encode()) % (2 ** 31))
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        elif spec.init == "constant":
            arr = jnp.full(spec.shape, spec.constant, dtype)
        else:
            arr = jax.random.normal(sub, spec.shape, dtype) * spec.scale
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree, dtype=jnp.float32):
    return _map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree)


def logical_axes(spec_tree):
    return _map_specs(lambda s: s.axes, spec_tree)


def param_bytes(spec_tree, itemsize: int = 4) -> int:
    total = 0
    for spec in jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec):
        n = 1
        for s in spec.shape:
            n *= s
        total += n * itemsize
    return total
