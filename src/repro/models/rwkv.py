"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Attention-free: per-head state is a (D x D) outer-product accumulator with
*data-dependent* per-channel decay w_t (the Finch contribution), computed by
a low-rank (lora) projection.  Decode state is O(1) in context — three
tensors per layer: last-token shifts for time/channel mix and the WKV state
(B, H, D, D) — which is why rwkv6 runs the long_500k cell.

The block carries its own channel-mix (mlp_pattern "none" in configs).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels.rwkv6_scan.ops import rwkv6_scan
from .config import ModelConfig
from .layers import cdtype
from .params import ParamSpec, dense_spec

LORA_W = 64     # decay-lora rank (rwkv6 uses 64 for 3B)


def rwkv_spec(cfg: ModelConfig, stacked: int = 0) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim

    def p(shape, axes, init="normal", scale=0.02, constant=0.0):
        if stacked:
            shape = (stacked,) + shape
            axes = ("layers",) + axes
        return ParamSpec(shape, axes, init, scale, constant)

    return {
        # time-mix interpolation coefficients (per channel)
        "mu_r": p((d,), ("embed",), "constant", constant=0.5),
        "mu_k": p((d,), ("embed",), "constant", constant=0.5),
        "mu_v": p((d,), ("embed",), "constant", constant=0.5),
        "mu_w": p((d,), ("embed",), "constant", constant=0.5),
        "mu_g": p((d,), ("embed",), "constant", constant=0.5),
        "wr": dense_spec(d, d, ("embed", "heads"), stacked=stacked),
        "wk": dense_spec(d, d, ("embed", "heads"), stacked=stacked),
        "wv": dense_spec(d, d, ("embed", "heads"), stacked=stacked),
        "wg": dense_spec(d, d, ("embed", "heads"), stacked=stacked),
        "wo": dense_spec(d, d, ("heads", "embed"), stacked=stacked),
        # data-dependent decay: w = exp(-exp(w0 + lora))
        "w0": p((d,), ("embed",), "constant", constant=-1.0),
        "w_lora_a": dense_spec(d, LORA_W, ("embed", None), stacked=stacked),
        "w_lora_b": dense_spec(LORA_W, d, (None, "heads"), stacked=stacked),
        "u_bonus": p((h, hd), (None, None), "normal", 0.02),
        "ln_x": p((d,), ("embed",), "ones"),          # per-head groupnorm
        # channel-mix
        "cmu_r": p((d,), ("embed",), "constant", constant=0.5),
        "cmu_k": p((d,), ("embed",), "constant", constant=0.5),
        "cwr": dense_spec(d, d, ("embed", "mlp"), stacked=stacked),
        "cwk": dense_spec(d, ff, ("embed", "mlp"), stacked=stacked),
        "cwv": dense_spec(ff, d, ("mlp", "embed"), stacked=stacked),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: y_t = x_{t-1}; position 0 gets ``last`` (or zeros)."""
    first = (jnp.zeros_like(x[:, :1]) if last is None else last[:, None])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _heads(x: jax.Array, h: int, hd: int) -> jax.Array:
    b, t, _ = x.shape
    return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)   # (B, H, T, D)


def _group_norm(x: jax.Array, scale: jax.Array, h: int, hd: int,
                eps: float) -> jax.Array:
    """Per-head LayerNorm of the WKV output (B, T, D)."""
    b, t, _ = x.shape
    xh = x.reshape(b, t, h, hd).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xn = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xn.reshape(b, t, h * hd) * scale.astype(jnp.float32)).astype(x.dtype)


def _mix_inputs(p, x: jax.Array, xx: jax.Array, cfg: ModelConfig):
    """Interpolated r/k/v/w/g inputs + projections (shared by scan/step)."""
    dt = cdtype(cfg)
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim

    def mix(mu):
        return (x + xx * p[mu].astype(x.dtype)).astype(dt)

    r = jnp.dot(mix("mu_r"), p["wr"].astype(dt))
    k = jnp.dot(mix("mu_k"), p["wk"].astype(dt))
    v = jnp.dot(mix("mu_v"), p["wv"].astype(dt))
    g = jax.nn.silu(jnp.dot(mix("mu_g"), p["wg"].astype(dt)))
    wl = jnp.tanh(jnp.dot(mix("mu_w"), p["w_lora_a"].astype(dt)))
    w_log = (p["w0"].astype(jnp.float32)
             + jnp.dot(wl, p["w_lora_b"].astype(dt)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log))                       # (…, D) in (0, 1)
    return r, k, v, w, g


def rwkv_time_mix(p, x: jax.Array, cfg: ModelConfig, *,
                  state: Tuple | None = None, return_state: bool = False):
    """x (B, S, D) -> (B, S, D).  state = (last_x (B,D), wkv (B,H,D,D))."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = cdtype(cfg)
    last_x, wkv0 = state if state is not None else (None, None)
    xx = _shift(x, last_x) - x
    r, k, v, w, g = _mix_inputs(p, x, xx, cfg)
    rh, kh, vh, wh = (_heads(z, h, hd) for z in (r, k, v, w))
    rh = constrain(rh, "batch", "heads", "seq", None)
    y, wkv = rwkv6_scan(rh, kh, vh, wh.astype(jnp.float32),
                        p["u_bonus"].astype(jnp.float32), state0=wkv0)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    y = _group_norm(y, p["ln_x"], h, hd, cfg.norm_eps) * g
    out = jnp.dot(y.astype(dt), p["wo"].astype(dt))
    if return_state:
        return out, (x[:, -1].astype(dt), wkv)
    return out


def rwkv_channel_mix(p, x: jax.Array, cfg: ModelConfig, *,
                     last_x: jax.Array | None = None,
                     return_state: bool = False):
    dt = cdtype(cfg)
    xx = _shift(x, last_x) - x
    xr = (x + xx * p["cmu_r"].astype(x.dtype)).astype(dt)
    xk = (x + xx * p["cmu_k"].astype(x.dtype)).astype(dt)
    r = jax.nn.sigmoid(jnp.dot(xr, p["cwr"].astype(dt)))
    k = jnp.square(jax.nn.relu(jnp.dot(xk, p["cwk"].astype(dt))))
    y = r * jnp.dot(k, p["cwv"].astype(dt))
    if return_state:
        return y, x[:, -1].astype(dt)
    return y


def rwkv_block(p, x: jax.Array, cfg: ModelConfig, *,
               state=None, return_state: bool = False):
    """Full pre-norm RWKV block body (norms applied by the caller stack).

    state = (tmix_last, wkv, cmix_last); both sub-mixes are residual.
    """
    if state is None:
        t_out = rwkv_time_mix(p, x, cfg)
        x = x + t_out
        x = x + rwkv_channel_mix(p, x, cfg)
        if return_state:
            raise ValueError("pass state to get return_state")
        return x
    tmix_last, wkv, cmix_last = state
    t_out, (t_last, wkv) = rwkv_time_mix(p, x, cfg, state=(tmix_last, wkv),
                                         return_state=True)
    x = x + t_out
    c_out, c_last = rwkv_channel_mix(p, x, cfg, last_x=cmix_last,
                                     return_state=True)
    x = x + c_out
    return x, (t_last, wkv, c_last)


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d, h, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, d), dtype))


def rwkv_state_struct(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d, h, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return (jax.ShapeDtypeStruct((batch, d), dtype),
            jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((batch, d), dtype))
