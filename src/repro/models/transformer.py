"""The model stack: embed → lax.scan over layer groups → norm → logits.

One periodic *group* holds ``cfg.block_pattern`` block positions (e.g. jamba:
1 attn + 7 mamba).  Parameters for each position are stacked over
``n_groups`` and the stack is a single ``lax.scan``, so HLO size is
O(period), not O(depth) — mistral-large's 88 layers lower as one scan of 22
groups (essential for the 1-CPU multi-pod dry-run, and what a real TPU build
wants anyway).

Three entry points (all pure, jit/pjit-able):

* :func:`forward`      — full-sequence hidden states (train / encoder);
* :func:`train_loss`   — CE loss + MoE aux losses + metrics;
* :func:`prefill` / :func:`decode_step` — serving with per-kind caches
  (KV / MLA-latent / mamba-state / rwkv-state), carried as scan xs/ys.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import (attend_decode, attend_full, attn_spec,
                        cache_from_prefill, init_kv_cache, kv_cache_struct)
from .config import ModelConfig
from .frontends import embed_audio, embed_vision, frontend_spec
from .layers import (apply_mlp, apply_norm, cdtype, cross_entropy,
                     embed_spec, embed_tokens, logits_from_hidden, mlp_spec,
                     norm_spec, residual_scale)
from .mamba import (init_mamba_state, mamba_decode, mamba_full, mamba_spec,
                    mamba_state_struct)
from .mla import (init_mla_cache, mla_cache_from_prefill, mla_cache_struct,
                  mla_decode, mla_full, mla_spec)
from .moe import apply_moe, moe_spec
from .rwkv import (init_rwkv_state, rwkv_channel_mix, rwkv_spec,
                   rwkv_state_struct, rwkv_time_mix)

AUX_LB_COEF = 0.01      # load-balance loss weight
AUX_Z_COEF = 0.001      # router z-loss weight

_BLOCK_SPECS = {"attn": attn_spec, "mla": mla_spec, "mamba": mamba_spec,
                "rwkv": rwkv_spec}


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------
def _position_spec(cfg: ModelConfig, kind: str, mlp_kind: str, stacked: int):
    out = {"norm1": norm_spec(cfg, stacked),
           "block": _BLOCK_SPECS[kind](cfg, stacked)}
    if mlp_kind == "dense":
        out["norm2"] = norm_spec(cfg, stacked)
        out["mlp"] = mlp_spec(cfg, cfg.d_ff, stacked)
    elif mlp_kind == "moe":
        out["norm2"] = norm_spec(cfg, stacked)
        out["mlp"] = moe_spec(cfg, stacked)
    elif kind == "rwkv":
        out["norm2"] = norm_spec(cfg, stacked)   # channel-mix pre-norm
    return out


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    g = cfg.n_groups
    spec: Dict[str, Any] = {
        "embed": embed_spec(cfg),
        "final_norm": norm_spec(cfg),
        "blocks": {
            f"pos{i}": _position_spec(cfg, kind, mlp_kind, g)
            for i, (kind, mlp_kind) in enumerate(
                zip(cfg.block_pattern, cfg.mlp_pattern))
        },
    }
    if cfg.first_layer_dense:
        first_kind = cfg.block_pattern[0]
        spec["layer0"] = {
            "norm1": norm_spec(cfg),
            "block": _BLOCK_SPECS[first_kind](cfg, 0),
            "norm2": norm_spec(cfg),
            "mlp": mlp_spec(cfg, cfg.d_ff_dense or cfg.d_ff, 0),
        }
    fe = frontend_spec(cfg)
    if fe:
        spec["frontend"] = fe
    return spec


# ---------------------------------------------------------------------------
# Embedding of model inputs
# ---------------------------------------------------------------------------
def embed_inputs(params, inputs: Dict[str, jax.Array], cfg: ModelConfig
                 ) -> jax.Array:
    """inputs: {"tokens": (B,S)} [+ "patches" (B,P,F) | "frames" (B,S,F)]."""
    if cfg.frontend == "audio":
        return embed_audio(params["frontend"], inputs["frames"], cfg)
    x = embed_tokens(params["embed"], inputs["tokens"], cfg)
    if cfg.frontend == "vision" and "patches" in inputs:
        prefix = embed_vision(params["frontend"], inputs["patches"], cfg)
        x = jnp.concatenate([prefix, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# One block position (shared by train / prefill / decode bodies)
# ---------------------------------------------------------------------------
def _apply_position(p, x, cfg: ModelConfig, kind: str, mlp_kind: str, *,
                    mode: str = "train", cache=None, pos=None):
    """Returns (x, aux (2,), new_cache_or_None)."""
    rs = residual_scale(cfg)
    aux = jnp.zeros((2,), jnp.float32)
    new_cache = None

    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        if mode == "decode":
            out, new_cache = attend_decode(p["block"], h, cache, pos, cfg)
        elif mode == "prefill":
            out, (k, v) = attend_full(p["block"], h, cfg, return_kv=True)
            new_cache = (k, v)
        else:
            out = attend_full(p["block"], h, cfg)
    elif kind == "mla":
        if mode == "decode":
            out, new_cache = mla_decode(p["block"], h, cache, pos, cfg)
        elif mode == "prefill":
            out, new_cache = mla_full(p["block"], h, cfg, return_cache=True)
        else:
            out = mla_full(p["block"], h, cfg)
    elif kind == "mamba":
        if mode == "decode":
            out, new_cache = mamba_decode(p["block"], h, cache, cfg)
        elif mode == "prefill":
            out, new_cache = mamba_full(p["block"], h, cfg, return_state=True)
        else:
            out = mamba_full(p["block"], h, cfg)
    elif kind == "rwkv":
        if mode == "decode":
            tlast, wkv, clast = cache
            out, (tlast2, wkv2) = rwkv_time_mix(
                p["block"], h, cfg, state=(tlast, wkv), return_state=True)
            x = x + out * rs
            h2 = apply_norm(p["norm2"], x, cfg)
            out2, clast2 = rwkv_channel_mix(p["block"], h2, cfg,
                                            last_x=clast, return_state=True)
            x = x + out2 * rs
            return x, aux, (tlast2, wkv2, clast2)
        elif mode == "prefill":
            zs = init_rwkv_state(cfg, x.shape[0], cdtype(cfg))
            out, (tlast2, wkv2) = rwkv_time_mix(
                p["block"], h, cfg, state=(zs[0], zs[1]), return_state=True)
            x = x + out * rs
            h2 = apply_norm(p["norm2"], x, cfg)
            out2, clast2 = rwkv_channel_mix(p["block"], h2, cfg,
                                            last_x=zs[2], return_state=True)
            x = x + out2 * rs
            return x, aux, (tlast2, wkv2, clast2)
        else:
            out = rwkv_time_mix(p["block"], h, cfg)
            x = x + out * rs
            h2 = apply_norm(p["norm2"], x, cfg)
            x = x + rwkv_channel_mix(p["block"], h2, cfg) * rs
            return x, aux, None
    else:
        raise ValueError(f"unknown block kind {kind}")

    x = x + out * rs
    if mlp_kind != "none":
        h2 = apply_norm(p["norm2"], x, cfg)
        if mlp_kind == "moe":
            b, s, _ = h2.shape
            gs = s if mode != "decode" else max(1, (b * s) // 16)
            m_out, aux = apply_moe(p["mlp"], h2, cfg, group_size=gs)
        else:
            m_out = apply_mlp(p["mlp"], h2, cfg)
        x = x + m_out * rs
    x = constrain(x, "batch", "seq", None)
    return x, aux, new_cache


def _apply_layer0(params, x, cfg: ModelConfig, *, mode="train", cache=None,
                  pos=None):
    """deepseek's dense first layer (same block kind, dense MLP)."""
    p = params["layer0"]
    kind = cfg.block_pattern[0]
    rs = residual_scale(cfg)
    h = apply_norm(p["norm1"], x, cfg)
    new_cache = None
    if kind == "mla":
        if mode == "decode":
            out, new_cache = mla_decode(p["block"], h, cache, pos, cfg)
        elif mode == "prefill":
            out, new_cache = mla_full(p["block"], h, cfg, return_cache=True)
        else:
            out = mla_full(p["block"], h, cfg)
    else:
        if mode == "decode":
            out, new_cache = attend_decode(p["block"], h, cache, pos, cfg)
        elif mode == "prefill":
            out, (k, v) = attend_full(p["block"], h, cfg, return_kv=True)
            new_cache = (k, v)
        else:
            out = attend_full(p["block"], h, cfg)
    x = x + out * rs
    h2 = apply_norm(p["norm2"], x, cfg)
    x = x + apply_mlp(p["mlp"], h2, cfg) * rs
    return x, new_cache


# ---------------------------------------------------------------------------
# Full-sequence forward (train / encode)
# ---------------------------------------------------------------------------
_REMAT_POLICIES = {
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def _gather_group_params(group_params, cfg: ModelConfig):
    """Explicit FSDP unshard: constrain every weight of this scan group to
    drop its "embed" (data-axis) sharding.  GSPMD then emits ONE all-gather
    per weight per group step (≈ params/n_groups bytes) and a backward
    reduce-scatter, instead of partial-sum all-reducing full activation
    tensors at every matmul — the classic ZeRO-3 forward schedule.  The
    gathers pipeline against the previous group's compute inside the scan.
    """
    from .params import logical_axes  # local: avoid import cycle at load

    dt = cdtype(cfg)

    def unshard(arr, ax):
        a = ax[1:] if (ax and ax[0] == "layers") else ax
        if "expert" in a:
            # EP is weight-stationary: tokens all-to-all to the experts;
            # gathering 16x expert weights per group would cost GiBs of
            # residency for nothing (measured on jamba train_4k, §Perf)
            return arr
        a = tuple(None if name == "embed" else name for name in a)
        # gather big matrices in the compute dtype: halves all-gather bytes
        # (fp32 master -> bf16 cast happens *before* the unshard constraint)
        if arr.ndim >= 2 and arr.dtype == jnp.float32 and cfg.dtype != "float32":
            arr = arr.astype(dt)
        return constrain(arr, *a)

    gathered = {}
    for i, (kind, mlp_kind) in enumerate(
            zip(cfg.block_pattern, cfg.mlp_pattern)):
        sub = group_params[f"pos{i}"]
        spec = _position_spec(cfg, kind, mlp_kind, stacked=1)
        arrs, tdef = jax.tree_util.tree_flatten(sub)
        axes = jax.tree_util.tree_leaves(
            logical_axes(spec), is_leaf=lambda x: isinstance(x, tuple))
        gathered[f"pos{i}"] = jax.tree_util.tree_unflatten(
            tdef, [unshard(a, ax) for a, ax in zip(arrs, axes)])
    return gathered


def _maybe_remat(fn, cfg: ModelConfig):
    """Per-layer-group remat: only the scan carry survives between groups;
    block internals are recomputed in backward per the policy.  This is what
    bounds train activation memory to O(1) in depth (EXPERIMENTS §Dry-run)."""
    if cfg.remat == "none":
        return fn
    policy = getattr(jax.checkpoint_policies, _REMAT_POLICIES[cfg.remat])
    return jax.checkpoint(fn, policy=policy)


def forward(params, inputs: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """-> (hidden (B, S, D), aux_losses (2,))."""
    x = embed_inputs(params, inputs, cfg)
    x = constrain(x, "batch", "seq", None)
    aux0 = jnp.zeros((2,), jnp.float32)
    if cfg.first_layer_dense:
        x, _ = _apply_layer0(params, x, cfg, mode="train")

    def group(x, group_params):
        if cfg.fsdp_gather_weights:
            group_params = _gather_group_params(group_params, cfg)
        aux = jnp.zeros((2,), jnp.float32)
        for i, (kind, mlp_kind) in enumerate(
                zip(cfg.block_pattern, cfg.mlp_pattern)):
            x, a, _ = _apply_position(group_params[f"pos{i}"], x, cfg,
                                      kind, mlp_kind, mode="train")
            aux = aux + a
        return x, aux

    group = _maybe_remat(group, cfg)

    def body(carry, group_params):
        x, aux = carry
        x, a = group(x, group_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux


def train_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """batch: {"tokens"/"frames", "labels", optional "mask"} → (loss, metrics)."""
    hidden, aux = forward(params, batch, cfg)
    logits = logits_from_hidden(params["embed"], hidden, cfg)
    labels = batch["labels"]
    mask = batch.get("mask")
    if logits.shape[1] != labels.shape[1]:        # vision prefix: no loss
        prefix = logits.shape[1] - labels.shape[1]
        logits = logits[:, prefix:]
    ce = cross_entropy(logits, labels, mask)
    loss = ce + AUX_LB_COEF * aux[0] + AUX_Z_COEF * aux[1]
    metrics = {"ce": ce, "load_balance": aux[0], "router_z": aux[1],
               "loss": loss}
    return loss, metrics


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def _position_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype, make):
    if kind == "attn":
        fns = {"init": init_kv_cache, "struct": kv_cache_struct}
        return fns[make](cfg, batch, max_len, dtype)
    if kind == "mla":
        fns = {"init": init_mla_cache, "struct": mla_cache_struct}
        return fns[make](cfg, batch, max_len, dtype)
    if kind == "mamba":
        fns = {"init": init_mamba_state, "struct": mamba_state_struct}
        return fns[make](cfg, batch, dtype)
    if kind == "rwkv":
        fns = {"init": init_rwkv_state, "struct": rwkv_state_struct}
        return fns[make](cfg, batch, dtype)
    raise ValueError(kind)


def _stack_struct(tree, n: int):
    return jax.tree_util.tree_map(
        lambda s: (jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
                   if isinstance(s, jax.ShapeDtypeStruct)
                   else jnp.broadcast_to(s, (n,) + s.shape)), tree)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, make: str = "init"):
    """Cache pytree: {"pos{i}": stacked-over-groups per-kind state}
    [+ "layer0" for deepseek].  ``make="struct"`` gives ShapeDtypeStructs."""
    cache: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.block_pattern):
        per = _position_cache(cfg, kind, batch, max_len, dtype, make)
        cache[f"pos{i}"] = _stack_struct(per, cfg.n_groups)
    if cfg.first_layer_dense:
        cache["layer0"] = _position_cache(cfg, cfg.block_pattern[0], batch,
                                          max_len, dtype, make)
    return cache


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return init_cache(cfg, batch, max_len, dtype, make="struct")


#: Logical sharding axes per cache-leaf kind (mirrors _position_cache).
_CACHE_AXES = {
    "attn": {"k": ("batch", "kv_heads", "kv_seq", None),
             "v": ("batch", "kv_heads", "kv_seq", None)},
    "mla": {"c_kv": ("batch", "kv_seq", None),
            "k_rope": ("batch", "kv_seq", None)},
    "mamba": (("batch", None, "mlp"), ("batch", "mlp", None)),
    "rwkv": (("batch", None), ("batch", "heads", None, None),
             ("batch", None)),
}


def cache_axes(cfg: ModelConfig):
    """Pytree of logical-axes tuples matching :func:`cache_struct` exactly
    (stacked positions gain a leading "layers" axis)."""
    def stacked(tree):
        return jax.tree_util.tree_map(
            lambda ax: ("layers",) + ax, tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        out[f"pos{i}"] = stacked(_CACHE_AXES[kind])
    if cfg.first_layer_dense:
        out["layer0"] = _CACHE_AXES[cfg.block_pattern[0]]
    return out


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def prefill(params, inputs: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: int, cache_dtype=jnp.bfloat16):
    """Process the prompt; -> (last-token logits (B, Vp), cache at S)."""
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no prefill/decode")
    x = embed_inputs(params, inputs, cfg)
    x = constrain(x, "batch", "seq", None)
    cache: Dict[str, Any] = {}
    if cfg.first_layer_dense:
        x, c0 = _apply_layer0(params, x, cfg, mode="prefill")
        cache["layer0"] = _pad_prefill(cfg, cfg.block_pattern[0], c0,
                                       max_len, cache_dtype)

    def body(x, group_params):
        caches = []
        for i, (kind, mlp_kind) in enumerate(
                zip(cfg.block_pattern, cfg.mlp_pattern)):
            x, _, c = _apply_position(group_params[f"pos{i}"], x, cfg,
                                      kind, mlp_kind, mode="prefill")
            caches.append(_pad_prefill(cfg, kind, c, max_len, cache_dtype))
        return x, tuple(caches)

    x, stacked = jax.lax.scan(body, x, params["blocks"])
    for i in range(cfg.period):
        cache[f"pos{i}"] = stacked[i]
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, cache


def _pad_prefill(cfg, kind, c, max_len, dtype):
    if kind == "attn":
        return cache_from_prefill(cfg, c[0], c[1], max_len, dtype)
    if kind == "mla":
        return mla_cache_from_prefill(cfg, c[0], c[1], max_len, dtype)
    return c    # mamba / rwkv states are O(1): stored as-is


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_step(params, cache, tokens: jax.Array, pos, cfg: ModelConfig):
    """One token for every sequence.  tokens (B,) int32, pos scalar int32.

    Returns (logits (B, Vp) fp32, updated cache).
    """
    if cfg.is_encoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    x = embed_tokens(params["embed"], tokens[:, None], cfg)
    x = constrain(x, "batch", None, None)
    if cfg.first_layer_dense:
        x, c0 = _apply_layer0(params, x, cfg, mode="decode",
                              cache=cache["layer0"], pos=pos)
        new_layer0 = c0

    def body(x, xs):
        group_params, group_cache = xs
        new_caches = []
        for i, (kind, mlp_kind) in enumerate(
                zip(cfg.block_pattern, cfg.mlp_pattern)):
            x, _, c = _apply_position(
                group_params[f"pos{i}"], x, cfg, kind, mlp_kind,
                mode="decode", cache=group_cache[f"pos{i}"], pos=pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    scan_cache = {k: v for k, v in cache.items() if k != "layer0"}
    x, stacked = jax.lax.scan(body, x, (params["blocks"], scan_cache))
    new_cache = {f"pos{i}": stacked[i] for i in range(cfg.period)}
    if cfg.first_layer_dense:
        new_cache["layer0"] = new_layer0
    x = apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params["embed"], x, cfg)[:, 0]
    return logits, new_cache
