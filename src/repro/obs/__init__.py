"""repro.obs — end-to-end observability: spans, traces, metrics.

The machine model already knows where every modeled nanosecond of a
request goes; this package makes that knowledge inspectable.  Three
pieces:

* :class:`Tracer` / :class:`Span` — per-request span trees with explicit
  parent links, recorded on the *modeled virtual clock* (the same injected
  clock + per-lane ``modeled_busy_until`` discipline as the goodput
  gates), so traces are deterministic and assertable;
* :meth:`Tracer.to_chrome_json` — a Perfetto/Chrome-trace exporter:
  request trees and per-lane launch slices (sized by each node's captured
  :class:`~repro.core.machine.PhaseBreakdown`, laid out along the DAG
  critical path so concurrent branches visibly overlap);
* :class:`MetricsRegistry` with :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` — the unified telemetry registry the serving
  counters publish into, dumping as :meth:`MetricsRegistry.snapshot` or
  Prometheus text.

Tracing is opt-in and zero-overhead-when-off: ``Server(tracer=...)`` and
``CommandQueue(tracer=...)`` take a tracer explicitly, every hook guards
on ``tracer is not None``, and telemetry never perturbs modeled totals,
goodput, or outputs (the traced benchmark arms assert bit-identity).

Worked example — tracing one request from submit to result::

    import jax.numpy as jnp
    from repro.core import EGPU_16T, Kernel, Stage
    from repro.obs import Tracer
    from repro.serve import Server

    class VClock:                      # the bench-style virtual clock
        t = 0.0
        def __call__(self):
            return self.t

    k = Kernel("scale", executor=lambda x: (x * 2.0,))
    clk, tracer = VClock(), Tracer()
    srv = Server([Stage(k, n_inputs=1)], workers=(EGPU_16T,),
                 bucket_sizes=(8,), max_batch=1, clock=clk, tracer=tracer)
    rid = srv.submit(jnp.ones((4, 4)))          # max_batch=1: launches now
    srv.flush()
    (out,) = srv.result(rid)

    root = tracer.request_root(rid)             # the rid's span tree:
    for s in tracer.children(root):             #   admission   [t0, t0]
        print(s.name, s.t0, s.t1)               #   bucket-wait [t0, t_launch]
                                                #   dispatch    [t_launch, t_x]
                                                #   execute     [t_x, t_done]
                                                #   result      [t_done, t_done]
    assert tracer.validate_request_trees() == []
    tracer.to_chrome_json("trace.json")         # open in ui.perfetto.dev

The serving stack emits spans at every hop — submit, admission,
bucket-wait, deadline-flush, dispatch-pick, retry/backoff, launch,
per-stage kernel+transfer execution, retire, result — with fault
injections, breaker trips, shed decisions, and cache hits/misses attached
as span events.  :meth:`Tracer.validate_request_trees` pins the
completeness contract: every accepted rid's tree closes with exactly one
terminal span (``result`` or a named ``shed``).

Power telemetry (ISSUE 8): serving under a
:class:`~repro.serve.PowerBudget` lands in the same channels — the
dispatcher emits a ``power-throttle`` track instant for every candidate
lane skipped over a budget breach and a ``power-shed`` request event on
every rid shed because no lane had headroom, while
``ServeReport.publish_metrics`` adds the fleet power series
(``repro_fleet_avg_power_watts``, ``repro_fleet_peak_power_watts``,
``repro_fleet_energy_joules`` / ``repro_fleet_idle_energy_joules``,
``repro_serve_requests_per_second_per_watt``,
``repro_serve_goodput_per_second_per_watt``) and the enforcement
counters (``repro_serve_power_shed_total``,
``repro_serve_power_throttled_total``,
``repro_serve_budget_violations_total`` — the last must read 0) plus
per-lane ``repro_lane_idle_power_watts`` /
``repro_lane_budget_violations_total``.
"""

from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .trace import (TERMINAL_SPANS, Span, Tracer, validate_chrome_trace)

__all__ = [
    "Counter",
    "DEFAULT_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "TERMINAL_SPANS",
    "Tracer",
    "validate_chrome_trace",
]
