"""Unified telemetry registry: Counter / Gauge / Histogram with label sets.

Every layer of the serving stack keeps counters (``GraphCache`` hit/miss,
``QueueStats`` per-lane totals, ``FaultPlan`` injections, the
``ServeReport`` roll-up).  The registry gives them one place to publish:
metrics are named, typed, carry label sets (``lane="0:e-gpu-16t"``), and
dump as either a nested :meth:`MetricsRegistry.snapshot` dict or a
Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus_text`).

Two publishing styles coexist:

* **live instruments** — call :meth:`Counter.inc` / :meth:`Gauge.set` /
  :meth:`Histogram.observe` at the event site;
* **snapshot publishers** — sources that already keep their own monotonic
  totals (the serving counters) write them with :meth:`Counter.set_total`,
  which is idempotent: re-publishing the same totals never double-counts.
  ``GraphCache.publish_metrics``, ``FaultPlan.publish_metrics``,
  ``QueueStats.publish_metrics`` and ``ServeReport.publish_metrics`` all
  use this style, and ``Server.publish_metrics(registry)`` drives the
  whole stack in one call.

Telemetry is observational only: publishing reads totals the stack already
keeps and never perturbs modeled time, energy, or outputs.
"""

from __future__ import annotations

import math
import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram buckets (seconds-flavored, microseconds to seconds)
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey) -> str:
    if not key:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + body + "}"


class _Metric:
    """Shared naming/label plumbing for the three instrument types."""

    type_name = "untyped"

    def __init__(self, name: str, help: str = ""):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, Any] = {}

    def labels(self) -> List[LabelKey]:
        return sorted(self._series)


class Counter(_Metric):
    """Monotonically non-decreasing count (per label set)."""

    type_name = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc by {amount})")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def set_total(self, total: float, **labels: Any) -> None:
        """Publish an externally-kept monotonic total (idempotent — the
        snapshot-publisher style).  Decreasing an already-published total
        is loud: that is a broken source, not a restart we can infer."""
        key = _label_key(labels)
        if total < self._series.get(key, 0.0):
            raise ValueError(
                f"counter {self.name}{_fmt_labels(key)} cannot decrease "
                f"from {self._series[key]} to {total}")
        self._series[key] = float(total)

    def value(self, **labels: Any) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """A value that can go anywhere (per label set)."""

    type_name = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        self._series[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics) per label set."""

    type_name = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        ordered = tuple(sorted(float(b) for b in buckets))
        if not ordered or any(not math.isfinite(b) for b in ordered):
            raise ValueError(f"buckets must be finite and non-empty, "
                             f"got {buckets}")
        self.buckets = ordered

    def _cell(self, key: LabelKey) -> Dict[str, Any]:
        cell = self._series.get(key)
        if cell is None:
            cell = {"bucket_counts": [0] * len(self.buckets),
                    "count": 0, "sum": 0.0, "max": float("-inf")}
            self._series[key] = cell
        return cell

    def observe(self, value: float, **labels: Any) -> None:
        cell = self._cell(_label_key(labels))
        for i, le in enumerate(self.buckets):
            if value <= le:
                cell["bucket_counts"][i] += 1
        cell["count"] += 1
        cell["sum"] += float(value)
        cell["max"] = max(cell["max"], float(value))

    def value(self, **labels: Any) -> Dict[str, Any]:
        cell = self._cell(_label_key(labels))
        return {"count": cell["count"], "sum": cell["sum"],
                "buckets": dict(zip(self.buckets, cell["bucket_counts"]))}

    def quantile(self, q: float, **labels: Any) -> float:
        """Bucket-resolution quantile estimate (upper bound of the first
        bucket covering the target rank, clamped to the observed max)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        cell = self._cell(_label_key(labels))
        if cell["count"] == 0:
            return 0.0
        target = q * cell["count"]
        for le, cum in zip(self.buckets, cell["bucket_counts"]):
            if cum >= target:
                return min(le, cell["max"])
        return cell["max"]


class MetricsRegistry:
    """Named, typed metrics with get-or-create registration.

    Re-registering a name returns the existing instrument (so independent
    publishers can share a series); re-registering under a *different*
    type is loud.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       **kwargs: Any) -> _Metric:
        existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.type_name}, not {cls.type_name}")
            return existing
        metric = cls(name, help, **kwargs)
        self._metrics[name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def __iter__(self) -> Iterable[_Metric]:
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump: name -> {type, help, samples: [{labels, value}]}."""
        out: Dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            samples = []
            for key in m.labels():
                value = (m.value(**dict(key)) if not isinstance(m, Histogram)
                         else m.value(**dict(key)))
                samples.append({"labels": dict(key), "value": value})
            out[name] = {"type": m.type_name, "help": m.help,
                         "samples": samples}
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.type_name}")
            if isinstance(m, Histogram):
                for key in m.labels():
                    cell = m._series[key]
                    cum_pairs = list(zip(m.buckets, cell["bucket_counts"]))
                    for le, cum in cum_pairs:
                        k = key + (("le", repr(le)),)
                        lines.append(
                            f"{name}_bucket{_fmt_labels(k)} {cum}")
                    k = key + (("le", "+Inf"),)
                    lines.append(
                        f"{name}_bucket{_fmt_labels(k)} {cell['count']}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)} {cell['sum']}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {cell['count']}")
            else:
                for key in m.labels():
                    lines.append(
                        f"{name}{_fmt_labels(key)} {m._series[key]}")
        return "\n".join(lines) + ("\n" if lines else "")
