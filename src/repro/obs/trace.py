"""Span tracing on the modeled virtual clock.

A :class:`Tracer` records :class:`Span`\\ s — named intervals with explicit
parent links — on whatever clock the caller timestamps them with.  The
serving stack timestamps every span with the *modeled* virtual timeline
(the injected server clock plus each lane's ``modeled_busy_until``
machine-model schedule), so a trace is deterministic: two runs of the same
traffic produce byte-identical span trees, and CI can gate on their shape.

Tracing is strictly observational.  The tracer never touches modeled
totals, goodput, or outputs — it only *reads* timestamps the serving stack
already computes — and every integration point guards with
``if tracer is not None``, so a server built without a tracer allocates no
object from this module on its hot dispatch path.

Request trees
-------------

The serving layer (``Server(tracer=...)``) grows one span tree per accepted
request id, on the track ``rid:<rid>``::

    request                    [t_submit ........................ t_done]
      admission                [t_submit, t_submit]
      bucket-wait              [t_submit ......... t_launch]
      dispatch                 [t_launch ... exec_start]
      execute                  [exec_start ......... t_done]
      result | shed            [t_end, t_end]        <- exactly one terminal

with the mid-flight happenings — deadline flushes, dispatch picks, injected
faults, retries/backoff, breaker trips, cache hits/misses — attached to the
root as timestamped span *events*.  :meth:`Tracer.validate_request_trees`
checks the completeness contract: every accepted rid's tree is closed and
ends in exactly one terminal span named ``result`` or ``shed``.

Each dispatcher lane additionally gets a ``lane:<name>`` track holding one
``launch`` slice per micro-batch, decomposed into per-node kernel/transfer
slices sized by the captured :class:`~repro.core.machine.PhaseBreakdown`
and laid out along the node DAG's critical-path schedule — concurrent
branches visibly overlap in the exported trace.

Export
------

:meth:`Tracer.to_chrome_json` writes the Chrome trace event format (the
``chrome://tracing`` / Perfetto JSON): spans become ``"ph": "X"`` complete
events (``ts``/``dur`` in microseconds of virtual time), span events become
``"ph": "i"`` instants, and tracks map to pid/tid pairs named via metadata
events.  :func:`validate_chrome_trace` is the schema gate CI runs on the
artifact: required keys, non-negative durations, monotonic timestamps per
track, and no orphan parent ids.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: span names that terminate a request tree (exactly one per accepted rid)
TERMINAL_SPANS = ("result", "shed")


@dataclasses.dataclass
class Span:
    """One named interval on a track, with an explicit parent link.

    ``t0``/``t1`` are seconds on the caller's (virtual) clock; ``t1`` is
    ``None`` while the span is open.  ``events`` are timestamped point
    annotations inside the span (fault injected, retry, breaker trip...).
    """

    span_id: int
    name: str
    track: str
    t0: float
    t1: Optional[float] = None
    parent_id: Optional[int] = None
    rid: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    events: List[Tuple[float, str, Dict[str, Any]]] = \
        dataclasses.field(default_factory=list)

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0


class Tracer:
    """Collects spans; knows nothing about time except what callers stamp.

    The low-level API (:meth:`begin`/:meth:`end`/:meth:`span`/
    :meth:`event`/:meth:`instant`) records arbitrary spans.  The
    request-tree helpers (:meth:`begin_request` /
    :meth:`request_event` / :meth:`child` / :meth:`finish_request`)
    maintain the per-rid trees the serving stack emits and
    :meth:`validate_request_trees` checks.
    """

    def __init__(self) -> None:
        self._ids = itertools.count()
        self.spans: List[Span] = []
        #: track-level point annotations outside any span:
        #: (track, t, name, attrs)
        self.instants: List[Tuple[str, float, str, Dict[str, Any]]] = []
        self._by_id: Dict[int, Span] = {}
        self._roots: Dict[int, Span] = {}        # rid -> root span
        self._open_rids: Dict[int, Span] = {}    # rid -> still-open root

    # -- low-level spans -----------------------------------------------------
    def begin(self, name: str, t: float, track: str = "server",
              parent: Optional[Span] = None, rid: Optional[int] = None,
              **attrs: Any) -> Span:
        span = Span(span_id=next(self._ids), name=name, track=track,
                    t0=float(t),
                    parent_id=None if parent is None else parent.span_id,
                    rid=rid, attrs=attrs)
        self.spans.append(span)
        self._by_id[span.span_id] = span
        return span

    def end(self, span: Span, t: float) -> Span:
        if not span.open:
            raise RuntimeError(f"span {span.name!r} already ended")
        if float(t) < span.t0:
            raise ValueError(
                f"span {span.name!r} cannot end at {t} before start {span.t0}")
        span.t1 = float(t)
        return span

    def span(self, name: str, t0: float, t1: float, track: str = "server",
             parent: Optional[Span] = None, rid: Optional[int] = None,
             **attrs: Any) -> Span:
        """Record an already-closed span (the retroactive form the serving
        layer uses once a launch's modeled schedule is known)."""
        return self.end(self.begin(name, t0, track=track, parent=parent,
                                   rid=rid, **attrs), t1)

    def event(self, span: Span, t: float, name: str, **attrs: Any) -> None:
        span.events.append((float(t), name, attrs))

    def instant(self, track: str, t: float, name: str, **attrs: Any) -> None:
        """A track-level point annotation outside any span (e.g. a request
        shed at the door before it ever got a rid)."""
        self.instants.append((track, float(t), name, attrs))

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span.span_id]

    # -- request trees -------------------------------------------------------
    @staticmethod
    def request_track(rid: int) -> str:
        return f"rid:{rid}"

    def begin_request(self, rid: int, t: float, **attrs: Any) -> Span:
        """Open rid's root span (``request``) plus its zero-width
        ``admission`` child marking the accepted admission decision."""
        if rid in self._roots:
            raise RuntimeError(f"request {rid} already has a root span")
        root = self.begin("request", t, track=self.request_track(rid),
                          rid=rid, **attrs)
        self._roots[rid] = root
        self._open_rids[rid] = root
        self.span("admission", t, t, track=root.track, parent=root, rid=rid)
        return root

    def request_root(self, rid: int) -> Optional[Span]:
        return self._roots.get(rid)

    def request_rids(self) -> List[int]:
        return sorted(self._roots)

    def request_event(self, rid: int, t: float, name: str,
                      **attrs: Any) -> None:
        """Attach a point event to rid's open root (no-op for unknown or
        already-finished rids, so late bookkeeping can't corrupt a tree)."""
        root = self._open_rids.get(rid)
        if root is not None:
            self.event(root, t, name, **attrs)

    def child(self, rid: int, name: str, t0: float, t1: float,
              **attrs: Any) -> Optional[Span]:
        """A closed child span under rid's root, on the rid's track."""
        root = self._roots.get(rid)
        if root is None:
            return None
        return self.span(name, t0, t1, track=root.track, parent=root,
                         rid=rid, **attrs)

    def finish_request(self, rid: int, t: float, terminal: str,
                       **attrs: Any) -> Optional[Span]:
        """Close rid's tree with its terminal span (``result`` or ``shed``).

        Idempotent-safe: a rid whose tree is already closed (or that was
        never opened — tracer installed mid-run) is left untouched.
        """
        if terminal not in TERMINAL_SPANS:
            raise ValueError(f"terminal must be one of {TERMINAL_SPANS}, "
                             f"got {terminal!r}")
        root = self._open_rids.pop(rid, None)
        if root is None:
            return None
        term = self.span(terminal, t, t, track=root.track, parent=root,
                         rid=rid, **attrs)
        self.end(root, t)
        return term

    def validate_request_trees(self, rids: Optional[Sequence[int]] = None
                               ) -> List[str]:
        """The completeness contract, as a list of violations (empty = OK).

        For every rid (default: all rids ever opened): the root exists and
        is closed, every span in its tree is closed, and the tree ends in
        *exactly one* terminal span (``result`` or a named ``shed``) —
        never a dangling request.
        """
        errors = []
        for rid in (self.request_rids() if rids is None else rids):
            root = self._roots.get(rid)
            if root is None:
                errors.append(f"rid {rid}: no root span")
                continue
            if root.open:
                errors.append(f"rid {rid}: root span never closed (dangling)")
            kids = self.children(root)
            for s in kids:
                if s.open:
                    errors.append(f"rid {rid}: child span {s.name!r} "
                                  "never closed (dangling)")
            terminals = [s for s in kids if s.name in TERMINAL_SPANS]
            if len(terminals) != 1:
                errors.append(
                    f"rid {rid}: expected exactly one terminal span, got "
                    f"{[s.name for s in terminals]}")
            elif root.t1 is not None and terminals[0].t0 != root.t1:
                errors.append(
                    f"rid {rid}: terminal {terminals[0].name!r} at "
                    f"{terminals[0].t0} != root end {root.t1}")
        return errors

    # -- Chrome trace export -------------------------------------------------
    def _track_ids(self) -> Dict[str, Tuple[int, int]]:
        """Stable (pid, tid) per track: requests under one process, lanes
        under another, queues a third, everything else under ``server``."""
        groups = {"rid": 1, "lane": 2, "queue": 3}
        tracks = sorted({s.track for s in self.spans}
                        | {t for (t, _, _, _) in self.instants})
        out: Dict[str, Tuple[int, int]] = {}
        next_tid = {pid: itertools.count(1) for pid in (1, 2, 3, 4)}
        for track in tracks:
            prefix = track.split(":", 1)[0]
            pid = groups.get(prefix, 4)
            if pid == 1:
                try:                      # rid tracks keep their rid as tid
                    out[track] = (1, int(track.split(":", 1)[1]))
                    continue
                except ValueError:
                    pass
            out[track] = (pid, next(next_tid[pid]))
        return out

    def to_chrome_json(self, path: Optional[Any] = None) -> Dict[str, Any]:
        """The trace in Chrome trace-event JSON (Perfetto-loadable).

        Virtual-clock seconds map to microsecond ``ts``/``dur``.  When
        ``path`` is given the document is also written there.
        """
        track_ids = self._track_ids()
        pid_names = {1: "requests", 2: "lanes", 3: "queues", 4: "server"}
        events: List[Dict[str, Any]] = []
        for pid in sorted({p for (p, _) in track_ids.values()}):
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": pid_names[pid]}})
        for track, (pid, tid) in sorted(track_ids.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": track}})

        timed: List[Dict[str, Any]] = []
        for s in self.spans:
            pid, tid = track_ids[s.track]
            t1 = s.t0 if s.t1 is None else s.t1
            args = {"span_id": s.span_id, **s.attrs}
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            if s.rid is not None:
                args["rid"] = s.rid
            timed.append({"ph": "X", "name": s.name, "cat": s.track,
                          "ts": s.t0 * 1e6, "dur": (t1 - s.t0) * 1e6,
                          "pid": pid, "tid": tid, "args": args})
            for (t, name, attrs) in s.events:
                timed.append({"ph": "i", "name": name, "cat": s.track,
                              "ts": t * 1e6, "s": "t", "pid": pid,
                              "tid": tid,
                              "args": {"span_id": s.span_id, **attrs}})
        for (track, t, name, attrs) in self.instants:
            pid, tid = track_ids[track]
            timed.append({"ph": "i", "name": name, "cat": track,
                          "ts": t * 1e6, "s": "t", "pid": pid, "tid": tid,
                          "args": dict(attrs)})
        # monotonic per track by construction of the sort (validated by
        # validate_chrome_trace; ties keep emission order — Python's sort
        # is stable)
        timed.sort(key=lambda e: (e["pid"], e["tid"], e["ts"]))
        events.extend(timed)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"clock": "modeled-virtual",
                             "n_spans": len(self.spans)}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, default=str)
        return doc


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema-validate a Chrome trace document (the CI artifact gate).

    Checks the required top-level/per-event keys, non-negative durations,
    *monotonic* timestamps per (pid, tid) track, and that every
    ``args.parent_id`` references a ``span_id`` present in the document —
    no orphan parents.  Returns a list of violations (empty = valid).
    """
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing required top-level key 'traceEvents'"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    span_ids = set()
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, ev in enumerate(events):
        for key in ("ph", "name", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i}: missing required key {key!r}")
        ph = ev.get("ph")
        if ph == "M":
            continue
        if "ts" not in ev:
            errors.append(f"event {i}: timed event missing 'ts'")
            continue
        if ph == "X":
            if "dur" not in ev:
                errors.append(f"event {i}: complete event missing 'dur'")
            elif ev["dur"] < 0:
                errors.append(f"event {i}: negative dur {ev['dur']}")
            sid = ev.get("args", {}).get("span_id")
            if sid is not None:
                span_ids.add(sid)
        track = (ev.get("pid"), ev.get("tid"))
        if ev["ts"] < last_ts.get(track, float("-inf")):
            errors.append(
                f"event {i}: ts {ev['ts']} not monotonic on track {track} "
                f"(last {last_ts[track]})")
        last_ts[track] = ev["ts"]
    for i, ev in enumerate(events):
        parent = ev.get("args", {}).get("parent_id")
        if parent is not None and parent not in span_ids:
            errors.append(f"event {i}: orphan parent_id {parent}")
    return errors
