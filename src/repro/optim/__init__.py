"""repro.optim — AdamW (bf16 moments), schedules, clipping. optax-free."""

from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import constant_schedule, cosine_schedule, wsd_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "wsd_schedule", "cosine_schedule", "constant_schedule"]
