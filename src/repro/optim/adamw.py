"""AdamW with bf16 moments and global-norm clipping.

Memory layout is the production concern at 398B params: fp32 params +
fp32 m + fp32 v is 12 B/param — over v5e's 16 GiB/chip even sharded 256-way
for jamba.  We keep fp32 master params and store both Adam moments in
**bf16** (8 B/param total state), which fits every assigned arch on the
single-pod mesh (EXPERIMENTS.md §Dry-run memory table).  bf16 moments with
fp32 update math is a standard large-scale trick (the moment quantization
noise is far below gradient noise); tests verify convergence parity with
fp32 moments on a quadratic and a tiny LM.

All functions are pure pytree-to-pytree maps — they inherit the params'
sharding, so optimizer state is sharded exactly like the weights (ZeRO
comes free from GSPMD).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.bfloat16


def adamw_init(params):
    """(m, v, step) — moments in bf16, step scalar int32."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, lr, cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices, not norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
