"""Learning-rate schedules: WSD (MiniCPM), cosine, constant.

The WSD (warmup-stable-decay) schedule is part of the minicpm-2b assignment:
linear warmup → flat stable phase → exponential-ish decay over the last
``decay_frac`` of training.  Schedules are (step: int32) -> lr fp32, pure,
so they live inside the jitted train step.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, total_steps: int, *,
                 warmup_steps: int = 0, decay_frac: float = 0.1,
                 final_scale: float = 0.1) -> Callable:
    """MiniCPM WSD: warmup → stable at peak → decay to final_scale * peak."""
    warmup = max(1, warmup_steps or total_steps // 100)
    decay_start = int(total_steps * (1.0 - decay_frac))

    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        w = jnp.minimum(1.0, s / warmup)
        frac = jnp.clip((s - decay_start) / max(1, total_steps - decay_start),
                        0.0, 1.0)
        decay = final_scale ** frac          # exponential anneal
        return peak_lr * w * decay

    return lr


def cosine_schedule(peak_lr: float, total_steps: int, *,
                    warmup_steps: int = 0, final_scale: float = 0.1
                    ) -> Callable:
    warmup = max(1, warmup_steps or total_steps // 100)

    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        w = jnp.minimum(1.0, s / warmup)
        t = jnp.clip((s - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        cos = final_scale + (1 - final_scale) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak_lr * w * cos

    return lr


def constant_schedule(lr_value: float) -> Callable:
    def lr(step):
        return jnp.asarray(lr_value, jnp.float32)
    return lr
