"""repro.serve — the APU serving subsystem (ISSUE 2).

Turns the one-shot ``APU.offload`` pipeline into a long-lived serving
engine:

* :class:`GraphCache` — memoized compiled :class:`CommandGraph`\\ s across
  offloads (LRU, hit/miss/eviction counters);
* :class:`BucketBatcher` — shape-bucketed dynamic batching (pad-to-bucket,
  coalesce, crop back);
* :class:`MultiQueueDispatcher` / :class:`QueueWorker` — load-balanced
  multi-queue dispatch with in-flight-depth backpressure and per-queue
  machine-model accounting;
* :class:`ShardedWorker` (ISSUE 5) — a dispatcher lane spanning a
  :class:`jax.sharding.Mesh` slice: cached graphs are lowered with
  ``NamedSharding``\\ s derived from the ``repro.distributed`` rule table
  (batch -> data axes, divisibility fallback to replication), modeled
  totals scale by the shard count;
* :class:`Server` / :class:`ServeReport` — the front-end tying them
  together: submit -> batch -> cached fused launch -> per-request results +
  requests/s, modeled latency percentiles, per-mesh-axis utilization and
  energy per request;
* the open-loop front door (ISSUE 6) — SLO-aware intake
  (``Server.submit(deadline=..., priority=...)`` with modeled-capacity
  admission control and loud :class:`AdmissionError` sheds), deadline-aware
  partial-bucket flushing, and fault-tolerant dispatch: a deterministic
  seeded :class:`FaultPlan` (launch failures, latency spikes, lane
  :class:`Blackout`\\ s) injected at the worker launch gate, retried by the
  dispatcher onto other lanes with capped backoff, repeat offenders
  quarantined behind :class:`CircuitBreaker`\\ s with half-open probes —
  retried batches stay bit-identical to the fault-free path;
* observability (ISSUE 7) — built with ``Server(tracer=...)`` the whole
  request lifecycle lands in per-rid span trees on the modeled virtual
  clock (see :mod:`repro.obs`), ``Server.publish_metrics`` dumps the
  stack's telemetry into a :class:`~repro.obs.MetricsRegistry`, and
  :attr:`ServeReport.latency_decomposition_s` carries the p50/p99 flame
  attribution over :data:`DECOMP_PHASES`.  All opt-in: an untraced server
  allocates nothing from ``repro.obs`` on its hot dispatch path;
* power-budget-aware serving (ISSUE 8) — ``Server(power_budget=...)``
  installs a :class:`PowerBudget` (per-lane and/or fleet caps in mW, the
  paper's <= 28 mW envelope) on the dispatcher, which prices every
  candidate lane per launch (:class:`LanePrice`: modeled window,
  window-average power, requests-per-joule), routes to the most efficient
  on-budget lane, throttles breachy candidates, and sheds loudly with
  :class:`PowerBudgetError` when no lane has headroom.  Idle lanes burn
  their clock-gated leakage floor in :class:`ServeReport`'s honest fleet
  energy, and every launch re-audits its booked window price
  (``n_budget_violations`` must stay 0).  Composes with DVFS operating
  points (:class:`~repro.core.OperatingPoint`, ``EGPUConfig.at``);
* the continuous-batching decode engine (ISSUE 9) —
  :class:`DecodeEngine` serves autoregressive decode maxtext/JetStream
  style: per-request ``prefill`` -> ``insert`` into a slot of a persistent
  batched decode state resident on the engine's lane -> ``generate``
  advancing ALL occupied slots one token per step in exactly ONE cached
  ``CommandGraph`` launch (slot insertion is a donated-buffer update,
  never a re-capture), bit-identical to whole-batch greedy decoding for
  every cache family under staggered arrival.  ``Server(engine=...)``
  opens the streaming front (``submit_decode`` / per-rid ``stream``
  iterators — a finished request never blocks neighbors), the step cost
  is priced by the machine model with a bytes-per-step roofline read off
  the captured schedule (:class:`EngineRoofline`), and
  :mod:`repro.serve.http` puts a dependency-free asyncio streaming HTTP
  ingress in front of it.  Engine classes load lazily — pipeline-only
  servers keep the model stack off their import path.
"""

from .batching import (BucketBatcher, MicroBatch, ServeRequest,
                       batched_stages, pad_to)
from .cache import (GraphCache, input_signature, stage_signature,
                    stages_signature)
from .dispatch import (CircuitBreaker, DispatchError, LaunchTicket,
                       MultiQueueDispatcher, PowerBudgetError, QueueStats,
                       QueueWorker)
from .faults import (Blackout, FaultDecision, FaultPlan, InjectedFault,
                     apply_spike, env_seed)
from .power import LanePrice, PowerBudget
from .server import (DECOMP_PERCENTILES, DECOMP_PHASES, PERCENTILES,
                     AdmissionError, Server, ServeReport)
from .sharded import (BATCH_AXIS, ShardedWorker, data_mesh, mesh_signature,
                      shard_breakdown)

#: engine symbols resolved lazily (PEP 562): importing them pulls the model
#: stack (repro.models / repro.train), which pipeline-only servers avoid
_ENGINE_EXPORTS = ("DecodeEngine", "DecodeState", "EngineRoofline", "Prefix",
                   "batch_axes", "engine_roofline", "graph_traffic")
_HTTP_EXPORTS = ("EngineHTTPServer",)


def __getattr__(name: str):
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    if name in _HTTP_EXPORTS:
        from . import http
        return getattr(http, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BucketBatcher", "MicroBatch", "ServeRequest", "batched_stages", "pad_to",
    "GraphCache", "input_signature", "stage_signature", "stages_signature",
    "CircuitBreaker", "DispatchError", "LaunchTicket", "MultiQueueDispatcher",
    "PowerBudgetError", "QueueStats", "QueueWorker",
    "Blackout", "FaultDecision", "FaultPlan", "InjectedFault", "apply_spike",
    "env_seed",
    "LanePrice", "PowerBudget",
    "DECOMP_PERCENTILES", "DECOMP_PHASES", "PERCENTILES",
    "AdmissionError", "Server", "ServeReport",
    "BATCH_AXIS", "ShardedWorker", "data_mesh", "mesh_signature",
    "shard_breakdown",
    *_ENGINE_EXPORTS, *_HTTP_EXPORTS,
]
