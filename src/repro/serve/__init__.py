"""repro.serve — the APU serving subsystem (ISSUE 2).

Turns the one-shot ``APU.offload`` pipeline into a long-lived serving
engine:

* :class:`GraphCache` — memoized compiled :class:`CommandGraph`\\ s across
  offloads (LRU, hit/miss/eviction counters);
* :class:`BucketBatcher` — shape-bucketed dynamic batching (pad-to-bucket,
  coalesce, crop back);
* :class:`MultiQueueDispatcher` / :class:`QueueWorker` — load-balanced
  multi-queue dispatch with in-flight-depth backpressure and per-queue
  machine-model accounting;
* :class:`ShardedWorker` (ISSUE 5) — a dispatcher lane spanning a
  :class:`jax.sharding.Mesh` slice: cached graphs are lowered with
  ``NamedSharding``\\ s derived from the ``repro.distributed`` rule table
  (batch -> data axes, divisibility fallback to replication), modeled
  totals scale by the shard count;
* :class:`Server` / :class:`ServeReport` — the front-end tying them
  together: submit -> batch -> cached fused launch -> per-request results +
  requests/s, modeled latency percentiles, per-mesh-axis utilization and
  energy per request.
"""

from .batching import (BucketBatcher, MicroBatch, ServeRequest,
                       batched_stages, pad_to)
from .cache import (GraphCache, input_signature, stage_signature,
                    stages_signature)
from .dispatch import (LaunchTicket, MultiQueueDispatcher, QueueStats,
                       QueueWorker)
from .server import PERCENTILES, Server, ServeReport
from .sharded import (BATCH_AXIS, ShardedWorker, data_mesh, mesh_signature,
                      shard_breakdown)

__all__ = [
    "BucketBatcher", "MicroBatch", "ServeRequest", "batched_stages", "pad_to",
    "GraphCache", "input_signature", "stage_signature", "stages_signature",
    "LaunchTicket", "MultiQueueDispatcher", "QueueStats", "QueueWorker",
    "PERCENTILES", "Server", "ServeReport",
    "BATCH_AXIS", "ShardedWorker", "data_mesh", "mesh_signature",
    "shard_breakdown",
]
