"""Dynamic batching — bucket-by-shape, pad-to-bucket, coalesce, crop back.

Serving traffic arrives one request at a time with ragged sizes; the e-GPU
only amortizes Tiny-OpenCL startup + scheduling when work is chained and
batched (paper §IV-B / §VIII-B).  The batcher closes the gap:

1. each request's arrays are padded along ``pad_axis`` up to the smallest
   configured *bucket* length that fits (so a handful of shape classes cover
   arbitrary traffic);
2. requests sharing a bucket accumulate until ``max_batch`` (or an explicit
   flush) and are stacked on a new leading batch axis — the batch dimension
   is itself padded to ``max_batch`` so every launch of a bucket has
   *identical* shapes and hits one :class:`~repro.serve.cache.GraphCache`
   entry;
3. :func:`batched_stages` lifts the pipeline's per-request kernels over the
   batch axis with ``jax.vmap`` (constants broadcast, work counts scaled by
   the batch size so the machine model stays honest);
4. after launch, :meth:`MicroBatch.crop` slices each request's true extent
   back out.

Correctness contract: pipeline kernels must be *pad-stable* — zero-padding a
request along ``pad_axis`` must not change the outputs at the request's
valid indices (true for row-independent kernels: elementwise ops, per-row
GeMM, gather/embedding, causal FIR).  Kernels that reduce over the padded
axis (global softmax, whole-signal statistics) need an explicit mask stage
or exact-fit buckets (``bucket_sizes`` containing every admissible length).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.apu import Stage
from ..core.machine import WorkCounts
from ..core.runtime import Kernel


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One in-flight request: payload arrays + bookkeeping."""

    rid: int
    arrays: Tuple[jax.Array, ...]
    t_submit: float
    #: true (un-padded) extent of each array along the batcher's pad axis
    lengths: Tuple[int, ...] = ()
    #: ABSOLUTE completion deadline on the submitter's clock timeline
    #: (``t_submit + budget``), or ``None`` for best-effort requests;
    #: drives deadline-aware flushing and violation accounting (ISSUE 6)
    deadline_s: Optional[float] = None
    #: scheduling priority (higher wins): under overload a higher-priority
    #: request may preempt a lower-priority *pending* one instead of being
    #: shed itself
    priority: int = 0


@dataclasses.dataclass
class MicroBatch:
    """A coalesced launch unit: ``inputs`` are the stacked padded arrays
    (leading axis == ``capacity``, the bucket's max batch), ``requests``
    the live entries occupying its first rows."""

    bucket_key: Tuple[Any, ...]
    inputs: Tuple[jax.Array, ...]
    requests: Tuple[ServeRequest, ...]
    capacity: int
    pad_axis: int = 0
    crop_outputs: bool = True
    #: input positions to DONATE on launch (``CommandGraph.launch_prefix``
    #: ``donate=``): the serve engine marks its persistent decode-state
    #: buffers here so every generate step reuses them in place instead of
    #: allocating a fresh cache per token.  Donated inputs are consumed —
    #: the submitter must replace them with the launch's outputs.
    donate: Tuple[int, ...] = ()

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def crop(self, outputs: Sequence[Any]) -> List[Tuple[jax.Array, ...]]:
        """Slice each live request's true extent out of the batched outputs.

        Every output is expected to carry the batch on axis 0; the request's
        ``pad_axis`` (an axis of the *un-batched* row, so axis ``pad_axis``
        of ``row = out[i]``) is cropped back to its true length when the
        output kept the padded extent, else returned whole (reduced
        outputs).  ``ServeRequest.lengths`` has one entry per request
        *array*: output ``j`` crops against input ``j``'s true length and
        padded extent (pipelines emitting one output per input — the
        multi-input case where extents differ), with extra outputs falling
        back to the first input's.

        Caveat: "kept the padded extent" is detected by shape — an output
        dimension that *coincidentally* equals the bucket size (a fixed
        64-bin histogram served with a 64-bucket, say) would be wrongly
        cropped.  Pipelines with such outputs must set
        ``crop_outputs=False`` on the batcher/server and slice results
        themselves using ``ServeRequest.lengths``.
        """
        if not self.crop_outputs:
            return [tuple((o.data if hasattr(o, "data") else o)[i]
                          for o in outputs)
                    for i in range(len(self.requests))]
        ax = self.pad_axis

        def padded_len(j: int) -> Optional[int]:
            src = self.inputs[j if j < len(self.inputs) else 0] \
                if self.inputs else None
            return (src.shape[ax + 1]
                    if src is not None and src.ndim > ax + 1 else None)

        per_request: List[Tuple[jax.Array, ...]] = []
        for i, req in enumerate(self.requests):
            rows = []
            for j, out in enumerate(outputs):
                arr = out.data if hasattr(out, "data") else out
                row = arr[i]
                length = (req.lengths[j if j < len(req.lengths) else 0]
                          if req.lengths else None)
                padded = padded_len(j)
                if (length is not None and padded is not None
                        and row.ndim > ax and row.shape[ax] == padded
                        and row.shape[ax] >= length):
                    sl = [slice(None)] * row.ndim
                    sl[ax] = slice(0, length)
                    row = row[tuple(sl)]
                rows.append(row)
            per_request.append(tuple(rows))
        return per_request


def pad_to(arr: jax.Array, size: int, axis: int = 0,
           fill: float | int = 0) -> jax.Array:
    """Pad ``arr`` along ``axis`` up to ``size`` with ``fill``."""
    cur = arr.shape[axis]
    if cur == size:
        return arr
    if cur > size:
        raise ValueError(f"array extent {cur} along axis {axis} exceeds "
                         f"pad target {size}")
    pads = [(0, 0)] * arr.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(arr, pads, constant_values=fill)


class BucketBatcher:
    """Accumulate requests into shape buckets; emit full micro-batches.

    ``bucket_sizes`` are the admissible padded lengths (ascending); a request
    lands in the smallest bucket covering its ``pad_axis`` extent.  ``add``
    returns a :class:`MicroBatch` when a bucket fills to ``max_batch``;
    ``drain()`` flushes every partial bucket (batch-dim padded to
    ``max_batch`` so shapes — and hence cached graphs — never vary).
    """

    def __init__(self, bucket_sizes: Sequence[int], max_batch: int = 8,
                 pad_axis: int = 0, fill: float | int = 0,
                 crop_outputs: bool = True):
        # Loud construction-time validation (ISSUE 6): the historical
        # sorted(set(...)) canonicalization silently papered over unsorted
        # and duplicate bucket lists — a typo like (256, 64, 1024) then
        # surfaced only as a wrong bucket choice deep in traffic.  Reject
        # malformed inputs here, where the caller can see them.
        sizes = [int(b) for b in bucket_sizes]
        if not sizes:
            raise ValueError("need at least one bucket size")
        bad = [b for b in sizes if b <= 0]
        if bad:
            raise ValueError(
                f"bucket sizes must be positive, got {bad} in {sizes}")
        if len(set(sizes)) != len(sizes):
            raise ValueError(f"duplicate bucket sizes: {sizes}")
        if sizes != sorted(sizes):
            raise ValueError(
                f"bucket_sizes must be strictly ascending, got {sizes}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.bucket_sizes = tuple(sizes)
        self.max_batch = max_batch
        self.pad_axis = pad_axis
        self.fill = fill
        self.crop_outputs = crop_outputs
        self._pending: Dict[Tuple[Any, ...], List[ServeRequest]] = {}
        self._rid = itertools.count()
        # counters (surfaced in ServeReport)
        self.n_submitted = 0
        self.n_batches = 0
        self.padded_elements = 0   # request elements added purely by padding
        self.deadline_flushes = 0  # partial buckets launched by tick()

    def mint_rid(self) -> int:
        """Claim the next request id from the server-wide sequence.

        The decode-engine path (``Server.submit_decode``) mints here too,
        so engine and pipeline requests share ONE rid space — results,
        sheds and trace trees can never collide across the two fronts.
        """
        return next(self._rid)

    # -- bucketing ----------------------------------------------------------
    def bucket_size_for(self, length: int) -> int:
        for b in self.bucket_sizes:
            if length <= b:
                return b
        raise ValueError(
            f"request length {length} exceeds largest bucket "
            f"{self.bucket_sizes[-1]}")

    def bucket_key_for(self, arrays: Sequence[jax.Array]) -> Tuple[Any, ...]:
        """Padded (shape, dtype) per array — the bucket identity."""
        key = []
        for a in arrays:
            if a.ndim <= self.pad_axis:    # nothing to pad: exact-shape key
                key.append((tuple(a.shape), str(a.dtype)))
                continue
            shape = list(a.shape)
            shape[self.pad_axis] = self.bucket_size_for(shape[self.pad_axis])
            key.append((tuple(shape), str(a.dtype)))
        return tuple(key)

    def _check_oversize(self, arrays: Sequence[jax.Array]) -> None:
        """One loud, uniform oversize error at intake.

        Both historical failure paths — :meth:`bucket_size_for` (a bare
        "length exceeds largest bucket" with no array context) and
        :func:`pad_to` (a generic extent/target mismatch) — are preempted
        here with a single message naming the array index, the pad axis,
        the offending extent and the largest configured bucket, so a
        client submitting an oversize request learns exactly which input
        to split (or which bucket to add) instead of decoding an internal
        padding error.
        """
        largest = self.bucket_sizes[-1]
        for j, a in enumerate(arrays):
            if a.ndim <= self.pad_axis:
                continue                 # exact-shape keyed: never padded
            extent = a.shape[self.pad_axis]
            if extent > largest:
                raise ValueError(
                    f"oversize request: array {j} has extent {extent} "
                    f"along pad_axis {self.pad_axis}, which exceeds the "
                    f"largest configured bucket {largest} (buckets: "
                    f"{self.bucket_sizes}); configure a larger bucket or "
                    "split the request")

    # -- request intake -----------------------------------------------------
    def submit(self, *arrays: Any, t_submit: float = 0.0,
               deadline_s: Optional[float] = None,
               priority: int = 0) -> ServeRequest:
        """Wrap ``arrays`` into a request and stage it in its bucket.

        ``deadline_s`` is the request's ABSOLUTE deadline on the caller's
        clock timeline (the server passes ``t_submit + budget``);
        ``priority`` is its scheduling priority (higher wins under
        overload).  Raises a uniform :class:`ValueError` naming the
        offending array, axis, extent and largest bucket when any array
        cannot fit a configured bucket (see :meth:`_check_oversize`).
        """
        arrs = tuple(jnp.asarray(a) for a in arrays)
        self._check_oversize(arrs)
        req = ServeRequest(rid=next(self._rid), arrays=arrs,
                           t_submit=t_submit,
                           lengths=tuple(
                               a.shape[self.pad_axis]
                               if a.ndim > self.pad_axis else 1
                               for a in arrs),
                           deadline_s=deadline_s, priority=int(priority))
        self.n_submitted += 1
        key = self.bucket_key_for(arrs)
        self._pending.setdefault(key, []).append(req)
        return req

    def pop_full(self) -> List[MicroBatch]:
        """Micro-batches for every bucket that reached ``max_batch``."""
        out = []
        for key, reqs in list(self._pending.items()):
            while len(reqs) >= self.max_batch:
                take, self._pending[key] = (reqs[: self.max_batch],
                                            reqs[self.max_batch:])
                reqs = self._pending[key]
                out.append(self._collate(key, take))
            if not reqs:
                del self._pending[key]
        return out

    def drain(self) -> List[MicroBatch]:
        """Flush every pending bucket (partial batches padded to capacity)."""
        out = self.pop_full()
        for key, reqs in list(self._pending.items()):
            if reqs:
                out.append(self._collate(key, reqs))
        self._pending.clear()
        return out

    def tick(self, now: float, slack_s: float = 0.0) -> List[MicroBatch]:
        """Deadline-aware flush (ISSUE 6): launch partial buckets whose
        budget is at risk.

        A bucket flushes when its OLDEST deadline-carrying request has
        ``deadline_s - now <= slack_s`` — i.e. waiting any longer for the
        bucket to fill would spend budget the launch itself still needs
        (``slack_s`` is the caller's estimate of queueing + service time).
        Buckets holding only best-effort requests never deadline-flush;
        they wait for capacity or an explicit :meth:`drain`.
        """
        out = []
        for key, reqs in list(self._pending.items()):
            deadlines = [r.deadline_s for r in reqs
                         if r.deadline_s is not None]
            if not deadlines or min(deadlines) - now > slack_s:
                continue
            out.append(self._collate(key, reqs))
            self.deadline_flushes += 1
            del self._pending[key]
        return out

    def remove(self, rid: int) -> Optional[ServeRequest]:
        """Un-stage a pending request by id (admission-control preemption);
        returns it, or ``None`` when ``rid`` is not pending."""
        for key, reqs in list(self._pending.items()):
            for i, r in enumerate(reqs):
                if r.rid == rid:
                    reqs.pop(i)
                    if not reqs:
                        del self._pending[key]
                    return r
        return None

    def lowest_priority_pending(self) -> Optional[ServeRequest]:
        """The pending request overload shedding would evict first: lowest
        priority, newest submission among equals (least sunk wait)."""
        victim: Optional[ServeRequest] = None
        for reqs in self._pending.values():
            for r in reqs:
                if victim is None or (r.priority, -r.t_submit) < (
                        victim.priority, -victim.t_submit):
                    victim = r
        return victim

    @property
    def n_pending(self) -> int:
        return sum(len(v) for v in self._pending.values())

    # -- collation ----------------------------------------------------------
    def _collate(self, key: Tuple[Any, ...],
                 reqs: Sequence[ServeRequest]) -> MicroBatch:
        self.n_batches += 1
        n_arrays = len(key)
        stacked = []
        for j in range(n_arrays):
            shape, _dtype = key[j]
            rows = []
            for r in reqs:
                a = r.arrays[j]
                if a.ndim > self.pad_axis:
                    padded = pad_to(a, shape[self.pad_axis], self.pad_axis,
                                    self.fill)
                    self.padded_elements += int(padded.size - a.size)
                    rows.append(padded)
                else:
                    rows.append(a)
            batch = jnp.stack(rows)
            if len(reqs) < self.max_batch:          # pad the batch dim too:
                extra = self.max_batch - len(reqs)  # one shape per bucket
                batch = jnp.concatenate(
                    [batch, jnp.full((extra,) + batch.shape[1:], self.fill,
                                     batch.dtype)])
                self.padded_elements += extra * math.prod(batch.shape[1:])
            stacked.append(batch)
        return MicroBatch(bucket_key=key, inputs=tuple(stacked),
                          requests=tuple(reqs), capacity=self.max_batch,
                          pad_axis=self.pad_axis,
                          crop_outputs=self.crop_outputs)


# ---------------------------------------------------------------------------
# Lifting a per-request pipeline over the batch axis
# ---------------------------------------------------------------------------
def _batched_executor(executor: Callable[..., Any],
                      n_consts: int) -> Callable[..., Any]:
    def batched(*arrays: Any, **params: Any) -> Any:
        n_data = len(arrays) - n_consts
        in_axes = (0,) * n_data + (None,) * n_consts
        return jax.vmap(lambda *a: executor(*a, **params),
                        in_axes=in_axes)(*arrays)
    return batched


def _batched_counts(counts: Optional[Callable[..., WorkCounts]],
                    batch: int) -> Optional[Callable[..., WorkCounts]]:
    if counts is None:
        return None

    def scaled(**kw: Any) -> WorkCounts:
        return counts(**kw).scaled(batch)
    return scaled


def batched_stages(stages: Sequence[Stage], batch: int) -> List[Stage]:
    """Lift per-request :class:`Stage`\\ s to operate on a ``batch``-stacked
    leading axis: data flows through ``jax.vmap`` (constants broadcast), and
    each kernel's ``counts`` are scaled by ``batch`` so the modeled
    time/energy describes the whole micro-batch."""
    out = []
    for st in stages:
        kern = Kernel(
            name=st.kernel.name,
            executor=_batched_executor(st.kernel.executor, len(st.consts)),
            counts=_batched_counts(st.kernel.counts, batch),
            jitted=False,   # the vmap wrapper is a fresh unjitted callable
            # registry identity survives batching (with the batch size as an
            # extra variant axis), keeping serve cache keys stable across
            # rebuilt pipelines of Program-created kernels
            family=st.kernel.family,
            config=st.kernel.config,
            variant=st.kernel.variant + (("__batched__", batch),),
        )
        out.append(Stage(kern, params=dict(st.params),
                         counts_params=dict(st.counts_params),
                         consts=tuple(st.consts), n_inputs=st.n_inputs))
    return out
