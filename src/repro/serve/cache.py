"""GraphCache — memoized CommandGraph compilation for the serving layer.

The paper's Tiny-OpenCL results (§IV-B, §VIII-B) show dispatch overhead only
amortizes when work is chained and *resident*; PR 1's ``CommandGraph`` gets
there for one offload, but every ``APU.offload`` still re-captures and
re-jits the chain.  The cache closes that gap: compiled graphs are memoized
on a key of

    (EGPUConfig, per-stage signature, input shapes/dtypes, NDRanges)

so steady-state traffic pays capture + XLA compilation once per distinct
(pipeline, shape bucket, device config) and every later launch is a pure
replay.  Eviction is LRU with hit/miss/eviction counters — the counters are
the contract the serving tests pin ("a warm server performs zero
re-captures").

Stage signatures identify the *computation*, not the closure object: kernel
name + executor identity (code object, defaults AND closure-cell contents —
two lambdas born at the same source line capturing different values must
not collide, because the captured graph bakes the capture in) + params +
counts-params + the content hash of every constant buffer.  Hashing
constants means two pipelines that share kernel names but carry different
weights can never collide (a false hit would serve the wrong model).
Executors that read module-level *globals* mutated between calls are
outside the contract — capture state via closures, params or consts.
For a long-lived server, compute the stage part once with
:func:`stages_signature` and pass it as ``key_prefix`` (plain
``APU.offload`` calls get the same effect from the cache's internal
signature memo, keyed on stage-object identity).
"""

from __future__ import annotations

import functools
import hashlib
import os
from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional, Sequence, Tuple

import jax
import numpy as np

from ..analyze.graph import GraphVerifyError
from ..core.apu import APU, Stage
from ..core.ndrange import NDRange
from ..core.runtime import CommandGraph

_SIG_MEMO_CAPACITY = 64


def _array_sig(v: Any) -> Tuple[Any, ...]:
    """Content signature of a captured constant (shape, dtype, sha1)."""
    arr = np.asarray(v)
    return ("arr", arr.shape, str(arr.dtype),
            hashlib.sha1(arr.tobytes()).hexdigest())


def _code_sig(code: Any, depth: int = 0) -> str:
    """Hash of a code object INCLUDING its constants (two lambdas differing
    only in an inline literal share co_code — the literal lives in
    co_consts; nested code objects recurse)."""
    h = hashlib.sha1(code.co_code)
    h.update(repr(code.co_names).encode())
    for const in code.co_consts:
        if hasattr(const, "co_code") and depth < 4:
            h.update(_code_sig(const, depth + 1).encode())
        else:
            h.update(repr(const).encode())
    return h.hexdigest()


def _callable_sig(fn: Any, depth: int = 0) -> Tuple[Any, ...]:
    """Identity of an executor: code (bytecode + consts) + defaults +
    closure contents.

    Closure cells holding arrays sign by content, nested callables recurse
    (bounded), anything else signs by ``repr`` — an unstable repr (default
    ``object.__repr__`` with an address) degrades to cache *misses*, never
    to a false hit.
    """
    if depth > 4:
        return ("depth",)
    if isinstance(fn, functools.partial):
        return ("partial", _callable_sig(fn.func, depth + 1),
                tuple(_value_sig(a, depth + 1) for a in fn.args),
                tuple(sorted((k, _value_sig(v, depth + 1))
                             for k, v in fn.keywords.items())))
    code = getattr(fn, "__code__", None)
    if code is None:        # builtin / callable object
        return ("obj", type(fn).__name__, getattr(fn, "__module__", ""),
                getattr(fn, "__qualname__", repr(fn)))
    cells = tuple(_value_sig(c.cell_contents, depth + 1)
                  for c in (fn.__closure__ or ()))
    defaults = tuple(_value_sig(d, depth + 1)
                     for d in (fn.__defaults__ or ()))
    return ("fn", getattr(fn, "__module__", ""), fn.__qualname__,
            _code_sig(code), defaults, cells)


def _value_sig(v: Any, depth: int = 0) -> Tuple[Any, ...]:
    """Signature of a kernel param / closure cell: arrays by content (they
    are baked into the captured node), containers element-wise (a repr of a
    large array inside a list truncates to '...' and would collide),
    callables structurally, everything else by repr (jit-static values)."""
    if isinstance(v, (jax.Array, np.ndarray, np.generic)):
        return _array_sig(v)
    if isinstance(v, (list, tuple)) and depth <= 4:
        return ("seq", type(v).__name__,
                tuple(_value_sig(x, depth + 1) for x in v))
    if isinstance(v, dict) and depth <= 4:
        return ("map", tuple(sorted(
            (repr(k), _value_sig(x, depth + 1)) for k, x in v.items())))
    if callable(v):
        return _callable_sig(v, depth)
    return ("val", repr(v))


def _params_sig(params: Dict[str, Any]) -> Tuple[Any, ...]:
    return tuple(sorted((k, _value_sig(v)) for k, v in params.items()))


def kernel_signature(k: Any) -> Tuple[Any, ...]:
    """Hashable identity of a :class:`~repro.core.runtime.Kernel`.

    Kernels created through the host-API-v2 registry
    (:meth:`repro.core.program.Program.create_kernel`) carry a *registry
    identity* — ``(family, config, variant)`` — which is both cheaper and
    more stable than hashing executor bytecode + closures: registry kernels
    are memoized singletons, so two pipelines built from the same program
    can never mint distinguishable-but-equal closures (the PR-2 signature
    machinery stays as the fallback for ad-hoc kernels).
    """
    if getattr(k, "family", None) is not None:
        return ("reg", k.family, k.config, k.variant, k.name)
    return (k.name, _callable_sig(k.executor))


def stage_signature(stage: Stage) -> Tuple[Any, ...]:
    """Hashable identity of one :class:`~repro.core.apu.Stage`."""
    return (
        kernel_signature(stage.kernel),
        _params_sig(stage.params),
        _params_sig(stage.counts_params),
        stage.n_inputs,
        tuple(_array_sig(c) for c in stage.consts),
    )


def stages_signature(stages: Sequence[Stage]) -> Tuple[Any, ...]:
    """Hashable identity of a whole pipeline (compute once, reuse per batch)."""
    return tuple(stage_signature(s) for s in stages)


def input_signature(inputs: Sequence[Any]) -> Tuple[Any, ...]:
    """Shape/dtype signature of the pipeline inputs (values excluded — a
    cached graph is re-launched on fresh data of the same aval)."""
    sig = []
    for x in inputs:
        x = np.asarray(x) if not isinstance(x, (jax.Array, np.ndarray)) else x
        sig.append((tuple(x.shape), str(x.dtype)))
    return tuple(sig)


class GraphCache:
    """LRU cache of compiled :class:`CommandGraph`\\ s keyed on
    (device config, pipeline signature, input avals, ndranges).

    One cache may be shared across several :class:`APU`\\ s with different
    ``EGPUConfig`` presets — the config is part of the key, so a 16T graph
    can never be served to an 8T device.  Same-config callers genuinely
    *share* an entry; that is safe for accounting because launches bind to
    the caller's queue (``graph.launch(..., queue=...)``), so the shared
    graph's capture queue never accumulates anyone's launch events.
    ``capacity`` bounds the number of resident graphs (each holds its
    jitted executable and captured constants); the least-recently-used
    entry is evicted first.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("GraphCache capacity must be >= 1")
        self.capacity = capacity
        self._graphs: "OrderedDict[Hashable, CommandGraph]" = OrderedDict()
        # Memoized stages_signature keyed on stage-object identity: callers
        # that reuse their Stage list (APU.offload in a loop) skip re-hashing
        # every constant buffer per call.  Entries hold strong refs to the
        # stage tuple so an id() can never be recycled while memoized.
        self._sig_memo: "OrderedDict[Tuple[int, ...], Tuple[Tuple[Stage, ...], Hashable]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # capture-time sanitizer roll-up (repro.analyze): every miss's
        # fresh capture is statically verified before admission.  Findings
        # are counted always (they surface in ServeReport / metrics) and
        # raise under REPRO_VERIFY=1 — a hit replays a verified graph, so
        # the warm path never re-verifies.
        self.verified = 0
        self.findings = 0

    def __len__(self) -> int:
        return len(self._graphs)

    def _stages_sig(self, stages: Sequence[Stage]) -> Hashable:
        key = tuple(id(s) for s in stages)
        memo = self._sig_memo.get(key)
        if memo is not None and len(memo[0]) == len(stages) and all(
                a is b for a, b in zip(memo[0], stages)):
            self._sig_memo.move_to_end(key)
            return memo[1]
        sig = stages_signature(stages)
        self._sig_memo[key] = (tuple(stages), sig)
        if len(self._sig_memo) > _SIG_MEMO_CAPACITY:
            self._sig_memo.popitem(last=False)
        return sig

    def key_for(self, apu: APU, stages: Sequence[Stage],
                inputs: Sequence[Any],
                ndranges: Optional[Sequence[NDRange]] = None,
                key_prefix: Optional[Hashable] = None) -> Hashable:
        """The full cache key for one offload/capture request.

        ``key_prefix`` replaces the per-call :func:`stages_signature`
        (which hashes every constant buffer) with a precomputed identity —
        the hot-path form for a server whose pipeline never changes.
        Without it, the signature is memoized on stage-object identity, so
        repeated offloads of the *same* Stage list hash constants once.
        """
        pipe = key_prefix if key_prefix is not None else self._stages_sig(stages)
        ndr = (None if ndranges is None else
               tuple((n.global_size, n.local_size) for n in ndranges))
        # explicit-transfer captures have a different node structure (write/
        # read nodes, resident kernels) than classic ones — never share.
        # The APU's placement (a ShardedWorker's mesh + sharding-rule
        # signature, None for single-device callers) keys too: sharded and
        # single-device entries of one pipeline must never collide, so a
        # shared cache keeps their hit/miss accounting — and their
        # launch-invariant memos (fused breakdown, pipeline report, the
        # per-binding jit cache each graph grows) — cleanly separated.
        return (apu.egpu.config, getattr(apu, "explicit_transfers", False),
                getattr(apu, "placement", None),
                pipe, input_signature(inputs), ndr)

    def get_or_capture(self, apu: APU, stages: Sequence[Stage],
                       inputs: Sequence[Any],
                       ndranges: Optional[Sequence[NDRange]] = None,
                       key_prefix: Optional[Hashable] = None,
                       ) -> Tuple[CommandGraph, bool]:
        """Return ``(graph, hit)`` — capturing (and thereby compiling on
        first launch) only on a miss.  The entry is promoted to
        most-recently-used either way."""
        key = self.key_for(apu, stages, inputs, ndranges, key_prefix)
        graph = self._graphs.get(key)
        if graph is not None:
            self.hits += 1
            self._graphs.move_to_end(key)
            return graph, True
        self.misses += 1
        graph = apu.capture_pipeline(stages, inputs, ndranges)
        findings = graph.verify()
        self.verified += 1
        self.findings += len(findings)
        if findings and os.environ.get("REPRO_VERIFY") == "1":
            raise GraphVerifyError(findings)
        self._graphs[key] = graph
        if len(self._graphs) > self.capacity:
            self._graphs.popitem(last=False)
            self.evictions += 1
        return graph, False

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._graphs),
                "capacity": self.capacity, "verified": self.verified,
                "findings": self.findings}

    def publish_metrics(self, registry) -> None:
        """Publish the cache counters into a
        :class:`~repro.obs.MetricsRegistry` (snapshot style, idempotent)."""
        c = registry.counter("repro_graph_cache_events_total",
                             "graph cache hits/misses/evictions")
        c.set_total(self.hits, kind="hits")
        c.set_total(self.misses, kind="misses")
        c.set_total(self.evictions, kind="evictions")
        registry.gauge("repro_graph_cache_entries",
                       "resident compiled graphs").set(len(self._graphs))
        registry.gauge("repro_graph_cache_capacity",
                       "configured cache capacity").set(self.capacity)
        s = registry.counter("repro_graph_sanitizer_total",
                             "capture-time graph sanitizer results")
        s.set_total(self.verified, kind="verified")
        s.set_total(self.findings, kind="findings")

    def clear(self) -> None:
        self._graphs.clear()
        self._sig_memo.clear()
