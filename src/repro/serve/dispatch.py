"""Multi-queue dispatch — load balancing, backpressure, per-queue accounting.

One e-GPU instance is one in-order queue; a serving deployment runs several
(possibly heterogeneous — different ``EGPUConfig`` presets, mirroring the
paper's configurability story).  The dispatcher routes each micro-batch to
the least-loaded :class:`QueueWorker`, bounds every worker's in-flight depth
(launch beyond ``max_in_flight`` first retires the oldest ticket — classic
credit-based backpressure, keeping queue memory and latency bounded), and
rolls per-queue machine-model totals up for the
:class:`~repro.serve.server.ServeReport`.

Every worker owns its :class:`~repro.core.runtime.CommandQueue` (the APU's)
and launches cached graphs with launch-time queue binding
(``graph.launch_prefix(..., queue=worker.queue)``), so a
:class:`~repro.serve.cache.GraphCache` entry shared by several same-config
workers books each launch's events and modeled totals on the launching
worker's queue only — per-queue accounting is exact by construction, not by
coincidence.  Workers retire tickets through the Event-lifecycle API: after
a ticket's outputs are realized, ``queue.drain(n)`` +
``queue.release_events(upto=n)`` return the worker's queue to O(in-flight)
memory while the released events' modeled time/energy stay in the queue's
running totals.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax

from ..core.apu import APU
from ..core.device import EGPUConfig
from ..core.machine import PhaseBreakdown
from ..core.runtime import Buffer, CommandGraph
from .batching import MicroBatch


@dataclasses.dataclass
class LaunchTicket:
    """One in-flight micro-batch launch and its modeled cost."""

    batch: MicroBatch
    outputs: Tuple[Buffer, ...]
    worker: "QueueWorker"
    #: fused breakdown of the whole batched chain (startup+scheduling paid
    #: once per launch — every request in the batch experiences this latency)
    fused: Optional[PhaseBreakdown]
    energy_j: float
    t_launch: float
    t_done: Optional[float] = None
    #: events this launch appended to the launching worker's queue (one per
    #: node — launch-time binding, never the graph's capture queue)
    n_events: int = 0

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def modeled_latency_s(self) -> Optional[float]:
        return None if self.fused is None else self.fused.total_s


class QueueWorker:
    """One serving lane: an :class:`APU` + bounded in-flight window.

    ``max_in_flight`` is the backpressure credit count: a launch that would
    exceed it first retires the oldest outstanding ticket (waiting on its
    results and releasing its queue events), so a worker can never
    accumulate unbounded speculative work.
    """

    def __init__(self, config: EGPUConfig, name: Optional[str] = None,
                 max_in_flight: int = 2, explicit_transfers: bool = True):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        # Host API v2 (default): the worker's captures move each
        # micro-batch through explicit enqueue_write_buffer /
        # enqueue_read_buffer nodes at the batch boundaries, so the queue's
        # modeled totals price the real request traffic as dedicated
        # transfer events instead of the per-kernel overlap heuristic.
        self.apu = APU(config, explicit_transfers=explicit_transfers)
        #: this worker's own command queue — every launch binds its events
        #: and modeled totals here, never to a cached graph's capture queue
        self.queue = self.apu.queue
        self.name = name or config.name
        self.max_in_flight = max_in_flight
        self._inflight: List[LaunchTicket] = []
        # accounting
        self.n_batches = 0
        self.n_requests = 0
        self.modeled_s = 0.0
        self.energy_j = 0.0
        self.peak_in_flight = 0
        self.backpressure_stalls = 0

    @property
    def depth(self) -> int:
        return len(self._inflight)

    # -- launch / retire ----------------------------------------------------
    def _do_launch(self, graph: CommandGraph, batch: MicroBatch
                   ) -> Tuple[Tuple[Buffer, ...],
                              Optional[PhaseBreakdown], float]:
        """Fire one launch and return (outputs, fused breakdown, energy).

        The subclass hook :class:`~repro.serve.sharded.ShardedWorker`
        overrides: it binds the launch to its mesh and scales the modeled
        breakdown by the shard count actually applied."""
        outs = graph.launch_prefix(batch.inputs, queue=self.queue)
        fused, energy = graph.fused_modeled()   # memoized: launch-invariant
        return outs, fused, energy

    def launch(self, graph: CommandGraph, batch: MicroBatch
               ) -> Tuple[LaunchTicket, List[LaunchTicket]]:
        """Launch ``batch`` through ``graph``; returns the new ticket plus
        any tickets retired to stay under the in-flight bound."""
        retired = []
        while len(self._inflight) >= self.max_in_flight:
            self.backpressure_stalls += 1
            retired.append(self._retire_oldest())
        outs, fused, energy = self._do_launch(graph, batch)
        ticket = LaunchTicket(batch=batch, outputs=outs, worker=self,
                              fused=fused, energy_j=energy,
                              t_launch=time.perf_counter(),
                              n_events=len(graph.nodes))
        self._inflight.append(ticket)
        self.peak_in_flight = max(self.peak_in_flight, len(self._inflight))
        self.n_batches += 1
        self.n_requests += batch.n_requests
        if fused is not None:
            self.modeled_s += fused.total_s
        self.energy_j += energy
        return ticket, retired

    def _retire_oldest(self) -> LaunchTicket:
        ticket = self._inflight.pop(0)
        for b in ticket.outputs:
            if isinstance(b.data, jax.Array):
                b.data.block_until_ready()
        # Release exactly this launch's event segment.  Every launch binds
        # to THIS worker's queue and tickets retire oldest-first, so the
        # segment at the queue head is this ticket's own — even when the
        # graph itself is a cached entry shared with sibling workers.
        self.queue.drain(ticket.n_events)
        self.queue.release_events(upto=ticket.n_events)
        ticket.t_done = time.perf_counter()
        return ticket

    def drain(self) -> List[LaunchTicket]:
        """Retire every outstanding ticket (oldest first)."""
        out = []
        while self._inflight:
            out.append(self._retire_oldest())
        return out

    def modeled_s_per_request(self) -> Optional[float]:
        """Modeled seconds per served request, or ``None`` before any
        modeled launch completed (unprofiled queues, cold workers)."""
        if self.n_requests <= 0 or self.modeled_s <= 0.0:
            return None
        return self.modeled_s / self.n_requests

    def stats(self) -> "QueueStats":
        return QueueStats(
            name=self.name, config=self.apu.egpu.config.name,
            batches=self.n_batches, requests=self.n_requests,
            modeled_s=self.modeled_s, energy_j=self.energy_j,
            peak_in_flight=self.peak_in_flight,
            backpressure_stalls=self.backpressure_stalls)


@dataclasses.dataclass(frozen=True)
class QueueStats:
    """Per-queue roll-up surfaced in the :class:`ServeReport`."""

    name: str
    config: str
    batches: int
    requests: int
    modeled_s: float
    energy_j: float
    peak_in_flight: int
    backpressure_stalls: int
    #: mesh lane width: total devices this worker's launches span (1 for a
    #: plain single-device QueueWorker)
    shards: int = 1
    #: the worker's mesh layout as ((axis, size), ...); () when unsharded
    mesh_axes: Tuple[Tuple[str, int], ...] = ()
    #: mean per-launch utilization of each mesh axis — the fraction of the
    #: axis's devices a launch's sharding actually exploited (a
    #: divisibility fallback to replication shows up as < 1.0 here)
    mesh_utilization: Tuple[Tuple[str, float], ...] = ()


class MultiQueueDispatcher:
    """Route micro-batches to the least-loaded worker.

    "Least loaded" is in-flight depth first; depth ties break on **modeled
    seconds per request** — the machine model's view of each lane's speed —
    so a faster / wider lane (a 16-thread config, a sharded mesh lane)
    genuinely attracts more traffic.  Tie-breaking on raw requests served
    (the pre-ISSUE-5 rule) permanently biased heterogeneous mixes: a fast
    worker that served one extra warmup batch lost every subsequent tie to
    a slower sibling at equal depth.  Workers with no model data yet
    (cold, or unprofiled) fall back to requests served, and are preferred
    at equal depth so every lane bootstraps its model quickly.
    """

    def __init__(self, workers: Sequence[QueueWorker]):
        if not workers:
            raise ValueError("need at least one QueueWorker")
        names = [w.name for w in workers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate worker names: {names}")
        self.workers = list(workers)

    @staticmethod
    def _route_key(w: QueueWorker) -> Tuple[float, int, float, int]:
        spr = w.modeled_s_per_request()
        if spr is None:                  # no model data yet: fall back to
            return (w.depth, 0, float(w.n_requests), w.n_requests)
        # final n_requests entry keeps equal-speed (homogeneous) lanes
        # alternating instead of resolving every exact spr tie to the
        # first worker in declaration order
        return (w.depth, 1, spr, w.n_requests)

    def pick(self) -> QueueWorker:
        """The worker the next micro-batch should go to (see class doc)."""
        return min(self.workers, key=self._route_key)

    def drain_all(self) -> List[LaunchTicket]:
        out: List[LaunchTicket] = []
        for w in self.workers:
            out.extend(w.drain())
        return out

    def stats(self) -> Tuple[QueueStats, ...]:
        return tuple(w.stats() for w in self.workers)
