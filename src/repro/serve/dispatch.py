"""Multi-queue dispatch — load balancing, backpressure, fault-tolerant
routing, per-queue accounting.

One e-GPU instance is one in-order queue; a serving deployment runs several
(possibly heterogeneous — different ``EGPUConfig`` presets, mirroring the
paper's configurability story).  The dispatcher routes each micro-batch to
the least-loaded :class:`QueueWorker`, bounds every worker's in-flight depth
(launch beyond ``max_in_flight`` first retires the oldest ticket — classic
credit-based backpressure, keeping queue memory and latency bounded), and
rolls per-queue machine-model totals up for the
:class:`~repro.serve.server.ServeReport`.

Every worker owns its :class:`~repro.core.runtime.CommandQueue` (the APU's)
and launches cached graphs with launch-time queue binding
(``graph.launch_prefix(..., queue=worker.queue)``), so a
:class:`~repro.serve.cache.GraphCache` entry shared by several same-config
workers books each launch's events and modeled totals on the launching
worker's queue only — per-queue accounting is exact by construction, not by
coincidence.  Workers retire tickets through the Event-lifecycle API: after
a ticket's outputs are realized, ``queue.drain(n)`` +
``queue.release_events(upto=n)`` return the worker's queue to O(in-flight)
memory while the released events' modeled time/energy stay in the queue's
running totals.

Fault tolerance (ISSUE 6): a worker built with a
:class:`~repro.serve.faults.FaultPlan` gates every ``_do_launch`` through
the plan — injected failures raise :class:`InjectedFault` *before* any real
work.  :meth:`MultiQueueDispatcher.dispatch` retries a failed micro-batch
with capped exponential backoff, preferring a *different* lane each
attempt; per-lane :class:`CircuitBreaker`\\ s quarantine repeat offenders
(skipped by routing while OPEN) and re-admit them through half-open probe
launches, so a blacked-out lane neither absorbs traffic nor stays banned
after it recovers.  Because injected faults fire pre-launch and kernels are
pure, a retried micro-batch is bit-identical to the fault-free path.

Modeled virtual time: every launch also advances the lane's
``modeled_busy_until`` on the server's clock timeline —
``start = max(now, busy_until)``, ``done = start + fused.total_s`` — giving
each ticket a deterministic machine-model completion time
(``t_done_modeled``) that deadline checks and the overload benchmark's
goodput gate use instead of wall-clock noise.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Set, Tuple

from ..core.apu import APU
from ..core.device import EGPUConfig
from ..core.machine import PhaseBreakdown
from ..core.power import egpu_idle_power_mw
from ..core.runtime import Buffer, CommandGraph
from ..obs import Tracer
from .batching import MicroBatch
from .faults import FaultPlan, InjectedFault, apply_spike
from .power import LanePrice, PowerBudget


class DispatchError(RuntimeError):
    """A micro-batch exhausted every retry across the fleet.

    ``retired`` carries tickets retired for backpressure during the failed
    attempts — those launches were real and must still be finalized.
    """

    def __init__(self, msg: str, retired: Sequence["LaunchTicket"] = ()):
        super().__init__(msg)
        self.retired = tuple(retired)


class PowerBudgetError(DispatchError):
    """No lane can take the micro-batch within the :class:`PowerBudget`.

    A :class:`DispatchError` subclass so the server's existing loud-shed
    machinery applies unchanged: the batch's requests surface an
    :class:`~repro.serve.server.AdmissionError` naming the budget — an
    over-budget fleet throttles and sheds, it never quietly overdraws.
    """


@dataclasses.dataclass
class LaunchTicket:
    """One in-flight micro-batch launch and its modeled cost."""

    batch: MicroBatch
    outputs: Tuple[Buffer, ...]
    worker: "QueueWorker"
    #: fused breakdown of the whole batched chain (startup+scheduling paid
    #: once per launch — every request in the batch experiences this latency)
    fused: Optional[PhaseBreakdown]
    energy_j: float
    t_launch: float
    t_done: Optional[float] = None
    #: events this launch appended to the launching worker's queue (one per
    #: node — launch-time binding, never the graph's capture queue)
    n_events: int = 0
    #: machine-model completion time on the server's clock timeline:
    #: ``max(t_launch, lane busy_until) + fused.total_s`` — deterministic,
    #: used for deadline-violation checks and modeled goodput
    t_done_modeled: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def modeled_latency_s(self) -> Optional[float]:
        return None if self.fused is None else self.fused.total_s


class QueueWorker:
    """One serving lane: an :class:`APU` + bounded in-flight window.

    ``max_in_flight`` is the backpressure credit count: a launch that would
    exceed it first retires the oldest outstanding ticket (waiting on its
    results and releasing its queue events), so a worker can never
    accumulate unbounded speculative work.

    ``fault_plan`` (ISSUE 6) hooks deterministic fault injection into
    :meth:`_do_launch`; ``clock`` is the time source every timestamp on
    this lane uses — the overload benchmark injects a virtual clock so the
    whole serving timeline becomes machine-model-deterministic.
    """

    def __init__(self, config: EGPUConfig, name: Optional[str] = None,
                 max_in_flight: int = 2, explicit_transfers: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer: Optional[Tracer] = None):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        # Host API v2 (default): the worker's captures move each
        # micro-batch through explicit enqueue_write_buffer /
        # enqueue_read_buffer nodes at the batch boundaries, so the queue's
        # modeled totals price the real request traffic as dedicated
        # transfer events instead of the per-kernel overlap heuristic.
        self.apu = APU(config, explicit_transfers=explicit_transfers)
        #: this worker's own command queue — every launch binds its events
        #: and modeled totals here, never to a cached graph's capture queue
        self.queue = self.apu.queue
        self.name = name or config.name
        self.max_in_flight = max_in_flight
        self.fault_plan = fault_plan
        self.clock = clock
        #: opt-in span tracer (ISSUE 7): every hook guards on ``is not
        #: None`` so an untraced worker allocates no obs object on the
        #: hot dispatch path
        self.tracer = tracer
        self._inflight: List[LaunchTicket] = []
        self._launch_seq = 0             # fault-plan launch index (attempts)
        #: machine-model time this lane is busy until (server clock
        #: timeline); launches queue behind it, giving deterministic
        #: per-ticket modeled completion times
        self.modeled_busy_until = 0.0
        #: clock-gated leakage floor of this lane, watts (§IV SLEEP_REQ):
        #: what the lane draws between launches; scales with the config's
        #: DVFS operating point through the power model
        self.idle_power_w = egpu_idle_power_mw(config) * 1e-3
        #: the fleet's :class:`PowerBudget` (installed by the dispatcher);
        #: when set, every launch re-audits its own window-average power
        self.power_budget: Optional[PowerBudget] = None
        # accounting
        self.n_batches = 0
        self.n_requests = 0
        self.modeled_s = 0.0
        self.energy_j = 0.0
        self.peak_in_flight = 0
        self.backpressure_stalls = 0
        self.launch_failures = 0         # injected faults this lane absorbed
        self.budget_violations = 0       # launches that broke the lane cap

    @property
    def depth(self) -> int:
        return len(self._inflight)

    @property
    def inflight_requests(self) -> int:
        """Live requests across this lane's in-flight tickets (admission
        control counts them as queue depth)."""
        return sum(t.batch.n_requests for t in self._inflight)

    # -- power pricing (ISSUE 8) --------------------------------------------
    def estimate(self, graph: CommandGraph
                 ) -> Tuple[Optional[PhaseBreakdown], float]:
        """Modeled (fused breakdown, energy) a launch of ``graph`` would
        book on this lane — the dispatcher's pricing view, matching
        :meth:`_do_launch`'s accounting exactly minus injected latency
        spikes (which only *lengthen* the window, so the price is an upper
        bound on the booked window-average power).  ShardedWorker overrides
        with its shard-scaled breakdown."""
        return graph.fused_modeled()

    def pending_energy_j(self, t_now: float) -> float:
        """Energy this lane's in-flight tickets still deliver after
        ``t_now``: each ticket's launch energy, scaled by the unelapsed
        fraction of its modeled service window."""
        e = 0.0
        for t in self._inflight:
            if t.fused is None or t.t_done_modeled is None:
                continue
            dur = t.fused.total_s
            if dur <= 0.0:
                continue
            remaining = min(dur, max(0.0, t.t_done_modeled - t_now))
            e += t.energy_j * (remaining / dur)
        return e

    def price(self, fused: Optional[PhaseBreakdown], energy_j: float,
              t_now: float, n_requests: int = 1) -> LanePrice:
        """Price a candidate launch: modeled latency (backlog + service on
        this lane's timeline) and the window-average power committing to
        it implies.  Pure read — books nothing."""
        modeled_s = fused.total_s if fused is not None else 0.0
        backlog_s = max(0.0, self.modeled_busy_until - t_now)
        window_s = backlog_s + modeled_s
        total_e = self.pending_energy_j(t_now) + energy_j
        avg_power_w = total_e / window_s if window_s > 0.0 else 0.0
        rpj = (n_requests / total_e) if total_e > 0.0 else float("inf")
        return LanePrice(lane=self.name, modeled_s=modeled_s,
                         window_s=window_s, avg_power_w=avg_power_w,
                         energy_j=energy_j, requests_per_joule=rpj)

    def current_power_w(self, t_now: float) -> float:
        """This lane's modeled draw right now: remaining in-flight energy
        over the remaining busy window, floored at the clock-gated leakage
        the silicon burns regardless; an idle lane sits exactly on that
        floor (§IV — SLEEP_REQ gates the clocks, leakage stays)."""
        backlog_s = max(0.0, self.modeled_busy_until - t_now)
        if backlog_s <= 0.0:
            return self.idle_power_w
        return max(self.idle_power_w,
                   self.pending_energy_j(t_now) / backlog_s)

    # -- launch / retire ----------------------------------------------------
    def _fault_gate(self) -> float:
        """The :class:`FaultPlan` hook at the top of every ``_do_launch``.

        Draws this lane's fate for the current launch index: raises
        :class:`InjectedFault` (launch failure / blackout) *before* any
        real work, or returns the latency spike to fold into the modeled
        breakdown (0.0 for a clean launch or no plan).
        """
        idx = self._launch_seq
        self._launch_seq += 1
        if self.fault_plan is None:
            return 0.0
        decision = self.fault_plan.draw(self.name, idx)
        if decision.fail:
            self.launch_failures += 1
            raise InjectedFault(
                f"injected fault on lane {self.name!r} launch {idx}: "
                f"{decision.reason}",
                lane=self.name, launch_idx=idx, reason=decision.reason)
        return decision.spike_s

    def _do_launch(self, graph: CommandGraph, batch: MicroBatch
                   ) -> Tuple[Tuple[Buffer, ...],
                              Optional[PhaseBreakdown], float]:
        """Fire one launch and return (outputs, fused breakdown, energy).

        Gated by :meth:`_fault_gate` (ISSUE 6) — an injected failure raises
        before the graph runs, so retries replay identical pure code.  The
        subclass hook :class:`~repro.serve.sharded.ShardedWorker`
        overrides: it binds the launch to its mesh and scales the modeled
        breakdown by the shard count actually applied."""
        spike_s = self._fault_gate()
        # `batch.donate` (the serve engine's resident decode state) donates
        # those input positions for in-place reuse; the empty default is
        # the historical non-donating launch.
        outs = graph.launch_prefix(batch.inputs, queue=self.queue,
                                   donate=batch.donate)
        fused, energy = graph.fused_modeled()   # memoized: launch-invariant
        return outs, apply_spike(fused, spike_s), energy

    def launch(self, graph: CommandGraph, batch: MicroBatch,
               t_now: Optional[float] = None
               ) -> Tuple[LaunchTicket, List[LaunchTicket]]:
        """Launch ``batch`` through ``graph``; returns the new ticket plus
        any tickets retired to stay under the in-flight bound.

        On an :class:`InjectedFault` the already-retired tickets ride out
        on the exception's ``retired`` attribute — their launches were
        real and the caller must still finalize them."""
        retired = []
        while len(self._inflight) >= self.max_in_flight:
            self.backpressure_stalls += 1
            retired.append(self._retire_oldest())
        try:
            outs, fused, energy = self._do_launch(graph, batch)
        except InjectedFault as e:
            e.retired = tuple(retired)
            raise
        t_now = self.clock() if t_now is None else t_now
        if (self.power_budget is not None
                and self.power_budget.lane_mw is not None):
            # the enforcement invariant's audit hook (ISSUE 8): re-price the
            # launch actually being booked — post-backpressure, spike
            # included — against the lane cap.  The dispatcher's pre-launch
            # pricing upper-bounds this, so the counter stays 0 whenever
            # routing enforced the budget; a non-zero count means a request
            # executed over budget (gated to zero by the hypothesis sweep).
            booked = self.price(fused, energy, t_now,
                                n_requests=batch.n_requests)
            if not self.power_budget.lane_ok(booked.avg_power_w):
                self.budget_violations += 1
        start = max(t_now, self.modeled_busy_until)
        t_done_modeled = start + (fused.total_s if fused is not None else 0.0)
        self.modeled_busy_until = t_done_modeled
        ticket = LaunchTicket(batch=batch, outputs=outs, worker=self,
                              fused=fused, energy_j=energy,
                              t_launch=t_now,
                              n_events=len(graph.nodes),
                              t_done_modeled=t_done_modeled)
        self._inflight.append(ticket)
        self.peak_in_flight = max(self.peak_in_flight, len(self._inflight))
        self.n_batches += 1
        self.n_requests += batch.n_requests
        if fused is not None:
            self.modeled_s += fused.total_s
        self.energy_j += energy
        if self.tracer is not None:
            self._trace_launch(graph, batch, start, t_done_modeled, fused)
        return ticket, retired

    def _trace_launch(self, graph: CommandGraph, batch: MicroBatch,
                      start: float, t_done: float,
                      fused: Optional[PhaseBreakdown]) -> None:
        """Lane-track slices for one launch (only reached when a tracer is
        installed): a ``launch`` span over the modeled service window, one
        ``startup+scheduling`` slice for the per-chain Tiny-OpenCL
        overhead, then one slice per graph node sized by its captured
        :class:`PhaseBreakdown` and laid out along the node DAG's
        critical-path schedule — concurrent branches visibly overlap.
        Purely observational: reads the already-computed modeled schedule,
        never feeds back into it."""
        tr = self.tracer
        track = f"lane:{self.name}"
        parent = tr.span("launch", start, t_done, track=track,
                         n_requests=batch.n_requests,
                         rids=[r.rid for r in batch.requests])
        if fused is None:
            return
        overhead_s = (fused.startup + fused.scheduling) / fused.freq_hz
        if overhead_s > 0.0:
            tr.span("startup+scheduling", start, start + overhead_s,
                    track=track, parent=parent)
        base = start + overhead_s
        finish: dict = {}
        for i, node in enumerate(graph.nodes):
            t0 = max((finish[d] for d in node.deps if d in finish),
                     default=base)
            b = node.modeled
            dur = (0.0 if b is None
                   else (b.transfer + b.compute) / b.freq_hz)
            finish[i] = t0 + dur
            if node.kind == "sync" or b is None:
                continue                 # zero-cost markers: no slice
            tr.span(node.kernel.name, t0, t0 + dur, track=track,
                    parent=parent, kind=node.kind)

    def _retire_oldest(self) -> LaunchTicket:
        ticket = self._inflight.pop(0)
        try:
            for b in ticket.outputs:
                data = b.data
                if hasattr(data, "block_until_ready"):
                    data.block_until_ready()
        finally:
            # Release exactly this launch's event segment.  Every launch
            # binds to THIS worker's queue and tickets retire oldest-first,
            # so the segment at the queue head is this ticket's own — even
            # when the graph itself is a cached entry shared with sibling
            # workers.  Regression (ISSUE 6): the drain/release MUST run
            # even when realization raises — the ticket is already popped,
            # and skipping the segment release would permanently skew this
            # lane's per-queue accounting against every later ticket.
            self.queue.drain(ticket.n_events)
            self.queue.release_events(upto=ticket.n_events)
            ticket.t_done = self.clock()
            if self.tracer is not None:
                self.tracer.instant(
                    f"lane:{self.name}", ticket.t_done, "retire",
                    n_requests=ticket.batch.n_requests,
                    n_events=ticket.n_events)
        return ticket

    def drain(self) -> List[LaunchTicket]:
        """Retire every outstanding ticket (oldest first)."""
        out = []
        while self._inflight:
            out.append(self._retire_oldest())
        return out

    def modeled_s_per_request(self) -> Optional[float]:
        """Modeled seconds per served request, or ``None`` before any
        modeled launch completed (unprofiled queues, cold workers)."""
        if self.n_requests <= 0 or self.modeled_s <= 0.0:
            return None
        return self.modeled_s / self.n_requests

    def stats(self) -> "QueueStats":
        return QueueStats(
            name=self.name, config=self.apu.egpu.config.name,
            batches=self.n_batches, requests=self.n_requests,
            modeled_s=self.modeled_s, energy_j=self.energy_j,
            peak_in_flight=self.peak_in_flight,
            backpressure_stalls=self.backpressure_stalls,
            launch_failures=self.launch_failures,
            idle_power_w=self.idle_power_w,
            budget_violations=self.budget_violations)


@dataclasses.dataclass(frozen=True)
class QueueStats:
    """Per-queue roll-up surfaced in the :class:`ServeReport`."""

    name: str
    config: str
    batches: int
    requests: int
    modeled_s: float
    energy_j: float
    peak_in_flight: int
    backpressure_stalls: int
    #: mesh lane width: total devices this worker's launches span (1 for a
    #: plain single-device QueueWorker)
    shards: int = 1
    #: the worker's mesh layout as ((axis, size), ...); () when unsharded
    mesh_axes: Tuple[Tuple[str, int], ...] = ()
    #: mean per-launch utilization of each mesh axis — the fraction of the
    #: axis's devices a launch's sharding actually exploited (a
    #: divisibility fallback to replication shows up as < 1.0 here)
    mesh_utilization: Tuple[Tuple[str, float], ...] = ()
    #: injected faults this lane absorbed (ISSUE 6 fault plans)
    launch_failures: int = 0
    #: this lane's circuit-breaker state at report time
    breaker_state: str = "closed"
    #: times this lane's breaker tripped OPEN (quarantines)
    breaker_trips: int = 0
    #: clock-gated leakage floor of this lane, watts (ISSUE 8) — the serve
    #: report integrates it over the lane's idle modeled time
    idle_power_w: float = 0.0
    #: launches whose booked window-average power broke the lane cap
    #: (stays 0 while the dispatcher enforces the budget)
    budget_violations: int = 0

    def publish_metrics(self, registry) -> None:
        """Publish this lane's totals into a
        :class:`~repro.obs.MetricsRegistry` under ``lane=<name>`` labels
        (snapshot style, idempotent — see :mod:`repro.obs.metrics`)."""
        labels = dict(lane=self.name, config=self.config)
        c = registry.counter
        c("repro_lane_batches_total",
          "micro-batches launched per lane").set_total(self.batches, **labels)
        c("repro_lane_requests_total",
          "requests served per lane").set_total(self.requests, **labels)
        c("repro_lane_launch_failures_total",
          "injected faults absorbed per lane").set_total(
            self.launch_failures, **labels)
        c("repro_lane_breaker_trips_total",
          "circuit-breaker trips per lane").set_total(
            self.breaker_trips, **labels)
        c("repro_lane_backpressure_stalls_total",
          "launches that first retired a ticket").set_total(
            self.backpressure_stalls, **labels)
        g = registry.gauge
        g("repro_lane_modeled_seconds",
          "modeled seconds served per lane").set(self.modeled_s, **labels)
        g("repro_lane_energy_joules",
          "modeled energy per lane").set(self.energy_j, **labels)
        g("repro_lane_peak_in_flight",
          "peak in-flight depth per lane").set(self.peak_in_flight, **labels)
        g("repro_lane_breaker_open",
          "1 when the lane's breaker is OPEN").set(
            1.0 if self.breaker_state == "open" else 0.0, **labels)
        g("repro_lane_idle_power_watts",
          "clock-gated leakage floor per lane").set(
            self.idle_power_w, **labels)
        c("repro_lane_budget_violations_total",
          "launches booked over the lane power cap").set_total(
            self.budget_violations, **labels)


class CircuitBreaker:
    """Per-lane quarantine with half-open recovery probes.

    CLOSED lanes route normally.  ``failure_threshold`` *consecutive*
    failures trip the breaker OPEN: routing skips the lane for ``cooldown``
    dispatcher ticks (dispatch calls, not wall time — deterministic under
    virtual clocks).  After the cooldown the breaker goes HALF-OPEN and
    admits exactly one probe launch: success closes it, failure re-opens
    it for another cooldown.  A failure while half-open always re-trips
    (one strike), the classic breaker asymmetry.
    """

    def __init__(self, failure_threshold: int = 3, cooldown: int = 8):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown = cooldown
        self.state = "closed"
        self.consecutive_failures = 0
        self.opened_at_tick = 0
        self.trips = 0
        self._probe_in_flight = False

    def available(self, tick: int) -> bool:
        """May this lane take traffic at dispatcher tick ``tick``?  (Also
        performs the OPEN -> HALF-OPEN transition once the cooldown
        elapses.)"""
        if self.state == "open" and \
                tick - self.opened_at_tick >= self.cooldown:
            self.state = "half-open"
            self._probe_in_flight = False
        if self.state == "closed":
            return True
        return self.state == "half-open" and not self._probe_in_flight

    def on_attempt(self) -> None:
        if self.state == "half-open":
            self._probe_in_flight = True

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self.state = "closed"
        self._probe_in_flight = False

    def record_failure(self, tick: int) -> None:
        self.consecutive_failures += 1
        if (self.state == "half-open"
                or self.consecutive_failures >= self.failure_threshold):
            self.state = "open"
            self.opened_at_tick = tick
            self.trips += 1
            self.consecutive_failures = 0
            self._probe_in_flight = False


class MultiQueueDispatcher:
    """Route micro-batches to the least-loaded *available* worker.

    "Least loaded" is in-flight depth first; depth ties break on **modeled
    seconds per request** — the machine model's view of each lane's speed —
    so a faster / wider lane (a 16-thread config, a sharded mesh lane)
    genuinely attracts more traffic.  Tie-breaking on raw requests served
    (the pre-ISSUE-5 rule) permanently biased heterogeneous mixes: a fast
    worker that served one extra warmup batch lost every subsequent tie to
    a slower sibling at equal depth.  Workers with no model data yet
    (cold, or unprofiled) fall back to requests served, and are preferred
    at equal depth so every lane bootstraps its model quickly.

    Fault tolerance (ISSUE 6): :meth:`dispatch` is the retrying front —
    an :class:`InjectedFault` reroutes the micro-batch to a different lane
    under capped exponential backoff; per-lane :class:`CircuitBreaker`\\ s
    quarantine lanes that fail ``failure_threshold`` times in a row and
    re-admit them via half-open probes after ``breaker_cooldown`` dispatch
    ticks.  A batch that exhausts every retry raises
    :class:`DispatchError` so the server can shed it loudly.

    Power budgets (ISSUE 8): built with ``budget=``\\
    :class:`~repro.serve.power.PowerBudget`, routing switches to
    :meth:`_pick_powered` — every candidate lane is priced (modeled
    latency, window-average power over the launch window), over-cap lanes
    are throttled, budget-eligible ones compete on requests-per-joule, and
    a batch no lane can take on-budget raises :class:`PowerBudgetError`
    (a :class:`DispatchError`, so the server's loud-shed path applies).
    All pricing is on the modeled virtual timeline — deterministic, never
    wall clock.
    """

    def __init__(self, workers: Sequence[QueueWorker],
                 failure_threshold: int = 3, breaker_cooldown: int = 8,
                 max_attempts: Optional[int] = None,
                 backoff_base_s: float = 0.001,
                 backoff_cap_s: float = 0.05,
                 tracer: Optional[Tracer] = None,
                 budget: Optional[PowerBudget] = None):
        if not workers:
            raise ValueError("need at least one QueueWorker")
        names = [w.name for w in workers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate worker names: {names}")
        if max_attempts is not None and max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.workers = list(workers)
        self.breakers = {w.name: CircuitBreaker(failure_threshold,
                                                breaker_cooldown)
                         for w in workers}
        self.max_attempts = max_attempts
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        #: opt-in span tracer (ISSUE 7); guarded at every hook
        self.tracer = tracer
        #: fleet power budget (ISSUE 8); ``None`` keeps the historical
        #: latency-greedy routing with zero pricing overhead
        self.budget = budget
        if budget is not None:
            for w in self.workers:
                w.power_budget = budget
        self._tick = 0                   # dispatch calls (breaker clock)
        self.retries = 0                 # failed attempts that were rerouted
        self.dispatch_failures = 0       # batches that exhausted every retry
        self.power_throttles = 0         # lane candidates skipped for power
        self.power_sheds = 0             # batches no lane could take on-budget
        self.peak_fleet_power_w = 0.0    # max modeled fleet draw sampled

    @staticmethod
    def _route_key(w: QueueWorker) -> Tuple[float, int, float, int]:
        spr = w.modeled_s_per_request()
        if spr is None:                  # no model data yet: fall back to
            return (w.depth, 0, float(w.n_requests), w.n_requests)
        # final n_requests entry keeps equal-speed (homogeneous) lanes
        # alternating instead of resolving every exact spr tie to the
        # first worker in declaration order
        return (w.depth, 1, spr, w.n_requests)

    def available_workers(self) -> List[QueueWorker]:
        """Lanes routing may use right now: breaker CLOSED, or HALF-OPEN
        with a free probe slot.  Falls back to the whole fleet when every
        breaker is open — the dispatcher degrades to forced probes rather
        than refusing service outright."""
        avail = [w for w in self.workers
                 if self.breakers[w.name].available(self._tick)]
        return avail or list(self.workers)

    def pick(self, exclude: Sequence[str] = ()) -> QueueWorker:
        """The worker the next micro-batch should go to (see class doc).
        ``exclude`` names lanes that already failed this batch — they are
        only reconsidered when no other lane is left."""
        excluded: Set[str] = set(exclude)
        candidates = [w for w in self.available_workers()
                      if w.name not in excluded]
        if not candidates:
            candidates = [w for w in self.workers if w.name not in excluded]
        if not candidates:
            candidates = self.workers
        return min(candidates, key=self._route_key)

    # -- power-aware routing (ISSUE 8) --------------------------------------
    def fleet_power_w(self, t_now: float) -> float:
        """Modeled instantaneous fleet draw: busy lanes at their remaining
        window-average power, idle lanes at their clock-gated leakage
        floor."""
        return sum(w.current_power_w(t_now) for w in self.workers)

    def _pick_powered(self, batch: MicroBatch,
                      estimator: Callable[[QueueWorker],
                                          Tuple[Optional[PhaseBreakdown],
                                                float]],
                      t_now: float,
                      exclude: Sequence[str]) -> Optional[QueueWorker]:
        """Budget-aware routing: price every candidate lane — (modeled
        latency, window-average power) — and return the best
        requests-per-joule among budget-eligible ones, breaking ties on
        the shorter window and then the classic depth route key.  Lanes
        whose window price breaks the lane cap, or would push the modeled
        fleet draw over the fleet cap, are throttled (skipped and
        counted).  Returns ``None`` when no candidate can take the batch
        on-budget — the caller sheds loudly."""
        excluded: Set[str] = set(exclude)
        candidates = [w for w in self.available_workers()
                      if w.name not in excluded]
        if not candidates:
            candidates = [w for w in self.workers if w.name not in excluded]
        if not candidates:
            candidates = self.workers
        fleet_now = self.fleet_power_w(t_now)
        best, best_key = None, None
        for w in candidates:
            fused, energy = estimator(w)
            price = w.price(fused, energy, t_now,
                            n_requests=batch.n_requests)
            fleet_with = (fleet_now - w.current_power_w(t_now)
                          + price.avg_power_w)
            if not (self.budget.lane_ok(price.avg_power_w)
                    and self.budget.fleet_ok(fleet_with)):
                self.power_throttles += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        f"lane:{w.name}", t_now, "power-throttle",
                        avg_power_w=price.avg_power_w,
                        fleet_power_w=fleet_with)
                continue
            key = (-price.requests_per_joule, price.window_s,
                   self._route_key(w))
            if best is None or key < best_key:
                best, best_key = w, key
        return best

    def dispatch(self, batch: MicroBatch,
                 graph_for: Callable[[QueueWorker], CommandGraph],
                 t_now: Optional[float] = None,
                 estimate_for: Optional[
                     Callable[[QueueWorker],
                              Tuple[Optional[PhaseBreakdown], float]]] = None
                 ) -> Tuple[LaunchTicket, List[LaunchTicket]]:
        """Launch ``batch`` with retry + quarantine (the fault-tolerant
        front the server uses).

        ``graph_for(worker)`` supplies the worker's cached graph (graphs
        are per-APU/placement, so the cache lookup happens per attempt).
        With a :class:`PowerBudget` installed, ``estimate_for(worker)``
        (defaulting to ``worker.estimate(graph_for(worker))``) supplies
        the pricing view and routing goes through :meth:`_pick_powered`;
        a batch no lane can take on-budget raises
        :class:`PowerBudgetError`.  Returns the successful ticket plus
        every ticket retired for backpressure along the way — including
        by failed attempts.  Raises :class:`DispatchError` (carrying
        those retired tickets) when the attempt budget is exhausted.
        """
        self._tick += 1
        cap = (self.max_attempts if self.max_attempts is not None
               else 2 * len(self.workers))
        retired_all: List[LaunchTicket] = []
        tried: Set[str] = set()
        last: Optional[InjectedFault] = None
        for attempt in range(cap):
            if self.budget is None:
                worker = self.pick(exclude=tried)
            else:
                t_ref = (t_now if t_now is not None
                         else self.workers[0].clock())
                est = (estimate_for if estimate_for is not None
                       else lambda w: w.estimate(graph_for(w)))
                picked = self._pick_powered(batch, est, t_ref, tried)
                if picked is None:
                    self.power_sheds += 1
                    fleet_mw = self.fleet_power_w(t_ref) * 1e3
                    if self.tracer is not None:
                        for req in batch.requests:
                            self.tracer.request_event(
                                req.rid, t_ref, "power-shed",
                                fleet_power_mw=fleet_mw)
                    raise PowerBudgetError(
                        f"power budget (lane {self.budget.lane_mw} mW, "
                        f"fleet {self.budget.fleet_mw} mW) leaves no lane "
                        f"for a micro-batch of {batch.n_requests} "
                        f"request(s): modeled fleet draw {fleet_mw:.2f} mW",
                        retired=retired_all)
                worker = picked
            breaker = self.breakers[worker.name]
            breaker.on_attempt()
            if self.tracer is not None:
                t_evt = t_now if t_now is not None else worker.clock()
                for req in batch.requests:
                    self.tracer.request_event(
                        req.rid, t_evt, "dispatch-pick", lane=worker.name,
                        attempt=attempt)
            try:
                ticket, retired = worker.launch(graph_for(worker), batch,
                                                t_now=t_now)
            except InjectedFault as e:
                retired_all.extend(e.retired)
                trips_before = breaker.trips
                breaker.record_failure(self._tick)
                tried.add(worker.name)
                if len(tried) >= len(self.workers):
                    tried.clear()        # second pass over the fleet
                last = e
                will_retry = attempt + 1 < cap
                if self.tracer is not None:
                    t_evt = t_now if t_now is not None else worker.clock()
                    if breaker.trips > trips_before:
                        self.tracer.instant(f"lane:{worker.name}", t_evt,
                                            "breaker-trip",
                                            cooldown=breaker.cooldown)
                    for req in batch.requests:
                        self.tracer.request_event(
                            req.rid, t_evt, "fault", lane=worker.name,
                            launch_idx=e.launch_idx, reason=e.reason)
                        if breaker.trips > trips_before:
                            self.tracer.request_event(
                                req.rid, t_evt, "breaker-trip",
                                lane=worker.name)
                        if will_retry:
                            self.tracer.request_event(
                                req.rid, t_evt, "retry", attempt=attempt)
                if will_retry:
                    self.retries += 1
                    if self.backoff_base_s > 0.0:
                        backoff_s = min(self.backoff_cap_s,
                                        self.backoff_base_s * (2 ** attempt))
                        if self.tracer is not None:
                            for req in batch.requests:
                                self.tracer.request_event(
                                    req.rid, t_evt, "backoff",
                                    backoff_s=backoff_s)
                        time.sleep(backoff_s)
                continue
            breaker.record_success()
            retired_all.extend(retired)
            if self.budget is not None:
                # sample the modeled fleet draw with the new launch booked
                t_ref = t_now if t_now is not None else worker.clock()
                self.peak_fleet_power_w = max(self.peak_fleet_power_w,
                                              self.fleet_power_w(t_ref))
            return ticket, retired_all
        self.dispatch_failures += 1
        raise DispatchError(
            f"micro-batch of {batch.n_requests} request(s) failed all "
            f"{cap} dispatch attempts (last: {last})",
            retired=retired_all) from last

    def quarantines(self) -> int:
        """Total circuit-breaker trips across the fleet."""
        return sum(b.trips for b in self.breakers.values())

    def drain_all(self) -> List[LaunchTicket]:
        out: List[LaunchTicket] = []
        for w in self.workers:
            out.extend(w.drain())
        return out

    def stats(self) -> Tuple[QueueStats, ...]:
        out = []
        for w in self.workers:
            b = self.breakers[w.name]
            out.append(dataclasses.replace(
                w.stats(), breaker_state=b.state, breaker_trips=b.trips))
        return tuple(out)
