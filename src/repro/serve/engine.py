"""Slot-based continuous-batching decode engine (ISSUE 9).

The maxtext/JetStream serving split, on TinyCL primitives:

- :meth:`DecodeEngine.prefill` runs one request's prompt through a cached
  per-prompt-length ``CommandGraph`` and returns a :class:`Prefix` — the
  first greedy token plus the request's batch-1 cache.
- :meth:`DecodeEngine.insert` splices a prefix into slot ``i`` of a
  persistent :class:`DecodeState` whose cache leaves live batch-``num_slots``
  wide on the owning worker's queue.
- :meth:`DecodeEngine.generate` advances ALL occupied slots one token in
  exactly ONE cached-graph launch per step; freed slots admit freshly
  prefilled requests between steps, so a finished request never blocks its
  neighbors.

Engine invariants (pinned by ``tests/test_decode_serve.py``):

- **One cached graph per generate step.**  The step graph is captured once
  per (model config, num_slots) and re-launched with
  ``launch_prefix(..., donate=<cache leaves>)`` — slot insertion is a
  launch-time buffer update, never a re-capture, and the graphs stay pure:
  slot state is data the launch carries, not state the capture holds.
- **Slot insertion never perturbs other slots' outputs.**  The per-slot
  step is an independent ``jax.vmap`` lane over (cache slot, token,
  position); decode under staggered arrival is bit-identical to whole-batch
  :func:`~repro.train.serve.greedy_generate` for every cache family (plain
  KV, MLA latent, rwkv6 O(1) state).
- **Honest accounting.**  The bytes-per-step roofline
  (:func:`engine_roofline`) is summed off the captured schedule's
  :class:`~repro.core.runtime.GraphNode` counts — the
  :class:`~repro.core.machine.WorkCounts` each node was actually priced
  with — never re-derived on the side.
- **No full-vocabulary output rides the step graph.**  The decode kernel
  uses :func:`~repro.train.serve.make_decode_step` with
  ``return_logits=False``; :meth:`DecodeEngine.decode_graph`'s out avals
  carry tokens + cache only (aval-checked at capture).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.apu import Stage
from ..core.device import EGPUConfig, EGPU_16T
from ..core.machine import WorkCounts
from ..core.program import KernelRegistry, Program, kernel_family
from ..core.runtime import CommandGraph, Kernel
from ..core.scheduler import optimal_ndrange
from ..models.config import ModelConfig
from ..models.transformer import cache_axes, cache_struct
from ..obs import Tracer
from ..train.serve import make_decode_step, make_prefill_step
from .batching import MicroBatch
from .cache import GraphCache
from .dispatch import QueueWorker

_TOKEN_BYTES = 4                     # int32 token / position ids


def _is_axes(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def batch_axes(cfg: ModelConfig):
    """Pytree (cache structure) of each leaf's batch-axis index.

    Derived from :func:`~repro.models.transformer.cache_axes` — stacked
    ``pos{i}`` leaves carry batch at axis 1 behind the leading "layers"
    axis, deepseek's dense ``layer0`` leaves at axis 0 — so the engine
    never hard-codes a layout the model family can vary.
    """
    return jax.tree_util.tree_map(lambda ax: ax.index("batch"),
                                  cache_axes(cfg), is_leaf=_is_axes)


def _engine_counts(*, batch: int, params_bytes: float, cache_bytes: float,
                   write_bytes: float, ops: float, io_bytes: float,
                   resident: bool = True) -> WorkCounts:
    """First-order structural work of one engine step (or prefill).

    ``resident=True`` is the engine's captured-state contract: only token /
    position I/O crosses the host bus, the params + cache stream through
    the D$ hierarchy.  ``resident=False`` models the naive
    rebatch-per-step baseline that round-trips the whole cache through the
    host every token (out + back in) — the bench's comparison arm.
    """
    host = float(io_bytes) + (0.0 if resident else 2.0 * float(cache_bytes))
    return WorkCounts(
        ops=float(ops),
        dcache_bytes=float(params_bytes) + float(cache_bytes)
        + float(write_bytes),
        host_bytes=host,
        working_set=float(params_bytes) + float(cache_bytes))


#: engine kernel families live in a PRIVATE registry: their builders
#: require a ModelConfig (no default variant exists), so they must not
#: pollute the global registry that ``Program.create_kernels()`` sweeps
ENGINE_REGISTRY = KernelRegistry()


@kernel_family("engine.prefill", registry=ENGINE_REGISTRY)
def build_prefill_kernel(config: EGPUConfig = EGPU_16T, *,
                         cfg: ModelConfig, max_len: int,
                         cache_dtype: str = "bfloat16") -> Kernel:
    """Batch-1 prompt pass -> (first greedy token (1,), *cache leaves).

    One kernel serves every prompt length — the per-length specialization
    lives in the :class:`~repro.serve.cache.GraphCache` key (input avals),
    so distinct lengths get distinct captured graphs of the same kernel.
    """
    dtype = jnp.dtype(cache_dtype)
    step = make_prefill_step(cfg, max_len, dtype)

    # ``_params_def`` (the params treedef) is stamped on the executor by the
    # engine before first use — builders only see hashable variant keys, and
    # the treedef is identical for every engine sharing this (cfg, variant).
    def engine_prefill(prompt, *param_leaves):
        params = jax.tree_util.tree_unflatten(
            engine_prefill._params_def, param_leaves)
        logits, cache = step(params, {"tokens": prompt})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (tok, *jax.tree_util.tree_leaves(cache))

    return Kernel(name="engine.prefill", executor=engine_prefill,
                  counts=_engine_counts)


@kernel_family("engine.decode_step", registry=ENGINE_REGISTRY)
def build_decode_kernel(config: EGPUConfig = EGPU_16T, *,
                        cfg: ModelConfig, num_slots: int,
                        cache_dtype: str = "bfloat16") -> Kernel:
    """One token for every slot: (tokens (B,), positions (B,), *cache,
    *params) -> (next tokens (B,), *new cache leaves).

    Each slot is an independent ``jax.vmap`` lane over (cache slot, token,
    position) — per-slot positions are what make staggered insertion
    bit-identical to each request's own whole-batch trajectory.  The step
    body is the ``return_logits=False`` fast path, so no ``(B, vocab)``
    buffer rides the captured graph's outputs.
    """
    del num_slots                        # identity only: one graph per width
    bidx = batch_axes(cfg)
    cache_def = jax.tree_util.tree_structure(bidx)
    n_cache = cache_def.num_leaves
    step = make_decode_step(cfg, return_logits=False)

    def one(params, cache_slot, tok, pos):
        cache_b = jax.tree_util.tree_map(
            lambda c, i: jnp.expand_dims(c, i), cache_slot, bidx)
        nxt, new_cache = step(params, cache_b, tok[None], pos)
        new_slot = jax.tree_util.tree_map(
            lambda c, i: jnp.squeeze(c, axis=i), new_cache, bidx)
        return nxt[0], new_slot

    vstep = jax.vmap(one, in_axes=(None, bidx, 0, 0), out_axes=(0, bidx))

    def engine_decode(tokens, positions, *state):
        cache = jax.tree_util.tree_unflatten(cache_def, state[:n_cache])
        params = jax.tree_util.tree_unflatten(
            engine_decode._params_def, state[n_cache:])
        toks, new_cache = vstep(params, cache, tokens, positions)
        return (toks, *jax.tree_util.tree_leaves(new_cache))

    return Kernel(name="engine.decode_step", executor=engine_decode,
                  counts=_engine_counts)


@dataclasses.dataclass
class Prefix:
    """One prefilled request, ready for :meth:`DecodeEngine.insert`."""

    token: jax.Array                     # (1,) int32 — first greedy token
    cache: Any                           # batch-1 cache pytree
    pos: int                             # next decode position (= prompt len)
    prompt_len: int
    rid: Optional[int] = None            # server request id (None standalone)
    modeled_s: float = 0.0               # fused modeled prefill latency
    energy_j: float = 0.0


@dataclasses.dataclass
class DecodeState:
    """The persistent batched decode state (all ``num_slots`` wide).

    ``tokens``/``cache`` are replaced by each :meth:`DecodeEngine.generate`
    launch's outputs (the cache leaves are *donated*, so the old leaves are
    consumed in place); ``positions``/``occupied``/``rids`` are host-side
    launch-time data.
    """

    tokens: jax.Array                    # (B,) int32 — last emitted per slot
    positions: jax.Array                 # (B,) int32 — next decode position
    cache: Any                           # batch-B cache pytree
    occupied: List[bool]
    rids: List[Optional[int]]

    @property
    def num_slots(self) -> int:
        return len(self.occupied)

    @property
    def n_occupied(self) -> int:
        return sum(self.occupied)

    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupied) if not o]


@dataclasses.dataclass(frozen=True)
class EngineRoofline:
    """Memory-bandwidth roofline of ONE captured generate step, summed off
    the schedule's :class:`~repro.core.runtime.GraphNode` counts."""

    dcache_bytes: float                  # core <-> D$ traffic per step
    host_bytes: float                    # counts-level host traffic per step
    transfer_bytes: float                # explicit transfer-node bytes
    dcache_bw_bytes_per_s: float         # line width x CUs x clock
    modeled_step_s: float                # fused modeled latency of the step

    @property
    def bytes_per_step(self) -> float:
        return self.dcache_bytes + self.host_bytes + self.transfer_bytes

    @property
    def min_step_s(self) -> float:
        """Bandwidth-bound floor: D$ traffic over D$ bandwidth."""
        if self.dcache_bw_bytes_per_s <= 0.0:
            return 0.0
        return self.dcache_bytes / self.dcache_bw_bytes_per_s

    @property
    def mem_bound_fraction(self) -> float:
        """How much of the modeled step the bandwidth floor explains
        (→ 1.0 when decode is purely memory-bound, as AR decode is)."""
        if self.modeled_step_s <= 0.0:
            return 0.0
        return min(1.0, self.min_step_s / self.modeled_step_s)


def graph_traffic(graph: CommandGraph) -> Tuple[float, float, float]:
    """(dcache, host, transfer) bytes of one launch, read straight off the
    captured schedule — each kernel node carries the WorkCounts it was
    priced with, transfer nodes their payload size."""
    dcache = host = moved = 0.0
    for n in graph.nodes:
        if n.counts is not None:
            dcache += n.counts.dcache_bytes
            host += n.counts.host_bytes
        moved += n.nbytes
    return dcache, host, moved


def engine_roofline(graph: CommandGraph, config: EGPUConfig
                    ) -> EngineRoofline:
    dcache, host, moved = graph_traffic(graph)
    fused, _ = graph.fused_modeled()
    bw = (config.dcache_line_bytes * config.compute_units * config.freq_hz)
    return EngineRoofline(
        dcache_bytes=dcache, host_bytes=host, transfer_bytes=moved,
        dcache_bw_bytes_per_s=float(bw),
        modeled_step_s=fused.total_s if fused is not None else 0.0)


class DecodeEngine:
    """Continuous-batching decode on one :class:`QueueWorker` lane.

    ::

        engine = DecodeEngine(cfg, params, num_slots=4, max_len=64)
        state = engine.init_state()
        state = engine.insert(engine.prefill(params, prompt), state, slot=0)
        state, toks = engine.generate(params, state)   # ONE graph launch

    The worker must capture WITHOUT explicit transfers: the decode state is
    resident — donated back to each launch, never round-tripped — and the
    counts model prices exactly token/position I/O as host traffic
    (``resident=False`` builds the naive baseline arm for the bench).

    Donation discipline: donated inputs are consumed by XLA, so every
    launch realizes its token output and retires (drains) before the next
    launch donates the buffers the previous outputs alias.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 num_slots: int = 4, max_len: int = 64,
                 config: EGPUConfig = EGPU_16T,
                 worker: Optional[QueueWorker] = None,
                 cache: Optional[GraphCache] = None,
                 cache_dtype: Any = jnp.bfloat16,
                 resident: bool = True,
                 tracer: Optional[Tracer] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 name: str = "engine"):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if cfg.is_encoder:
            raise ValueError(f"{cfg.name} is encoder-only: no decode engine")
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.resident = resident
        self.name = name
        self.worker = worker if worker is not None else QueueWorker(
            config, name=name, max_in_flight=1, explicit_transfers=False,
            clock=clock, tracer=tracer)
        if self.worker.apu.explicit_transfers:
            raise ValueError(
                "DecodeEngine needs a worker with explicit_transfers=False: "
                "the decode state is resident (donated in place), not "
                "round-tripped through transfer nodes every step")
        self.config = self.worker.apu.egpu.config
        self.cache = cache if cache is not None else GraphCache(capacity=16)
        self.tracer = tracer
        self.clock = clock
        self._program = Program.build(self.config, registry=ENGINE_REGISTRY)
        self._bidx = batch_axes(cfg)
        self._param_leaves = tuple(jax.tree_util.tree_leaves(params))
        self._params_bytes = float(sum(x.nbytes for x in self._param_leaves))
        self._param_elems = float(sum(x.size for x in self._param_leaves))
        # per-slot cache traffic: kv_seq-indexed leaves write one position
        # per step, recurrent (O(1)) leaves rewrite whole; reads sweep all
        slot_struct = cache_struct(cfg, 1, max_len, self.cache_dtype)
        axes_leaves = jax.tree_util.tree_leaves(cache_axes(cfg),
                                                is_leaf=_is_axes)
        struct_leaves = jax.tree_util.tree_leaves(slot_struct)

        def _nbytes(s):
            return float(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize

        self._slot_cache_bytes = float(
            sum(_nbytes(x) for x in struct_leaves))
        self._slot_write_bytes = float(sum(
            (_nbytes(x) / max_len if "kv_seq" in ax else _nbytes(x))
            for x, ax in zip(struct_leaves, axes_leaves)))
        self._decode_stage: Optional[Tuple[Stage, ...]] = None
        self._prefill_stages: Dict[int, Tuple[Stage, ...]] = {}
        self._canonical_structs: Optional[Tuple[Any, ...]] = None
        #: the captured per-step graph (None until the first generate) —
        #: tests pin the no-(B, vocab)-output invariant on its out_avals
        self.decode_graph: Optional[CommandGraph] = None
        # accounting (all modeled / machine-model virtual time)
        self.n_prefills = 0
        self.n_inserts = 0
        self.n_steps = 0
        self.n_tokens = 0                # tokens emitted from occupied slots
        self.prefill_modeled_s = 0.0
        self.decode_modeled_s = 0.0
        self.energy_j = 0.0
        self._occupancy_sum = 0.0

    # -- state construction -------------------------------------------------
    def _decode_kernel(self) -> Kernel:
        kern = self._program.create_kernel(
            "engine.decode_step", cfg=self.cfg, num_slots=self.num_slots,
            cache_dtype=str(self.cache_dtype))
        kern.executor._params_def = jax.tree_util.tree_structure(self.params)
        return kern

    def _cache_structs(self) -> Tuple[Any, ...]:
        """Canonical per-leaf avals of the persistent cache: the decode
        step's OWN output avals (its fixed point), not ``cache_struct``'s
        advertised ones — recurrent families re-emit some leaves at the
        activation dtype (rwkv's token-shift state), and seeding the state
        there keeps every step on ONE captured graph."""
        if self._canonical_structs is not None:
            return self._canonical_structs
        b = self.num_slots
        kern = self._decode_kernel()
        leaves = [jax.ShapeDtypeStruct(s.shape, s.dtype)
                  for s in jax.tree_util.tree_leaves(
                      cache_struct(self.cfg, b, self.max_len,
                                   self.cache_dtype))]
        io = (jax.ShapeDtypeStruct((b,), jnp.int32),
              jax.ShapeDtypeStruct((b,), jnp.int32))
        pstructs = [jax.ShapeDtypeStruct(p.shape, p.dtype)
                    for p in self._param_leaves]
        for _ in range(3):                       # fixed point in <= 1 pass
            outs = jax.eval_shape(kern.executor, *io, *leaves, *pstructs)
            new = [jax.ShapeDtypeStruct(o.shape, o.dtype)
                   for o in outs[1:]]
            if [(l.shape, l.dtype) for l in new] == \
                    [(l.shape, l.dtype) for l in leaves]:
                break
            leaves = new
        self._canonical_structs = tuple(leaves)
        return self._canonical_structs

    def init_state(self) -> DecodeState:
        """An all-free decode state (zero cache, batch ``num_slots``)."""
        b = self.num_slots
        cache = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(self._bidx),
            [jnp.zeros(s.shape, s.dtype) for s in self._cache_structs()])
        return DecodeState(
            tokens=jnp.zeros((b,), jnp.int32),
            positions=jnp.zeros((b,), jnp.int32),
            cache=cache, occupied=[False] * b, rids=[None] * b)

    # -- counts -------------------------------------------------------------
    def _decode_counts_params(self) -> Dict[str, Any]:
        b = self.num_slots
        return dict(
            batch=b,
            params_bytes=self._params_bytes,
            cache_bytes=self._slot_cache_bytes * b,
            write_bytes=self._slot_write_bytes * b,
            ops=self._param_elems * b,
            io_bytes=float(3 * b * _TOKEN_BYTES),   # tokens+pos in, tokens out
            resident=self.resident)

    def _prefill_counts_params(self, prompt_len: int) -> Dict[str, Any]:
        return dict(
            batch=1,
            params_bytes=self._params_bytes,
            cache_bytes=self._slot_cache_bytes,
            write_bytes=self._slot_write_bytes * prompt_len,
            ops=self._param_elems * prompt_len,
            io_bytes=float(prompt_len * _TOKEN_BYTES + _TOKEN_BYTES),
            resident=self.resident)

    # -- graphs -------------------------------------------------------------
    def _prefill_graph(self, prompt: jax.Array) -> CommandGraph:
        s = int(prompt.shape[1])
        stages = self._prefill_stages.get(s)
        if stages is None:
            kern = self._program.create_kernel(
                "engine.prefill", cfg=self.cfg, max_len=self.max_len,
                cache_dtype=str(self.cache_dtype))
            kern.executor._params_def = jax.tree_util.tree_structure(
                self.params)
            stages = (Stage(kern,
                            counts_params=self._prefill_counts_params(s)),)
            self._prefill_stages[s] = stages
        inputs = (prompt, *self._param_leaves)
        ndr = [optimal_ndrange(s * self.cfg.d_model, self.config)]
        graph, _hit = self.cache.get_or_capture(
            self.worker.apu, list(stages), inputs, ndranges=ndr)
        return graph

    def _generate_graph(self, state: DecodeState) -> CommandGraph:
        stages = self._decode_stage
        if stages is None:
            stages = (Stage(self._decode_kernel(),
                            counts_params=self._decode_counts_params()),)
            self._decode_stage = stages
        inputs = (state.tokens, state.positions,
                  *jax.tree_util.tree_leaves(state.cache),
                  *self._param_leaves)
        ndr = [optimal_ndrange(self.num_slots * self.cfg.d_model,
                               self.config)]
        graph, hit = self.cache.get_or_capture(
            self.worker.apu, list(stages), inputs, ndranges=ndr)
        if not hit:
            # the satellite-6 invariant, checked at capture: no output aval
            # is a full-vocabulary (B, Vp) logits buffer
            bad = [a for a in graph.out_avals
                   if len(a.shape) >= 2
                   and a.shape[-1] == self.cfg.vocab_padded
                   and a.shape[0] == self.num_slots]
            if bad:
                raise AssertionError(
                    f"generate-step graph carries full-vocab outputs "
                    f"{[(a.shape, str(a.dtype)) for a in bad]}; "
                    "make_decode_step(return_logits=False) must elide them")
            # donation-aware sanitizer sweep at capture time (repro.analyze):
            # steady-state launches donate the cache-leaf slots, so prove
            # NOW that every reader of those slots sits on the ordered path
            # to the realize-then-drain boundary.  Memoized on the graph —
            # the per-step donating launch re-checks for free.
            donate = tuple(range(
                2, 2 + len(jax.tree_util.tree_leaves(state.cache))))
            findings = graph.verify(donate=donate)
            self.cache.findings += len(findings)
            if findings and os.environ.get("REPRO_VERIFY") == "1":
                from ..analyze.graph import GraphVerifyError
                raise GraphVerifyError(findings)
        self.decode_graph = graph
        return graph

    # -- the JetStream-style API -------------------------------------------
    def prefill(self, params: Optional[Any], prompt: Any,
                rid: Optional[int] = None) -> Prefix:
        """Run one request's prompt; returns its :class:`Prefix`.

        ``params`` may be ``None`` to use the engine's bound params (they
        are launch inputs either way — the captured graph is pure).
        """
        if params is not None and params is not self.params:
            raise ValueError(
                "prefill params must be the engine's bound params: the "
                "captured graphs pin their avals (pass None to reuse)")
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.ndim == 1:
            prompt = prompt[None, :]
        if prompt.ndim != 2 or prompt.shape[0] != 1:
            raise ValueError(
                f"prefill takes ONE request's prompt (S,) or (1, S); got "
                f"shape {tuple(prompt.shape)}")
        s = int(prompt.shape[1])
        if s < 1 or s >= self.max_len:
            raise ValueError(
                f"prompt length {s} must be in [1, max_len={self.max_len})")
        graph = self._prefill_graph(prompt)
        batch = MicroBatch(bucket_key=("engine.prefill", s),
                           inputs=(prompt, *self._param_leaves),
                           requests=(), capacity=1, crop_outputs=False)
        t_now = self.clock()
        ticket, _ = self.worker.launch(graph, batch, t_now=t_now)
        outs = ticket.outputs
        tok = outs[0].data
        cache = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(self._bidx),
            [b.data for b in outs[1:]])
        jax.block_until_ready(tok)
        self.worker.drain()
        modeled = ticket.modeled_latency_s or 0.0
        self.n_prefills += 1
        self.prefill_modeled_s += modeled
        self.energy_j += ticket.energy_j
        if self.tracer is not None and rid is not None:
            self.tracer.child(rid, "engine.prefill", t_now,
                              ticket.t_done_modeled or t_now,
                              prompt_len=s)
        return Prefix(token=tok, cache=cache, pos=s, prompt_len=s, rid=rid,
                      modeled_s=modeled, energy_j=ticket.energy_j)

    def insert(self, prefix: Prefix, state: DecodeState,
               slot: int) -> DecodeState:
        """Splice ``prefix`` into ``slot`` — a launch-time buffer update on
        the persistent state, never a re-capture."""
        if not 0 <= slot < state.num_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {state.num_slots})")
        if state.occupied[slot]:
            raise ValueError(f"slot {slot} is occupied (rid="
                             f"{state.rids[slot]}); release it first")
        state.tokens = state.tokens.at[slot].set(prefix.token[0])
        state.positions = state.positions.at[slot].set(prefix.pos)
        state.cache = jax.tree_util.tree_map(
            lambda dst, src, i: jax.lax.dynamic_update_index_in_dim(
                dst, jnp.squeeze(src, axis=i).astype(dst.dtype), slot, i),
            state.cache, prefix.cache, self._bidx)
        state.occupied[slot] = True
        state.rids[slot] = prefix.rid
        self.n_inserts += 1
        return state

    def release(self, state: DecodeState, slot: int) -> DecodeState:
        """Free a finished slot (its lane keeps stepping on stale data —
        pure and discarded — until a fresh prefix is inserted)."""
        state.occupied[slot] = False
        state.rids[slot] = None
        return state

    def generate(self, params: Optional[Any], state: DecodeState
                 ) -> Tuple[DecodeState, np.ndarray]:
        """Advance every slot one token — ONE cached-graph launch.

        Returns ``(state, tokens)`` where ``tokens`` is the realized (B,)
        int32 next-token vector (occupied slots' entries are live; free
        slots' entries are stale lanes to ignore).
        """
        if params is not None and params is not self.params:
            raise ValueError(
                "generate params must be the engine's bound params: the "
                "captured graph pins their avals (pass None to reuse)")
        graph = self._generate_graph(state)
        cache_leaves = jax.tree_util.tree_leaves(state.cache)
        inputs = (state.tokens, state.positions, *cache_leaves,
                  *self._param_leaves)
        # donate exactly the persistent cache leaves (input slots 2..) so
        # XLA reuses them for the step's outputs instead of allocating a
        # fresh cache per token
        donate = tuple(range(2, 2 + len(cache_leaves)))
        batch = MicroBatch(bucket_key=("engine.generate", self.num_slots),
                           inputs=inputs, requests=(),
                           capacity=self.num_slots, crop_outputs=False,
                           donate=donate)
        t_now = self.clock()
        ticket, _ = self.worker.launch(graph, batch, t_now=t_now)
        outs = ticket.outputs
        toks = outs[0].data
        new_leaves = [b.data for b in outs[1:]]
        # realize BEFORE retiring: the next launch donates these buffers
        tokens_np = np.asarray(jax.device_get(toks))
        jax.block_until_ready(new_leaves)
        self.worker.drain()
        state.tokens = toks
        state.positions = state.positions + 1
        state.cache = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(self._bidx), new_leaves)
        occ = state.n_occupied
        modeled = ticket.modeled_latency_s or 0.0
        self.n_steps += 1
        self.n_tokens += occ
        self.decode_modeled_s += modeled
        self.energy_j += ticket.energy_j
        self._occupancy_sum += occ / self.num_slots
        if self.tracer is not None:
            start = (ticket.t_done_modeled - modeled
                     if ticket.t_done_modeled is not None else t_now)
            self.tracer.span(
                "engine.generate", start,
                ticket.t_done_modeled if ticket.t_done_modeled is not None
                else t_now,
                track=f"engine/{self.name}", step=self.n_steps,
                occupied=occ, slots=self.num_slots)
            for slot, rid in enumerate(state.rids):
                if rid is not None and state.occupied[slot]:
                    self.tracer.request_event(
                        rid, ticket.t_done_modeled or t_now, "token",
                        slot=slot, step=self.n_steps)
        return state, tokens_np

    # -- reporting ----------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Mean occupied-slot fraction across generate steps."""
        return self._occupancy_sum / self.n_steps if self.n_steps else 0.0

    @property
    def tokens_per_s_modeled(self) -> float:
        """Steady-state decode throughput on the machine-model timeline."""
        if self.decode_modeled_s <= 0.0:
            return 0.0
        return self.n_tokens / self.decode_modeled_s

    def roofline(self) -> Optional[EngineRoofline]:
        """Bytes/step roofline of the captured generate graph (None before
        the first step)."""
        if self.decode_graph is None:
            return None
        return engine_roofline(self.decode_graph, self.config)

    def stats(self) -> Dict[str, float]:
        ro = self.roofline()
        return {
            "num_slots": self.num_slots,
            "n_prefills": self.n_prefills,
            "n_inserts": self.n_inserts,
            "n_steps": self.n_steps,
            "n_tokens": self.n_tokens,
            "prefill_modeled_s": self.prefill_modeled_s,
            "decode_modeled_s": self.decode_modeled_s,
            "energy_j": self.energy_j,
            "occupancy": self.occupancy,
            "tokens_per_s_modeled": self.tokens_per_s_modeled,
            "bytes_per_step": ro.bytes_per_step if ro is not None else 0.0,
            "mem_bound_fraction": (ro.mem_bound_fraction
                                   if ro is not None else 0.0),
        }

    def publish_metrics(self, registry) -> None:
        """Snapshot the engine counters into a
        :class:`~repro.obs.MetricsRegistry` (idempotent set-style)."""
        c = registry.counter("repro_engine_events_total",
                             "decode-engine prefills/inserts/steps/tokens")
        c.set_total(self.n_prefills, kind="prefills")
        c.set_total(self.n_inserts, kind="inserts")
        c.set_total(self.n_steps, kind="steps")
        c.set_total(self.n_tokens, kind="tokens")
        registry.gauge("repro_engine_slots",
                       "decode-engine slot width").set(self.num_slots)
        registry.gauge("repro_engine_occupancy",
                       "mean occupied-slot fraction").set(self.occupancy)
        registry.gauge("repro_engine_tokens_per_s_modeled",
                       "modeled steady-state decode throughput").set(
            self.tokens_per_s_modeled)
        ro = self.roofline()
        if ro is not None:
            registry.gauge("repro_engine_bytes_per_step",
                           "modeled traffic of one generate step").set(
                ro.bytes_per_step)
            registry.gauge("repro_engine_mem_bound_fraction",
                           "bandwidth-floor share of the modeled step").set(
                ro.mem_bound_fraction)
