"""Deterministic fault injection for the serving stack (ISSUE 6).

The robustness claim of the front door — bounded queues, bit-identical
retries, lanes that quarantine and recover — is only testable if failures
are *reproducible*.  A :class:`FaultPlan` is a pure function from
``(seed, lane name, launch index)`` to a :class:`FaultDecision`: the same
plan injects the same launch failures, latency spikes and lane blackouts
on every run, independent of dispatch order, Python hash salting or which
worker draws first.  Plans hook into
:meth:`repro.serve.dispatch.QueueWorker._do_launch` via the worker's
``fault_plan`` and fire *before* the real launch, so an injected failure
can never corrupt outputs — a retried micro-batch replays the same pure
cached graph and stays bit-identical to the fault-free path.

Three fault classes (the ISSUE-6 triple):

* **launch failures** — with probability ``p_launch_fail`` a launch raises
  :class:`InjectedFault` instead of running (a flaky lane);
* **latency spikes** — with probability ``p_latency_spike`` the launch
  succeeds but its modeled breakdown gains ``latency_spike_s`` of extra
  scheduling time (a contended lane; outputs untouched, energy untouched —
  a stall burns time, not work);
* **lane blackouts** — a :class:`Blackout` kills *every* launch of one
  lane over a contiguous launch-index window (a dead lane), independent of
  the seed, so recovery tests stay deterministic under the CI matrix leg's
  varying ``REPRO_FAULT_SEED``.

The CI fault leg sets ``REPRO_FAULT_SEED``; tests build their plans with
:func:`env_seed` so every PR exercises the injection machinery under a
fresh seed while local runs stay pinned to the default.
"""

from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.machine import PhaseBreakdown

#: environment variable the CI fault-injection matrix leg sets; tests seed
#: their FaultPlans through :func:`env_seed` so the leg varies the draws
ENV_SEED = "REPRO_FAULT_SEED"


def env_seed(default: int = 0) -> int:
    """The fault seed from ``REPRO_FAULT_SEED``, else ``default``."""
    raw = os.environ.get(ENV_SEED)
    return default if raw in (None, "") else int(raw)


class InjectedFault(RuntimeError):
    """A launch killed by the active :class:`FaultPlan`.

    Raised from the worker's fault gate *before* any real work, so the
    dispatcher can retry the micro-batch on another lane with nothing to
    roll back.  ``retired`` carries any tickets the failing worker retired
    for backpressure before the fault fired — those launches were real and
    their results must still be finalized by the caller.
    """

    def __init__(self, msg: str, lane: Optional[str] = None,
                 launch_idx: Optional[int] = None, reason: str = ""):
        super().__init__(msg)
        self.lane = lane
        self.launch_idx = launch_idx
        self.reason = reason
        self.retired: Tuple = ()


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    """What the plan does to one (lane, launch index) pair."""

    fail: bool = False
    reason: str = ""
    spike_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Blackout:
    """Lane ``lane`` refuses every launch with index in
    ``[start, start + length)`` — a deterministic dead-lane window."""

    lane: str
    start: int
    length: int

    def covers(self, lane: str, launch_idx: int) -> bool:
        return (lane == self.lane
                and self.start <= launch_idx < self.start + self.length)


class FaultPlan:
    """A seeded, deterministic schedule of injected failures.

    ``draw(lane, launch_idx)`` is pure: the decision depends only on
    ``(seed, lane, launch_idx)`` (lane names hashed with CRC-32, never
    Python's salted ``hash``), so two runs of the same traffic see the
    same faults regardless of dispatch interleaving — the property the
    bit-identical-retry tests rely on.  Blackout windows are
    seed-independent by design: a recovery test that kills lane 2 for five
    launches stays meaningful when CI rotates ``REPRO_FAULT_SEED``.
    """

    def __init__(self, seed: int = 0, p_launch_fail: float = 0.0,
                 p_latency_spike: float = 0.0, latency_spike_s: float = 0.0,
                 blackouts: Sequence[Blackout] = ()):
        for name, p in (("p_launch_fail", p_launch_fail),
                        ("p_latency_spike", p_latency_spike)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if latency_spike_s < 0.0:
            raise ValueError(f"latency_spike_s must be >= 0, "
                             f"got {latency_spike_s}")
        self.seed = int(seed)
        self.p_launch_fail = float(p_launch_fail)
        self.p_latency_spike = float(p_latency_spike)
        self.latency_spike_s = float(latency_spike_s)
        self.blackouts = tuple(blackouts)
        # observability counters (shared across every worker on the plan)
        self.injected_failures = 0
        self.injected_spikes = 0

    def publish_metrics(self, registry) -> None:
        """Publish the plan's injection totals into a
        :class:`~repro.obs.MetricsRegistry` (snapshot style, idempotent)."""
        c = registry.counter("repro_fault_injections_total",
                             "faults injected by the active FaultPlan")
        c.set_total(self.injected_failures, kind="failure",
                    seed=str(self.seed))
        c.set_total(self.injected_spikes, kind="spike", seed=str(self.seed))

    def draw(self, lane: str, launch_idx: int) -> FaultDecision:
        """The (deterministic) fate of launch ``launch_idx`` on ``lane``."""
        for b in self.blackouts:
            if b.covers(lane, launch_idx):
                self.injected_failures += 1
                return FaultDecision(
                    fail=True,
                    reason=f"lane blackout over launches "
                           f"[{b.start}, {b.start + b.length})")
        rng = np.random.default_rng(
            (self.seed, zlib.crc32(lane.encode()), launch_idx))
        u_fail, u_spike = rng.random(2)
        if u_fail < self.p_launch_fail:
            self.injected_failures += 1
            return FaultDecision(
                fail=True, reason=f"launch failure (p={self.p_launch_fail})")
        if self.latency_spike_s > 0.0 and u_spike < self.p_latency_spike:
            self.injected_spikes += 1
            return FaultDecision(spike_s=self.latency_spike_s)
        return FaultDecision()


def apply_spike(fused: Optional[PhaseBreakdown],
                spike_s: float) -> Optional[PhaseBreakdown]:
    """Fold an injected latency spike into a modeled breakdown.

    The spike is a scheduler stall: extra *scheduling* cycles at the
    chain's clock, no extra work — so modeled time (and hence deadline
    checks and latency percentiles) grow while modeled energy, which is
    total-work, stays put.
    """
    if fused is None or spike_s <= 0.0:
        return fused
    return dataclasses.replace(
        fused, scheduling=fused.scheduling + spike_s * fused.freq_hz)
