"""Asyncio streaming HTTP ingress for the decode engine (ISSUE 9).

A dependency-free front door over :meth:`Server.submit_decode` /
:meth:`Server.stream` — stdlib ``asyncio`` only, no web framework:

- ``POST /generate`` with a JSON body ``{"prompt": [int, ...],
  "max_new": N}`` answers ``200`` with ``Transfer-Encoding: chunked`` and
  streams ONE token id per line, flushed per generate step — a client
  reads tokens while later steps are still running, and a slow client on
  one connection never blocks another request's stream (per-rid queues).
- ``GET /healthz`` answers a one-line JSON status.

Requests shed by admission control answer ``503`` (loud, like
:class:`~repro.serve.server.AdmissionError` everywhere else); malformed
bodies answer ``400``.

Concurrency model: the engine is synchronous and single-state, so ALL
engine work (submit + token pulls) funnels through a single-thread
executor — HTTP concurrency lives in the event loop, engine steps stay
strictly serialized.  Pulling tokens for one connection advances every
occupied slot (that is continuous batching), so concurrent streams make
each other progress instead of queueing behind one another.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import json
from typing import Any, Dict, Optional, Tuple

from .server import AdmissionError, Server

_MAX_HEADER_BYTES = 64 * 1024
_MAX_BODY_BYTES = 4 * 1024 * 1024
_SENTINEL = object()


class EngineHTTPServer:
    """A tiny asyncio HTTP/1.1 server streaming engine tokens.

    ::

        front = EngineHTTPServer(server)        # server has an engine
        host, port = await front.start()        # port=0 picks a free one
        ...
        await front.stop()
    """

    def __init__(self, server: Server, host: str = "127.0.0.1",
                 port: int = 0):
        if server.engine is None:
            raise ValueError(
                "EngineHTTPServer fronts the decode engine: construct the "
                "Server with engine=DecodeEngine(...)")
        self.server = server
        self.host = host
        self.port = port
        self._srv: Optional[asyncio.AbstractServer] = None
        # ONE thread: every submit_decode / stream pull serializes here,
        # so the engine's persistent state never sees concurrent mutation
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine-http")

    async def start(self) -> Tuple[str, int]:
        self._srv = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._srv.sockets[0].getsockname()[1]
        return self.host, self.port

    async def stop(self) -> None:
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
            self._srv = None
        self._pool.shutdown(wait=True)

    # -- request plumbing ---------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            writer.close()
            return
        if len(head) > _MAX_HEADER_BYTES:
            await self._respond(writer, 431, {"error": "headers too large"})
            return
        try:
            request_line, headers = self._parse_head(head)
            method, path = request_line
            length = int(headers.get("content-length", "0"))
            if length > _MAX_BODY_BYTES:
                await self._respond(writer, 413, {"error": "body too large"})
                return
            body = await reader.readexactly(length) if length else b""
        except (ValueError, asyncio.IncompleteReadError) as e:
            await self._respond(writer, 400, {"error": f"bad request: {e}"})
            return
        try:
            if method == "POST" and path == "/generate":
                await self._generate(writer, body)
            elif method == "GET" and path == "/healthz":
                eng = self.server.engine
                await self._respond(writer, 200, {
                    "status": "ok", "slots": eng.num_slots,
                    "steps": eng.n_steps, "tokens": eng.n_tokens})
            else:
                await self._respond(writer, 404,
                                    {"error": f"no route {method} {path}"})
        except ConnectionError:
            pass                               # client went away mid-stream
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    @staticmethod
    def _parse_head(head: bytes) -> Tuple[Tuple[str, str], Dict[str, str]]:
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split()
        if len(parts) != 3:
            raise ValueError(f"malformed request line {lines[0]!r}")
        headers = {}
        for line in lines[1:]:
            if not line:
                continue
            name, _, value = line.partition(":")
            headers[name.strip().lower()] = value.strip()
        return (parts[0].upper(), parts[1]), headers

    @staticmethod
    async def _respond(writer: asyncio.StreamWriter, status: int,
                       payload: Dict[str, Any]) -> None:
        body = (json.dumps(payload) + "\n").encode()
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  413: "Payload Too Large", 431: "Headers Too Large",
                  503: "Service Unavailable"}.get(status, "Error")
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()

    # -- the streaming route ------------------------------------------------
    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            req = json.loads(body.decode() or "{}")
            prompt = req["prompt"]
            max_new = int(req.get("max_new", 16))
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) for t in prompt)):
                raise ValueError("prompt must be a non-empty list of ints")
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            await self._respond(writer, 400, {"error": f"bad request: {e}"})
            return
        loop = asyncio.get_running_loop()
        try:
            rid = await loop.run_in_executor(
                self._pool, lambda: self.server.submit_decode(
                    prompt, max_new=max_new))
        except AdmissionError as e:
            await self._respond(writer, 503, {"error": str(e)})
            return
        except ValueError as e:
            await self._respond(writer, 400, {"error": str(e)})
            return
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/plain; charset=utf-8\r\n"
            b"Transfer-Encoding: chunked\r\n"
            b"Connection: close\r\n"
            + f"X-Request-Id: {rid}\r\n\r\n".encode())
        await writer.drain()
        stream = self.server.stream(rid)

        def _pull():
            try:
                return next(stream)
            except StopIteration:
                return _SENTINEL

        try:
            while True:
                tok = await loop.run_in_executor(self._pool, _pull)
                if tok is _SENTINEL:
                    break
                chunk = f"{tok}\n".encode()
                writer.write(f"{len(chunk):X}\r\n".encode() + chunk + b"\r\n")
                await writer.drain()
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        except AdmissionError:
            # rid shed mid-stream (fault): the truncated chunked body is
            # the loud signal — no terminal chunk is ever written
            pass
