"""Power budgets for the serving fleet — power as a scheduling input.

The paper's headline is performance *inside an envelope*: 15.1x speed-up and
3.1x energy reduction within a <= 28 mW power budget (abstract, §VII-VIII),
with a power controller that clock-gates idle CUs (§IV).  This module makes
that envelope a first-class dispatch constraint (ISSUE 8): a
:class:`PowerBudget` caps the modeled **window-average power** of every lane
and of the whole fleet, the
:class:`~repro.serve.dispatch.MultiQueueDispatcher` prices each candidate
lane (modeled latency, average power over the launch window) before routing,
prefers the best requests-per-joule among budget-eligible lanes, and a batch
no lane can carry under budget is shed *loudly* through the existing
:class:`~repro.serve.server.AdmissionError` machinery — never silently
queued into a thermal lie.

Everything is priced on the machine model, never wall clock: a lane's window
for a candidate launch is ``backlog + service`` on its modeled timeline, its
average power is ``(remaining in-flight energy + launch energy) / window``,
and idle lanes draw their clock-gated leakage floor
(:func:`repro.core.power.egpu_idle_power_mw`).  Budgets therefore compose
with DVFS operating points: re-basing a lane's config via
``config.at(point)`` changes both its modeled time and its modeled power, and
the dispatcher re-prices automatically.

Worked example — a 28 mW fleet (the paper's envelope):

    >>> from repro.core import EGPU_16T, EGPU_8T
    >>> from repro.core.power import egpu_active_power_mw
    >>> from repro.serve import PowerBudget, Server
    >>> round(egpu_active_power_mw(EGPU_16T), 1)   # ~27 mW flat out
    27.1
    >>> budget = PowerBudget(lane_mw=28.0, fleet_mw=28.0)
    >>> srv = Server(stages, workers=(EGPU_16T, EGPU_8T),
    ...              power_budget=budget, clock=vclock)    # doctest: +SKIP

    With ``fleet_mw=28.0`` the two lanes *together* may never model more
    than 28 mW over their launch windows: the dispatcher fills the 16T lane
    (best requests-per-joule) until its window draw plus the 8T lane's
    leakage floor approaches the cap, throttles the second lane rather than
    exceed it, and sheds — with an :class:`AdmissionError` naming the budget
    — once no lane has headroom.  ``ServeReport`` then shows
    ``avg_fleet_power_w <= 0.028`` with zero ``n_budget_violations``: the
    envelope held by construction, not by luck.

Enforcement invariant (pinned by a hypothesis sweep in
``tests/test_power_serve.py``): **no accepted request ever executes on a
lane whose window-average power exceeds its budget** — every
:meth:`~repro.serve.dispatch.QueueWorker.launch` re-audits the window price
it actually booked, and the audit counter must stay 0.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PowerBudget:
    """Power caps for the serving fleet, in milliwatts (the paper's unit).

    ``lane_mw`` bounds each lane's window-average power per launch;
    ``fleet_mw`` bounds the modeled instantaneous draw summed across all
    lanes (busy lanes at their window-average, idle lanes at their
    clock-gated leakage floor).  ``None`` leaves a dimension uncapped.
    """

    lane_mw: Optional[float] = None
    fleet_mw: Optional[float] = None

    def __post_init__(self) -> None:
        for field in ("lane_mw", "fleet_mw"):
            v = getattr(self, field)
            if v is not None and v <= 0.0:
                raise ValueError(f"{field} must be positive, got {v}")
        if self.lane_mw is None and self.fleet_mw is None:
            raise ValueError(
                "PowerBudget needs at least one of lane_mw / fleet_mw")

    @property
    def lane_w(self) -> Optional[float]:
        return None if self.lane_mw is None else self.lane_mw * 1e-3

    @property
    def fleet_w(self) -> Optional[float]:
        return None if self.fleet_mw is None else self.fleet_mw * 1e-3

    def lane_ok(self, avg_power_w: float) -> bool:
        """Is a lane window-average draw within the per-lane cap?"""
        return self.lane_w is None or avg_power_w <= self.lane_w

    def fleet_ok(self, fleet_power_w: float) -> bool:
        """Is a modeled fleet draw within the fleet-wide cap?"""
        return self.fleet_w is None or fleet_power_w <= self.fleet_w


@dataclasses.dataclass(frozen=True)
class LanePrice:
    """One candidate lane's price for one micro-batch launch.

    The dispatcher's routing currency (ISSUE 8): ``window_s`` is modeled
    latency including the lane's backlog, ``avg_power_w`` the window-average
    draw the launch would commit the lane to, and ``requests_per_joule`` the
    efficiency score budget-eligible lanes compete on.
    """

    lane: str
    #: modeled service time of the candidate launch alone (fused chain)
    modeled_s: float
    #: backlog + service on the lane's modeled timeline — what the batch
    #: would actually wait+run for
    window_s: float
    #: (remaining in-flight energy + launch energy) / window
    avg_power_w: float
    #: active energy of the candidate launch
    energy_j: float
    #: live requests per joule of total window energy — higher is better
    requests_per_joule: float
