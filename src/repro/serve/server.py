"""Server — the long-lived serving engine over APU pipelines.

Ties the subsystem together: requests enter :meth:`Server.submit`, the
:class:`~repro.serve.batching.BucketBatcher` pads them to shape buckets and
coalesces full micro-batches, the
:class:`~repro.serve.cache.GraphCache` supplies (or captures, once per
bucket x worker) the batched :class:`CommandGraph`, and the
:class:`~repro.serve.dispatch.MultiQueueDispatcher` load-balances launches
across the configured e-GPU queues under an in-flight bound.  A warm server
on steady-state traffic therefore performs **zero** re-captures / re-jits:
every launch is a cached-graph replay, paying Tiny-OpenCL startup +
scheduling once per micro-batch (paper §IV-B residency, scaled out).

The open-loop front door (ISSUE 6) makes the engine survivable, not just
fast:

* **SLO intake** — ``submit(..., deadline=budget_s, priority=...)`` runs
  modeled-capacity admission control: the predicted completion (per-lane
  ``modeled_s_per_request()`` x queue depth, plus the lane's modeled
  backlog) is checked against the deadline budget, and infeasible or
  queue-full requests are shed with a loud :class:`AdmissionError` instead
  of queueing unboundedly.  ``max_pending`` bounds the staged queue; a
  higher-priority request may preempt a lower-priority pending one rather
  than be shed itself.
* **Deadline-aware flushing** — every submit (and the explicit
  :meth:`tick`) pumps :meth:`BucketBatcher.tick`, launching partial
  buckets whose oldest request's budget is at risk, so a lonely
  deadline-carrying request is not held hostage waiting for its bucket to
  fill.
* **Fault-tolerant dispatch** — launches route through
  :meth:`MultiQueueDispatcher.dispatch`: injected/lane failures retry on a
  different lane with capped backoff, repeat offenders are quarantined
  behind circuit breakers, and a batch that exhausts every retry is shed
  loudly (``result()`` on its requests raises :class:`AdmissionError`
  naming the reason — no request is ever silently lost).

:meth:`Server.report` rolls the per-queue machine-model accounting into a
:class:`ServeReport`: measured requests/s, modeled per-request latency
percentiles (each request experiences its batch's fused-chain latency),
modeled energy per request, and the robustness counters — goodput
(in-deadline completions/s, measured and modeled), sheds, deadline
violations, retries and quarantines.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import (TYPE_CHECKING, Any, Callable, Deque, Dict, Iterator,
                    Optional, Sequence, Tuple, Union)

if TYPE_CHECKING:                      # annotation only — no runtime import
    from .engine import DecodeEngine   # (keeps the model stack off pipeline-
                                       # only servers' import path)

import jax.numpy as jnp
import numpy as np

from ..core.apu import Stage
from ..core.device import EGPUConfig, EGPU_16T, OP_ANCHOR, env_op_point
from ..obs import MetricsRegistry, Tracer
from .batching import BucketBatcher, MicroBatch, batched_stages
from .cache import GraphCache, stages_signature
from .dispatch import (DispatchError, LaunchTicket, MultiQueueDispatcher,
                       PowerBudgetError, QueueStats, QueueWorker)
from .faults import FaultPlan
from .power import PowerBudget

PERCENTILES = (50, 90, 99)

#: per-request latency decomposition phases (ISSUE 7 flame attribution):
#: ``admission`` (the modeled admission decision — instantaneous today,
#: the column keeps the decomposition summing to end-to-end latency),
#: ``queueing`` (bucket wait: submit -> launch), ``dispatch`` (lane
#: backlog wait + the per-chain Tiny-OpenCL startup+scheduling overhead —
#: the paper's §VII overhead split), ``compute`` and ``transfer`` (the
#: fused chain's kernel and host<->D$ phases)
DECOMP_PHASES = ("admission", "queueing", "dispatch", "compute", "transfer")
DECOMP_PERCENTILES = (50, 99)


class AdmissionError(RuntimeError):
    """A request shed by admission control (or fault-exhausted dispatch).

    Raised from :meth:`Server.submit` when a request is rejected at the
    door, and from :meth:`Server.result` when an *accepted* request was
    shed later (priority preemption, dispatch exhaustion) — shedding is
    always loud, never a silent drop.
    """


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregate serving metrics (measured throughput, modeled cost)."""

    n_requests: int
    n_batches: int
    wall_s: float
    requests_per_s: float
    #: modeled request latency percentiles, seconds (p50/p90/p99); a request
    #: experiences the fused-chain latency of the micro-batch carrying it
    modeled_latency_s: Dict[int, float]
    #: mean amortized cost per request (batch fused time / live requests) —
    #: the throughput view of the same launches
    modeled_cost_per_request_s: float
    modeled_energy_per_request_j: float
    avg_batch_fill: float              # live requests / batch capacity
    padded_elements: int               # elements added purely by padding
    queues: Tuple[QueueStats, ...]
    cache: Dict[str, int]
    #: mean per-launch utilization of each mesh axis across the sharded
    #: lanes (batch-weighted); empty when no worker owns a mesh
    mesh_utilization: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    #: completed results dropped by the bounded LRU store (not fetched or
    #: ``keep``-refreshed within the last ``metrics_window`` completions)
    results_evicted: int = 0
    # -- robustness counters (ISSUE 6) --------------------------------------
    #: requests shed: admission rejects + priority preemptions + batches
    #: that exhausted every dispatch retry
    n_shed: int = 0
    #: completed requests whose modeled completion missed their deadline
    n_deadline_violations: int = 0
    #: in-deadline completions per measured wall second (requests without a
    #: deadline count as in-deadline)
    goodput_per_s: float = 0.0
    #: in-deadline completions per *modeled* second (machine-model
    #: makespan) — deterministic, the overload benchmark's gated number
    goodput_per_s_modeled: float = 0.0
    #: partial buckets launched because a deadline budget was at risk
    deadline_flushes: int = 0
    #: failed launch attempts rerouted to another lane
    n_retries: int = 0
    #: micro-batches that exhausted every dispatch retry (then shed)
    n_dispatch_failures: int = 0
    #: circuit-breaker trips across the fleet (lane quarantines)
    n_quarantines: int = 0
    #: per-request flame attribution (ISSUE 7): phase -> {percentile ->
    #: seconds}, decomposing modeled end-to-end latency into
    #: admission/queueing/dispatch/compute/transfer (see
    #: :data:`DECOMP_PHASES`); empty before any profiled completion
    latency_decomposition_s: Dict[str, Dict[int, float]] = \
        dataclasses.field(default_factory=dict)
    # -- power & energy accounting (ISSUE 8) --------------------------------
    #: modeled average fleet power over the serving makespan:
    #: ``fleet_energy_j / makespan``; 0.0 before any modeled launch
    avg_fleet_power_w: float = 0.0
    #: peak modeled instantaneous fleet draw, sampled at every budgeted
    #: launch (0.0 when serving uncapped — nothing samples it)
    peak_fleet_power_w: float = 0.0
    #: idle-lane leakage integrated over the modeled makespan — each lane
    #: burns its clock-gated floor (§IV SLEEP_REQ) whenever it is not
    #: serving, energy the active-only ledger used to omit
    fleet_idle_energy_j: float = 0.0
    #: honest fleet energy: active launch energy + idle-lane leakage
    fleet_energy_j: float = 0.0
    #: completed requests per modeled second per watt of modeled fleet
    #: draw — algebraically, requests per joule of ``fleet_energy_j``
    requests_per_s_per_watt: float = 0.0
    #: in-deadline completions per modeled second per watt — the
    #: ``bench=power`` gate's goodput-per-watt number
    goodput_per_s_per_watt: float = 0.0
    #: requests shed because no lane could take them on-budget
    n_power_shed: int = 0
    #: candidate lanes skipped during routing for a budget breach
    n_power_throttled: int = 0
    #: launches whose booked window-average power broke the lane cap —
    #: MUST stay 0 while the dispatcher enforces the budget (hypothesis-
    #: swept in tests/test_power_serve.py)
    n_budget_violations: int = 0
    #: the configured caps (mW), ``None`` when serving uncapped
    power_budget_lane_mw: Optional[float] = None
    power_budget_fleet_mw: Optional[float] = None
    # -- continuous-batching decode engine (ISSUE 9) ------------------------
    #: generate steps launched (each ONE cached-graph launch over all slots)
    engine_steps: int = 0
    #: tokens emitted from occupied slots across those steps
    engine_tokens: int = 0
    #: modeled time split: prompt passes vs autoregressive generate steps
    engine_prefill_s_modeled: float = 0.0
    engine_decode_s_modeled: float = 0.0
    #: steady-state decode throughput, tokens per modeled second
    engine_tokens_per_s_modeled: float = 0.0
    #: mean occupied-slot fraction across generate steps
    engine_slot_occupancy: float = 0.0
    #: modeled traffic of ONE captured generate step (bytes, summed off the
    #: captured schedule's per-node WorkCounts — the roofline numerator)
    engine_bytes_per_step: float = 0.0
    #: share of the modeled step the D$-bandwidth floor explains
    engine_mem_bound_fraction: float = 0.0
    # -- capture-time graph sanitizer (ISSUE 10, repro.analyze) -------------
    #: fresh captures statically verified at GraphCache miss time (a warm
    #: server replays verified graphs and never re-verifies)
    graphs_verified: int = 0
    #: sanitizer findings across those verifications — MUST stay 0: every
    #: finding is a capture-discipline bug (loud under REPRO_VERIFY=1)
    sanitizer_findings: int = 0

    def publish_metrics(self, registry: MetricsRegistry) -> MetricsRegistry:
        """Publish this report (and its per-queue / cache roll-ups) into a
        :class:`~repro.obs.MetricsRegistry` — snapshot style, idempotent.
        """
        c = registry.counter
        g = registry.gauge
        c("repro_serve_requests_total",
          "completed requests").set_total(self.n_requests)
        c("repro_serve_batches_total",
          "launched micro-batches").set_total(self.n_batches)
        c("repro_serve_shed_total",
          "requests shed (door rejects + preemptions + dispatch "
          "exhaustion)").set_total(self.n_shed)
        c("repro_serve_deadline_violations_total",
          "completions past their deadline").set_total(
            self.n_deadline_violations)
        c("repro_serve_deadline_flushes_total",
          "partial buckets launched for a deadline").set_total(
            self.deadline_flushes)
        c("repro_serve_retries_total",
          "failed launch attempts rerouted").set_total(self.n_retries)
        c("repro_serve_dispatch_failures_total",
          "micro-batches that exhausted every retry").set_total(
            self.n_dispatch_failures)
        c("repro_serve_quarantines_total",
          "circuit-breaker trips").set_total(self.n_quarantines)
        c("repro_serve_results_evicted_total",
          "unread results evicted by the bounded store").set_total(
            self.results_evicted)
        g("repro_serve_requests_per_second",
          "measured request throughput").set(self.requests_per_s)
        g("repro_serve_goodput_per_second_modeled",
          "in-deadline completions per modeled second").set(
            self.goodput_per_s_modeled)
        g("repro_serve_batch_fill_ratio",
          "live requests / batch capacity").set(self.avg_batch_fill)
        g("repro_serve_energy_per_request_joules",
          "modeled energy per request").set(
            self.modeled_energy_per_request_j)
        # power telemetry (ISSUE 8)
        g("repro_fleet_avg_power_watts",
          "modeled average fleet power over the makespan").set(
            self.avg_fleet_power_w)
        g("repro_fleet_peak_power_watts",
          "peak modeled instantaneous fleet draw").set(
            self.peak_fleet_power_w)
        g("repro_fleet_energy_joules",
          "fleet energy incl. idle leakage").set(self.fleet_energy_j)
        g("repro_fleet_idle_energy_joules",
          "idle-lane leakage over the makespan").set(
            self.fleet_idle_energy_j)
        g("repro_serve_requests_per_second_per_watt",
          "completed requests per modeled second per watt").set(
            self.requests_per_s_per_watt)
        g("repro_serve_goodput_per_second_per_watt",
          "in-deadline completions per modeled second per watt").set(
            self.goodput_per_s_per_watt)
        c("repro_serve_power_shed_total",
          "requests shed because no lane had power headroom").set_total(
            self.n_power_shed)
        c("repro_serve_power_throttled_total",
          "lane candidates skipped for a budget breach").set_total(
            self.n_power_throttled)
        c("repro_serve_budget_violations_total",
          "launches booked over the lane power cap").set_total(
            self.n_budget_violations)
        lat = g("repro_serve_modeled_latency_seconds",
                "modeled request latency percentiles")
        for p, v in self.modeled_latency_s.items():
            lat.set(v, quantile=f"p{p}")
        flame = g("repro_serve_latency_phase_seconds",
                  "per-request flame attribution (modeled)")
        for phase, pcts in self.latency_decomposition_s.items():
            for p, v in pcts.items():
                flame.set(v, phase=phase, quantile=f"p{p}")
        # decode-engine telemetry (ISSUE 9): only published once the engine
        # actually stepped, so pipeline-only servers add no empty series
        if self.engine_steps:
            ec = c("repro_engine_events_total",
                   "decode-engine prefills/inserts/steps/tokens")
            ec.set_total(self.engine_steps, kind="steps")
            ec.set_total(self.engine_tokens, kind="tokens")
            g("repro_engine_occupancy",
              "mean occupied-slot fraction").set(self.engine_slot_occupancy)
            g("repro_engine_tokens_per_s_modeled",
              "modeled steady-state decode throughput").set(
                self.engine_tokens_per_s_modeled)
            g("repro_engine_bytes_per_step",
              "modeled traffic of one generate step").set(
                self.engine_bytes_per_step)
            g("repro_engine_mem_bound_fraction",
              "bandwidth-floor share of the modeled step").set(
                self.engine_mem_bound_fraction)
        # same series GraphCache.publish_metrics writes — set_total is
        # idempotent, so publishing a report over a live cache never skews
        cache = registry.counter("repro_graph_cache_events_total",
                                 "graph cache hits/misses/evictions")
        for kind in ("hits", "misses", "evictions"):
            cache.set_total(self.cache[kind], kind=kind)
        g("repro_graph_cache_entries",
          "resident compiled graphs").set(self.cache["entries"])
        san = registry.counter("repro_graph_sanitizer_total",
                               "capture-time graph sanitizer results")
        san.set_total(self.graphs_verified, kind="verified")
        san.set_total(self.sanitizer_findings, kind="findings")
        for qs in self.queues:
            qs.publish_metrics(registry)
        return registry

    def summary(self) -> str:
        lines = [
            f"requests        {self.n_requests} in {self.n_batches} batches "
            f"(fill {self.avg_batch_fill:.0%}, "
            f"{self.padded_elements} padded elements)",
            f"throughput      {self.requests_per_s:,.0f} req/s measured "
            f"({self.wall_s * 1e3:.1f} ms wall)",
            "modeled latency " + "  ".join(
                f"p{p} {self.modeled_latency_s[p] * 1e3:.3f} ms"
                for p in sorted(self.modeled_latency_s)),
            f"modeled cost    {self.modeled_cost_per_request_s * 1e3:.3f} "
            f"ms/request amortized, "
            f"{self.modeled_energy_per_request_j * 1e6:.2f} uJ/request",
            f"graph cache     {self.cache['hits']} hits / "
            f"{self.cache['misses']} misses / "
            f"{self.cache['evictions']} evictions "
            f"({self.cache['entries']}/{self.cache['capacity']} resident)",
        ]
        if self.graphs_verified:
            lines.append(
                f"sanitizer       {self.graphs_verified} captures verified, "
                f"{self.sanitizer_findings} findings")
        for p in sorted({p for pcts in self.latency_decomposition_s.values()
                         for p in pcts}):
            lines.append(f"flame p{p:<2d}      " + "  ".join(
                f"{phase} {self.latency_decomposition_s[phase][p] * 1e3:.3f}"
                for phase in DECOMP_PHASES
                if phase in self.latency_decomposition_s) + " ms")
        if self.engine_steps:
            lines.append(
                f"engine          {self.engine_tokens} tokens in "
                f"{self.engine_steps} steps "
                f"(occupancy {self.engine_slot_occupancy:.0%})  "
                f"{self.engine_tokens_per_s_modeled:,.0f} tok/s modeled  "
                f"prefill {self.engine_prefill_s_modeled * 1e3:.3f} ms / "
                f"decode {self.engine_decode_s_modeled * 1e3:.3f} ms  "
                f"{self.engine_bytes_per_step:,.0f} B/step "
                f"({self.engine_mem_bound_fraction:.0%} mem-bound)")
        if (self.n_shed or self.n_deadline_violations
                or self.deadline_flushes):
            lines.append(
                f"slo             goodput {self.goodput_per_s_modeled:,.0f} "
                f"req/s modeled ({self.goodput_per_s:,.0f} measured)  "
                f"{self.n_shed} shed  "
                f"{self.n_deadline_violations} deadline misses  "
                f"{self.deadline_flushes} deadline flushes")
        if self.fleet_energy_j > 0.0:
            budget = ""
            if (self.power_budget_lane_mw is not None
                    or self.power_budget_fleet_mw is not None):
                caps = [f"lane<={self.power_budget_lane_mw:g} mW"
                        if self.power_budget_lane_mw is not None else "",
                        f"fleet<={self.power_budget_fleet_mw:g} mW"
                        if self.power_budget_fleet_mw is not None else ""]
                budget = "  budget " + " ".join(cp for cp in caps if cp)
            lines.append(
                f"power           avg {self.avg_fleet_power_w * 1e3:.2f} mW "
                f"(peak {self.peak_fleet_power_w * 1e3:.2f} mW)  "
                f"energy {self.fleet_energy_j * 1e6:.1f} uJ "
                f"(idle {self.fleet_idle_energy_j * 1e6:.1f} uJ)  "
                f"goodput/W {self.goodput_per_s_per_watt:,.0f}" + budget)
        if (self.n_power_shed or self.n_power_throttled
                or self.n_budget_violations):
            lines.append(
                f"power events    {self.n_power_shed} power sheds  "
                f"{self.n_power_throttled} throttles  "
                f"{self.n_budget_violations} budget violations")
        if (self.n_retries or self.n_quarantines
                or self.n_dispatch_failures):
            lines.append(
                f"faults          {self.n_retries} retries  "
                f"{self.n_quarantines} quarantines  "
                f"{self.n_dispatch_failures} dispatch failures")
        if self.mesh_utilization:
            lines.append("mesh util       " + "  ".join(
                f"{axis} {util:.0%}"
                for axis, util in sorted(self.mesh_utilization.items())))
        if self.results_evicted:
            lines.append(f"results         {self.results_evicted} unread "
                         "results evicted (bounded LRU store)")
        for qs in self.queues:
            mesh = ("" if not qs.mesh_axes else "  mesh " + "x".join(
                f"{a}={s}" for a, s in qs.mesh_axes))
            breaker = ("" if qs.breaker_state == "closed"
                       and not qs.launch_failures else
                       f"  faults {qs.launch_failures} "
                       f"(breaker {qs.breaker_state})")
            lines.append(
                f"  queue {qs.name:12s} {qs.batches:4d} batches "
                f"{qs.requests:5d} reqs  modeled {qs.modeled_s * 1e3:8.2f} ms "
                f"{qs.energy_j * 1e6:8.1f} uJ  peak in-flight "
                f"{qs.peak_in_flight} ({qs.backpressure_stalls} stalls)"
                + mesh + breaker)
        return "\n".join(lines)


class Server:
    """A long-lived serving engine for one APU pipeline.

    ``stages`` carry *per-request* semantics (exactly what
    :meth:`APU.offload` takes); the server lifts them over the batch axis
    internally.  ``workers`` are the lanes to dispatch across: each entry
    is either an :class:`EGPUConfig` preset (wrapped into a
    :class:`QueueWorker`) or a pre-built worker instance — in particular a
    :class:`~repro.serve.sharded.ShardedWorker` spanning a device-mesh
    slice.  Heterogeneous mixes are fine, each lane gets its own cached
    graphs.

    Robustness knobs (ISSUE 6):

    * ``max_pending`` — bound on staged (pre-launch) requests; beyond it
      submits shed (or preempt a lower-priority pending request).
      ``None`` keeps the historical unbounded-queue behavior.
    * ``admission`` / ``deadline_flush`` — disable the SLO machinery for
      A/B baselines (the overload benchmark's no-shed FIFO arm).
    * ``fault_plan`` — a :class:`~repro.serve.faults.FaultPlan` installed
      on every lane the server constructs (pre-built workers keep their
      own unless they have none).
    * ``clock`` — time source for the whole engine (workers included);
      the overload benchmark injects a virtual clock to make the entire
      serving timeline machine-model-deterministic.

    Pipeline contract: kernels must be pad-stable along axis 0 of each
    request array (see :mod:`repro.serve.batching`).
    """

    def __init__(self, stages: Sequence[Stage],
                 workers: Sequence[Union[EGPUConfig, QueueWorker]]
                 = (EGPU_16T,),
                 bucket_sizes: Sequence[int] = (64, 256, 1024),
                 max_batch: int = 4, max_in_flight: int = 2,
                 cache_capacity: int = 32, fill: float | int = 0,
                 crop_outputs: bool = True,
                 metrics_window: int = 100_000,
                 max_pending: Optional[int] = None,
                 admission: bool = True, deadline_flush: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 breaker_threshold: int = 3, breaker_cooldown: int = 8,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer: Optional[Tracer] = None,
                 power_budget: Optional[PowerBudget] = None,
                 engine: Optional["DecodeEngine"] = None):
        self.stages = tuple(stages)
        self.clock = clock
        self.max_pending = max_pending
        self.admission = admission
        self.deadline_flush = deadline_flush
        #: power envelope (ISSUE 8): when set, the dispatcher prices every
        #: candidate lane and routes for requests-per-joule under the caps
        self.power_budget = power_budget
        self._n_power_shed = 0
        #: opt-in span tracer (ISSUE 7), installed on the dispatcher and
        #: every lane; ``None`` (the default) keeps the hot dispatch path
        #: free of any obs allocation — every hook guards on it
        self.tracer = tracer
        self.batcher = BucketBatcher(bucket_sizes, max_batch=max_batch,
                                     fill=fill, crop_outputs=crop_outputs)
        # REPRO_OP_POINT (ISSUE 8): rebase anchor-point config presets onto
        # the environment's DVFS operating point — outputs must stay
        # bit-identical across op points (CI re-runs the serve suite under
        # it), only modeled time/power move.  Pre-built workers and configs
        # already rebased via ``config.at(point)`` keep their chosen point.
        point = env_op_point()
        lanes = []
        for i, w in enumerate(workers):
            if isinstance(w, QueueWorker):
                if fault_plan is not None and w.fault_plan is None:
                    w.fault_plan = fault_plan
                if clock is not time.perf_counter:
                    w.clock = clock
                if tracer is not None and w.tracer is None:
                    w.tracer = tracer
                lanes.append(w)
            else:
                cfg = (w.at(point) if point is not None
                       and w.operating_point is OP_ANCHOR else w)
                lanes.append(QueueWorker(
                    cfg, name=f"{i}:{w.name}", max_in_flight=max_in_flight,
                    fault_plan=fault_plan, clock=clock, tracer=tracer))
        if not lanes and engine is not None:
            # engine-only server: the engine's lane doubles as the (unused)
            # dispatch lane, so accounting has a single source of truth
            lanes = [engine.worker]
        self.dispatcher = MultiQueueDispatcher(
            lanes, failure_threshold=breaker_threshold,
            breaker_cooldown=breaker_cooldown, tracer=tracer,
            budget=power_budget)
        self.cache = GraphCache(cache_capacity)
        # Every micro-batch is padded to max_batch, so ONE batched pipeline
        # covers all traffic; its (const-hashing) signature is computed once
        # here, never on the hot path.
        self._bstages = batched_stages(self.stages, max_batch)
        self._bsig = stages_signature(self._bstages)
        # Completed results in LRU order (completion order, refreshed by
        # keep=True reads).  Bounded to the metrics window: results nobody
        # fetched (or keep-refreshed) within the last `metrics_window`
        # completions are EVICTED, so a long-lived server with
        # fire-and-forget clients keeps O(window) memory instead of
        # leaking every unread output forever.
        self._results: "OrderedDict[int, Tuple[Any, ...]]" = OrderedDict()
        self._results_window = max(1, int(metrics_window))
        self._results_evicted = 0
        self._evicted_upto = -1          # highest rid ever evicted unread
        # Accepted-then-shed requests (priority preemption, dispatch
        # exhaustion): rid -> reason.  Bounded like the results store so a
        # long-lived overloaded server stays O(window); result() raises a
        # loud AdmissionError for these.
        self._shed: "OrderedDict[int, str]" = OrderedDict()
        self.n_shed = 0                  # all sheds, incl. door rejects
        # Bounded metric windows: percentiles/means in report() describe the
        # last `metrics_window` requests, so a long-lived server's metric
        # memory is O(window), matching the O(in-flight) queue contract.
        self._modeled_latency: Deque[float] = deque(maxlen=metrics_window)
        self._modeled_cost: Deque[float] = deque(maxlen=metrics_window)
        self._modeled_energy: Deque[float] = deque(maxlen=metrics_window)
        self._n_done = 0
        self._n_in_deadline = 0
        self._n_deadline_violations = 0
        # Per-request flame attribution (ISSUE 7): modeled end-to-end
        # latency split into DECOMP_PHASES, windowed like the other
        # metrics.  Computed from timestamps the serve path already
        # carries (no tracer required).
        self._decomp: Dict[str, Deque[float]] = {
            phase: deque(maxlen=metrics_window) for phase in DECOMP_PHASES}
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self._t_last_modeled: Optional[float] = None
        # -- continuous-batching decode engine (ISSUE 9) --------------------
        #: slot-based decode engine behind :meth:`submit_decode` /
        #: :meth:`stream`; ``None`` keeps the server pipeline-only.  The
        #: engine adopts the server's clock and tracer so both fronts share
        #: one timeline and one trace.
        self.engine = engine
        if engine is not None:
            if clock is not time.perf_counter:
                engine.clock = clock
                engine.worker.clock = clock
            if tracer is not None:
                if engine.tracer is None:
                    engine.tracer = tracer
                if engine.worker.tracer is None:
                    engine.worker.tracer = tracer
        self._estate = None                  # DecodeState, built on demand
        #: accepted but not yet slotted: rid -> (prompt, max_new, deadline_s)
        self._eng_waiting: "OrderedDict[int, Tuple[Any, int, Optional[float]]]" = OrderedDict()
        #: slotted and generating: rid -> record dict (slot, remaining, ...)
        self._eng_active: Dict[int, Dict[str, Any]] = {}
        #: per-rid token queues not yet consumed by :meth:`stream` (LRU-
        #: bounded to the metrics window like the results store, so
        #: fire-and-forget clients can't leak token buffers forever)
        self._eng_streams: "OrderedDict[int, Deque[int]]" = OrderedDict()

    # -- warm-up ------------------------------------------------------------
    def warmup(self, *example_arrays: Any) -> int:
        """Pre-capture the batched graph for every (bucket, worker) pair.

        ``example_arrays`` is one representative request (its trailing dims
        and dtypes define the bucket shapes; values are irrelevant — capture
        traces abstractly).  After ``warmup`` a server sees zero re-captures
        on any traffic that fits the configured buckets.  Returns the number
        of graphs captured.
        """
        arrs = tuple(jnp.asarray(a) for a in example_arrays)
        captured = 0
        for size in self.batcher.bucket_sizes:
            inputs = []
            for a in arrs:
                shape = ((self.batcher.max_batch,) if a.ndim == 0 else
                         (self.batcher.max_batch, size) + a.shape[1:])
                inputs.append(jnp.zeros(shape, a.dtype))
            for worker in self.dispatcher.workers:
                _graph, hit = self.cache.get_or_capture(
                    worker.apu, self._bstages, tuple(inputs),
                    key_prefix=self._bsig)
                captured += 0 if hit else 1
        return captured

    # -- request intake -----------------------------------------------------
    def submit(self, *arrays: Any, deadline: Optional[float] = None,
               priority: int = 0) -> int:
        """Enqueue one request; full (or deadline-at-risk) buckets launch
        immediately.

        ``deadline`` is a *budget* in seconds from now (the request's
        absolute deadline is ``now + deadline`` on the server's clock);
        ``priority`` is its scheduling priority — under overload a
        higher-priority request may preempt a lower-priority pending one
        instead of being shed.  Raises :class:`AdmissionError` when
        admission control sheds the request (queue full, or the modeled
        capacity cannot meet the deadline), without consuming a request
        id.  Returns the request id; fetch its outputs with
        :meth:`result` after a :meth:`flush` (or once enough same-bucket
        traffic flushed it naturally)."""
        now = self.clock()
        if deadline is not None:
            deadline = float(deadline)
            if deadline <= 0.0:
                raise ValueError(
                    f"deadline must be a positive budget in seconds, "
                    f"got {deadline}")
        try:
            self._admit(now, deadline, priority)
        except AdmissionError as e:
            # door rejects never consumed a rid, so they carry no span
            # tree — the shed decision lands as a track-level instant
            if self.tracer is not None:
                self.tracer.instant("server", now, "shed-at-door",
                                    reason=str(e), priority=priority)
            raise
        req = self.batcher.submit(
            *arrays, t_submit=now,
            deadline_s=None if deadline is None else now + deadline,
            priority=priority)
        if self.tracer is not None:
            self.tracer.begin_request(
                req.rid, now, priority=priority,
                deadline_s=None if deadline is None else now + deadline)
            self.tracer.request_event(req.rid, now, "submit",
                                      n_pending=self.batcher.n_pending)
        # Start the wall clock only once a request is actually ACCEPTED
        # (regression, ISSUE 6): stamping before batcher.submit charged
        # servers whose first submit was rejected (oversize, shed) for
        # idle time they never served, skewing requests/s.
        if self._t0 is None:
            self._t0 = now
        self._launch(self.batcher.pop_full())
        if self.deadline_flush:
            self._launch(self.batcher.tick(now, slack_s=self._flush_slack()),
                         deadline_flushed=True)
        return req.rid

    def tick(self, now: Optional[float] = None) -> None:
        """Deadline pump for idle periods: launch any partial bucket whose
        oldest request's budget is at risk (callers with open-loop traffic
        should call this between arrivals)."""
        if not self.deadline_flush:
            return
        now = self.clock() if now is None else now
        self._launch(self.batcher.tick(now, slack_s=self._flush_slack()),
                     deadline_flushed=True)

    def flush(self) -> None:
        """Force every pending request through: drain partial buckets, then
        retire all in-flight launches (and, with an engine installed, run
        every accepted decode request to completion)."""
        self._launch(self.batcher.drain())
        self._finalize(self.dispatcher.drain_all())
        if self.engine is not None:
            self._eng_pump()
            while self._eng_active:
                self._eng_step()

    # -- admission control --------------------------------------------------
    def _best_spr(self) -> Optional[float]:
        """The fleet's best modeled seconds-per-request across currently
        available (non-quarantined) lanes; ``None`` while unprofiled."""
        sprs = [s for s in (w.modeled_s_per_request()
                            for w in self.dispatcher.available_workers())
                if s is not None]
        return min(sprs) if sprs else None

    def _predicted_completion_s(self, now: float) -> Optional[float]:
        """Modeled seconds until a request submitted *now* would complete:
        the earliest lane's modeled backlog, plus the queue ahead of it
        (staged + in-flight requests, split across the available lanes)
        served at the best lane's modeled seconds-per-request, plus its
        own service.  ``None`` while the fleet is unprofiled (cold servers
        admit everything and bootstrap)."""
        lanes = self.dispatcher.available_workers()
        spr = self._best_spr()
        if spr is None:
            return None
        backlog = min(max(0.0, w.modeled_busy_until - now) for w in lanes)
        depth = (self.batcher.n_pending
                 + sum(w.inflight_requests for w in lanes))
        return backlog + spr * (depth / max(1, len(lanes)) + 1.0)

    def _flush_slack(self) -> float:
        """Remaining-budget threshold at which a partial bucket must
        launch: the modeled backlog ahead of it plus one full batch's
        service — waiting longer would eat time the launch itself needs."""
        spr = self._best_spr()
        if spr is None:
            return 0.0
        now = self.clock()
        backlog = min((max(0.0, w.modeled_busy_until - now)
                       for w in self.dispatcher.available_workers()),
                      default=0.0)
        return backlog + spr * self.batcher.max_batch

    def _admit(self, now: float, deadline: Optional[float],
               priority: int) -> None:
        """Shed (raise :class:`AdmissionError`) instead of queueing
        unboundedly — see class docstring."""
        if not self.admission:
            return
        if (self.max_pending is not None
                and self.batcher.n_pending >= self.max_pending):
            victim = self.batcher.lowest_priority_pending()
            if victim is not None and victim.priority < priority:
                # the new request outranks a staged one: preempt the
                # lowest-priority pending request (loudly) and admit
                self.batcher.remove(victim.rid)
                self._record_shed(
                    victim.rid,
                    f"preempted while pending by a priority-{priority} "
                    f"request (own priority {victim.priority}, queue full "
                    f"at max_pending={self.max_pending})")
            else:
                self.n_shed += 1
                raise AdmissionError(
                    f"admission control shed request: {self.batcher.n_pending}"
                    f" pending >= max_pending={self.max_pending} and "
                    f"priority {priority} outranks no pending request")
        if deadline is not None:
            predicted = self._predicted_completion_s(now)
            if predicted is not None and predicted > deadline:
                self.n_shed += 1
                raise AdmissionError(
                    f"admission control shed request: predicted completion "
                    f"{predicted * 1e3:.3f} ms exceeds the deadline budget "
                    f"{deadline * 1e3:.3f} ms (modeled capacity, "
                    f"{self.batcher.n_pending} staged)")

    def _record_shed(self, rid: int, reason: str) -> None:
        self._shed[rid] = reason
        self.n_shed += 1
        if self.tracer is not None:
            # accepted-then-shed: the rid's tree ends in a named terminal
            self.tracer.finish_request(rid, self.clock(), "shed",
                                       reason=reason)
        while len(self._shed) > self._results_window:
            self._shed.popitem(last=False)

    # -- results ------------------------------------------------------------
    def result(self, rid: int, keep: bool = False) -> Tuple[Any, ...]:
        """Per-request outputs (cropped back to the request's true extent).

        Pops the stored result by default (pass ``keep=True`` to leave it
        readable again).  The store is a bounded LRU: results neither
        fetched nor ``keep``-refreshed within the last ``metrics_window``
        completions are evicted, so a long-lived server stays O(window)
        even when clients never fetch — an evicted read raises
        :class:`KeyError` with an explicit hint.  A request that was
        accepted but later shed (priority preemption, dispatch
        exhaustion) raises :class:`AdmissionError` naming the reason.
        """
        if rid in self._shed:
            raise AdmissionError(
                f"request {rid} was shed after acceptance: {self._shed[rid]}")
        if rid not in self._results:
            evicted = (" (or it was evicted: results not read within the "
                       f"last {self._results_window} completions — "
                       "metrics_window — are dropped)"
                       if rid <= self._evicted_upto else "")
            raise KeyError(
                f"request {rid} has no result (yet, or it was already "
                f"read{evicted}) — flush() the server or submit enough "
                "traffic to fill its bucket")
        if keep:
            # LRU refresh: an actively-polled kept result must not age out
            # behind completions that arrived after its last read
            self._results.move_to_end(rid)
            return self._results[rid]
        return self._results.pop(rid)

    @property
    def n_completed(self) -> int:
        return self._n_done

    # -- decode-engine front (ISSUE 9) --------------------------------------
    def submit_decode(self, prompt: Any, max_new: int,
                      deadline: Optional[float] = None,
                      priority: int = 0) -> int:
        """Enqueue one autoregressive decode request on the engine front.

        The request prefills into a free slot as soon as one exists (a
        launch-time buffer update on the persistent decode state — never a
        re-capture) and then rides the per-step ``generate`` launches with
        every other occupied slot.  Read its tokens incrementally with
        :meth:`stream` (which never blocks on neighbors) or all at once
        via :meth:`result` after :meth:`flush`.
        """
        eng = self._require_engine()
        prompt = jnp.asarray(prompt, jnp.int32).reshape(-1)
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        s = int(prompt.shape[0])
        if s < 1 or s + max_new > eng.max_len:
            raise ValueError(
                f"prompt ({s} tokens) + max_new ({max_new}) must fit the "
                f"engine's max_len={eng.max_len}")
        now = self.clock()
        if (self.admission and self.max_pending is not None
                and len(self._eng_waiting) >= self.max_pending):
            self.n_shed += 1
            if self.tracer is not None:
                self.tracer.instant("server", now, "shed-at-door",
                                    reason="engine queue full",
                                    priority=priority)
            raise AdmissionError(
                f"admission control shed decode request: "
                f"{len(self._eng_waiting)} waiting >= "
                f"max_pending={self.max_pending}")
        rid = self.batcher.mint_rid()
        if self.tracer is not None:
            self.tracer.begin_request(
                rid, now, priority=priority, prompt_len=s, max_new=max_new,
                deadline_s=None if deadline is None else now + deadline)
        if self._t0 is None:
            self._t0 = now
        self._eng_waiting[rid] = (
            prompt, int(max_new),
            None if deadline is None else now + float(deadline))
        self._eng_streams[rid] = deque()
        self._eng_pump()
        return rid

    def stream(self, rid: int) -> Iterator[int]:
        """Per-request token iterator: yields ``rid``'s tokens as generate
        steps produce them, driving the engine forward as needed.

        A finished neighbor never blocks this stream, and exhausting it
        leaves the request's full output in the results store.  Streaming
        a shed rid raises :class:`AdmissionError` (loud, like
        :meth:`result`)."""
        self._require_engine()
        while True:
            if rid in self._shed:
                raise AdmissionError(
                    f"request {rid} was shed after acceptance: "
                    f"{self._shed[rid]}")
            q = self._eng_streams.get(rid)
            while q:
                yield q.popleft()
            if rid not in self._eng_active and rid not in self._eng_waiting:
                self._eng_streams.pop(rid, None)
                return
            self._eng_pump()
            if self._eng_active:
                self._eng_step()

    def _require_engine(self) -> "DecodeEngine":
        if self.engine is None:
            raise RuntimeError(
                "this server has no decode engine: construct it with "
                "Server(..., engine=DecodeEngine(...))")
        return self.engine

    def _eng_pump(self) -> int:
        """Admit waiting decode requests into free slots (prefill + insert).

        Insertion is continuous batching's whole point: a freed slot takes
        a fresh request while the other slots keep decoding — the next
        generate step carries both, bit-identically for each."""
        eng = self.engine
        if self._estate is None:
            self._estate = eng.init_state()
        admitted = 0
        while self._eng_waiting and self._estate.free_slots():
            rid, (prompt, max_new, deadline_s) = \
                self._eng_waiting.popitem(last=False)
            slot = self._estate.free_slots()[0]
            try:
                prefix = eng.prefill(None, prompt, rid=rid)
            except Exception as e:                   # injected fault etc.
                self._eng_streams.pop(rid, None)
                self._record_shed(rid, f"engine prefill failed: {e}")
                continue
            rec = {"slot": slot, "remaining": max_new - 1,
                   "tokens": [int(prefix.token[0])],
                   "deadline_s": deadline_s}
            self._eng_streams[rid].append(rec["tokens"][0])
            if rec["remaining"] <= 0:
                self._eng_finish(rid, rec)
            else:
                eng.insert(prefix, self._estate, slot)
                self._estate.rids[slot] = rid
                self._eng_active[rid] = rec
            admitted += 1
        return admitted

    def _eng_step(self) -> bool:
        """ONE generate launch advancing every occupied slot one token;
        finished requests free their slots and the pump refills them."""
        eng = self.engine
        if not self._eng_active:
            return False
        try:
            self._estate, toks = eng.generate(None, self._estate)
        except Exception as e:
            # the persistent decode state is poisoned mid-flight (injected
            # fault or a donated-buffer launch failure): shed every active
            # rid LOUDLY and reset the state — no request is silently lost
            for rid, rec in list(self._eng_active.items()):
                self._eng_streams.pop(rid, None)
                self._record_shed(rid, f"engine generate failed: {e}")
            self._eng_active.clear()
            self._estate = eng.init_state()
            self._eng_pump()
            return True
        finished = []
        for rid, rec in self._eng_active.items():
            tok = int(toks[rec["slot"]])
            rec["tokens"].append(tok)
            rec["remaining"] -= 1
            self._eng_streams[rid].append(tok)
            if rec["remaining"] <= 0:
                finished.append(rid)
        for rid in finished:
            rec = self._eng_active.pop(rid)
            eng.release(self._estate, rec["slot"])
            self._eng_finish(rid, rec)
        if finished:
            self._eng_pump()
        return True

    def _eng_finish(self, rid: int, rec: Dict[str, Any]) -> None:
        """Book one completed decode request (results store, SLO counters,
        trace terminal) — the engine twin of :meth:`_finalize`."""
        now = self.clock()
        t_done_modeled = self.engine.worker.modeled_busy_until
        self._results[rid] = (np.asarray(rec["tokens"], np.int32),)
        while len(self._eng_streams) > self._results_window:
            self._eng_streams.popitem(last=False)
        while len(self._results) > self._results_window:
            old_rid, _ = self._results.popitem(last=False)
            self._results_evicted += 1
            self._evicted_upto = max(self._evicted_upto, old_rid)
        violated = (rec["deadline_s"] is not None
                    and t_done_modeled > rec["deadline_s"])
        if violated:
            self._n_deadline_violations += 1
        else:
            self._n_in_deadline += 1
        self._n_done += 1
        self._t_last = now if self._t_last is None else max(self._t_last, now)
        self._t_last_modeled = (t_done_modeled
                                if self._t_last_modeled is None
                                else max(self._t_last_modeled,
                                         t_done_modeled))
        if self.tracer is not None:
            if violated:
                self.tracer.request_event(rid, t_done_modeled,
                                          "deadline-miss",
                                          deadline_s=rec["deadline_s"])
            self.tracer.finish_request(rid, t_done_modeled, "result",
                                       n_tokens=len(rec["tokens"]))

    # -- internals ----------------------------------------------------------
    def _launch(self, batches: Sequence[MicroBatch],
                deadline_flushed: bool = False) -> None:
        for batch in batches:
            if self.tracer is not None and deadline_flushed:
                t_evt = self.clock()
                for req in batch.requests:
                    self.tracer.request_event(
                        req.rid, t_evt, "deadline-flush",
                        n_requests=batch.n_requests)

            def graph_for(worker: QueueWorker,
                          batch: MicroBatch = batch):
                graph, hit = self.cache.get_or_capture(
                    worker.apu, self._bstages, batch.inputs,
                    key_prefix=self._bsig)
                if self.tracer is not None:
                    t_evt = self.clock()
                    for req in batch.requests:
                        self.tracer.request_event(
                            req.rid, t_evt,
                            "cache-hit" if hit else "cache-miss",
                            lane=worker.name)
                return graph

            # Power routing prices EVERY candidate lane, not just the
            # chosen one — a quiet estimator keeps speculative pricing out
            # of the request trace (graph_for emits cache events per call)
            estimate_for = None
            if self.dispatcher.budget is not None:
                def estimate_for(worker: QueueWorker,
                                 batch: MicroBatch = batch):
                    graph, _hit = self.cache.get_or_capture(
                        worker.apu, self._bstages, batch.inputs,
                        key_prefix=self._bsig)
                    return worker.estimate(graph)
            try:
                _ticket, retired = self.dispatcher.dispatch(
                    batch, graph_for, t_now=self.clock(),
                    estimate_for=estimate_for)
            except DispatchError as e:
                # the batch exhausted every lane/retry (or, under a power
                # budget, no lane could take it on-budget): its launches
                # never happened, so shed every carried request LOUDLY —
                # the backpressure-retired tickets from failed attempts
                # were real launches and still finalize below
                self._finalize(e.retired)
                if isinstance(e, PowerBudgetError):
                    self._n_power_shed += len(batch.requests)
                    reason = f"power budget shed: {e}"
                else:
                    reason = f"dispatch failed: {e}"
                for req in batch.requests:
                    self._record_shed(req.rid, reason)
                continue
            self._finalize(retired)

    def _trace_completion(self, t: LaunchTicket, req: Any,
                          exec_start: float, violated: bool) -> None:
        """Retroactive request-tree spans for one completed request (only
        reached when a tracer is installed).  All timestamps are already
        known — bucket wait, lane schedule, modeled completion — so the
        spans are emitted at finalize time with zero hot-path cost."""
        tr = self.tracer
        rid = req.rid
        t_end = (t.t_done_modeled if t.t_done_modeled is not None
                 else exec_start)
        tr.child(rid, "bucket-wait", req.t_submit, t.t_launch)
        tr.child(rid, "dispatch", t.t_launch, exec_start,
                 lane=t.worker.name)
        tr.child(rid, "execute", exec_start, t_end, lane=t.worker.name,
                 batch_requests=t.batch.n_requests)
        if violated:
            tr.request_event(rid, t_end, "deadline-miss",
                             deadline_s=req.deadline_s)
        tr.finish_request(rid, t_end, "result")

    def _finalize(self, tickets: Sequence[LaunchTicket]) -> None:
        for t in tickets:
            per_request = t.batch.crop(t.outputs)
            n = max(1, t.batch.n_requests)
            # modeled start of the batch's service window on its lane
            # (t_done_modeled already includes any queueing behind the
            # lane's busy timeline)
            fused_s = t.fused.total_s if t.fused is not None else 0.0
            # clamped: an idle lane starts at t_launch exactly, and the
            # subtraction may land an ulp before it
            exec_start = (max(t.t_launch, t.t_done_modeled - fused_s)
                          if t.t_done_modeled is not None else t.t_launch)
            for req, outs in zip(t.batch.requests, per_request):
                self._results[req.rid] = outs
                while len(self._results) > self._results_window:
                    old_rid, _ = self._results.popitem(last=False)
                    self._results_evicted += 1
                    self._evicted_upto = max(self._evicted_upto, old_rid)
                if t.fused is not None:
                    # each request *experiences* the whole batch's fused
                    # latency; its amortized cost share (the throughput
                    # view) and energy split across the live requests
                    self._modeled_latency.append(t.fused.total_s)
                    self._modeled_cost.append(t.fused.scaled(1.0 / n).total_s)
                    self._modeled_energy.append(t.energy_j / n)
                    # flame attribution: the request's end-to-end modeled
                    # latency (submit -> t_done_modeled) split by phase —
                    # the five deques always sum to it (see DECOMP_PHASES)
                    freq = t.fused.freq_hz
                    self._decomp["admission"].append(0.0)
                    self._decomp["queueing"].append(
                        t.t_launch - req.t_submit)
                    self._decomp["dispatch"].append(
                        (exec_start - t.t_launch)
                        + (t.fused.startup + t.fused.scheduling) / freq)
                    self._decomp["compute"].append(t.fused.compute / freq)
                    self._decomp["transfer"].append(t.fused.transfer / freq)
                # deadline accounting against the deterministic modeled
                # completion time (requests without a deadline are always
                # "in deadline" for goodput purposes)
                violated = (req.deadline_s is not None
                            and t.t_done_modeled is not None
                            and t.t_done_modeled > req.deadline_s)
                if violated:
                    self._n_deadline_violations += 1
                else:
                    self._n_in_deadline += 1
                self._n_done += 1
                if self.tracer is not None:
                    self._trace_completion(t, req, exec_start, violated)
            if t.t_done is not None:
                self._t_last = (t.t_done if self._t_last is None
                                else max(self._t_last, t.t_done))
            if t.t_done_modeled is not None:
                self._t_last_modeled = (
                    t.t_done_modeled if self._t_last_modeled is None
                    else max(self._t_last_modeled, t.t_done_modeled))

    # -- reporting ----------------------------------------------------------
    def report(self) -> ServeReport:
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None else 0.0)
        modeled_span = ((self._t_last_modeled - self._t0)
                        if self._t0 is not None
                        and self._t_last_modeled is not None else 0.0)
        lat = np.asarray(self._modeled_latency, np.float64)
        pct = {p: (float(np.percentile(lat, p)) if lat.size else 0.0)
               for p in PERCENTILES}
        cost = (float(np.mean(self._modeled_cost))
                if self._modeled_cost else 0.0)
        energy = (float(np.mean(self._modeled_energy))
                  if self._modeled_energy else 0.0)
        n_batches = self.batcher.n_batches
        fill = (self._n_done / (n_batches * self.batcher.max_batch)
                if n_batches else 0.0)
        queues = self.dispatcher.stats()
        if (self.engine is not None
                and self.engine.worker not in self.dispatcher.workers):
            # the engine's lane books its launches like any dispatcher
            # lane, so fleet power/energy roll-ups stay honest (engine-only
            # servers already list it as the dispatch lane)
            queues = (*queues, self.engine.worker.stats())
        # batch-weighted mean utilization per mesh axis across sharded lanes
        axis_sum: Dict[str, float] = {}
        axis_n: Dict[str, int] = {}
        for qs in queues:
            for axis, util in qs.mesh_utilization:
                axis_sum[axis] = axis_sum.get(axis, 0.0) + util * qs.batches
                axis_n[axis] = axis_n.get(axis, 0) + qs.batches
        mesh_util = {a: axis_sum[a] / axis_n[a]
                     for a in axis_sum if axis_n[a]}
        decomp = {}
        if any(self._decomp[p] for p in DECOMP_PHASES):
            decomp = {
                phase: {p: float(np.percentile(
                            np.asarray(self._decomp[phase], np.float64), p))
                        for p in DECOMP_PERCENTILES}
                for phase in DECOMP_PHASES}
        # -- power & energy (ISSUE 8): honest fleet energy over the modeled
        # makespan — active launch energy per lane, plus each lane's
        # clock-gated leakage floor (§IV SLEEP_REQ) for every modeled second
        # it was NOT serving.  All derived efficiency numbers divide by the
        # honest total, never the active-only ledger.
        active_energy = sum(qs.energy_j for qs in queues)
        idle_energy = (sum(max(0.0, modeled_span - qs.modeled_s)
                           * qs.idle_power_w for qs in queues)
                       if modeled_span > 0 else 0.0)
        fleet_energy = active_energy + idle_energy
        engine_kwargs: Dict[str, Any] = {}
        if self.engine is not None and self.engine.n_steps:
            es = self.engine.stats()
            engine_kwargs = dict(
                engine_steps=int(es["n_steps"]),
                engine_tokens=int(es["n_tokens"]),
                engine_prefill_s_modeled=es["prefill_modeled_s"],
                engine_decode_s_modeled=es["decode_modeled_s"],
                engine_tokens_per_s_modeled=es["tokens_per_s_modeled"],
                engine_slot_occupancy=es["occupancy"],
                engine_bytes_per_step=es["bytes_per_step"],
                engine_mem_bound_fraction=es["mem_bound_fraction"])
        return ServeReport(
            n_requests=self._n_done,
            n_batches=n_batches,
            wall_s=wall,
            requests_per_s=(self._n_done / wall if wall > 0 else 0.0),
            modeled_latency_s=pct,
            modeled_cost_per_request_s=cost,
            modeled_energy_per_request_j=energy,
            avg_batch_fill=fill,
            padded_elements=self.batcher.padded_elements,
            queues=queues,
            cache=self.cache.stats(),
            graphs_verified=self.cache.verified,
            sanitizer_findings=self.cache.findings,
            mesh_utilization=mesh_util,
            results_evicted=self._results_evicted,
            n_shed=self.n_shed,
            n_deadline_violations=self._n_deadline_violations,
            goodput_per_s=(self._n_in_deadline / wall if wall > 0 else 0.0),
            goodput_per_s_modeled=(self._n_in_deadline / modeled_span
                                   if modeled_span > 0 else 0.0),
            deadline_flushes=self.batcher.deadline_flushes,
            n_retries=self.dispatcher.retries,
            n_dispatch_failures=self.dispatcher.dispatch_failures,
            n_quarantines=self.dispatcher.quarantines(),
            latency_decomposition_s=decomp,
            avg_fleet_power_w=(fleet_energy / modeled_span
                               if modeled_span > 0 else 0.0),
            peak_fleet_power_w=self.dispatcher.peak_fleet_power_w,
            fleet_idle_energy_j=idle_energy,
            fleet_energy_j=fleet_energy,
            requests_per_s_per_watt=(self._n_done / fleet_energy
                                     if fleet_energy > 0 else 0.0),
            goodput_per_s_per_watt=(self._n_in_deadline / fleet_energy
                                    if fleet_energy > 0 else 0.0),
            n_power_shed=self._n_power_shed,
            n_power_throttled=self.dispatcher.power_throttles,
            n_budget_violations=sum(qs.budget_violations for qs in queues),
            power_budget_lane_mw=(None if self.power_budget is None
                                  else self.power_budget.lane_mw),
            power_budget_fleet_mw=(None if self.power_budget is None
                                   else self.power_budget.fleet_mw),
            **engine_kwargs,
        )

    def publish_metrics(self, registry: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
        """Publish the whole stack's telemetry into a registry (snapshot
        style, idempotent): the :meth:`report` roll-up, per-queue stats,
        cache counters, and any installed fault plans' injection totals.
        """
        registry = MetricsRegistry() if registry is None else registry
        self.report().publish_metrics(registry)
        self.cache.publish_metrics(registry)
        plans = {id(w.fault_plan): w.fault_plan
                 for w in self.dispatcher.workers
                 if w.fault_plan is not None}
        for plan in plans.values():
            plan.publish_metrics(registry)
        return registry
