"""Server — the long-lived serving engine over APU pipelines.

Ties the subsystem together: requests enter :meth:`Server.submit`, the
:class:`~repro.serve.batching.BucketBatcher` pads them to shape buckets and
coalesces full micro-batches, the
:class:`~repro.serve.cache.GraphCache` supplies (or captures, once per
bucket x worker) the batched :class:`CommandGraph`, and the
:class:`~repro.serve.dispatch.MultiQueueDispatcher` load-balances launches
across the configured e-GPU queues under an in-flight bound.  A warm server
on steady-state traffic therefore performs **zero** re-captures / re-jits:
every launch is a cached-graph replay, paying Tiny-OpenCL startup +
scheduling once per micro-batch (paper §IV-B residency, scaled out).

:meth:`Server.report` rolls the per-queue machine-model accounting into a
:class:`ServeReport`: measured requests/s, modeled per-request latency
percentiles (each request experiences its batch's fused-chain latency) and
modeled energy per request.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core.apu import Stage
from ..core.device import EGPUConfig, EGPU_16T
from .batching import BucketBatcher, MicroBatch, batched_stages
from .cache import GraphCache, stages_signature
from .dispatch import LaunchTicket, MultiQueueDispatcher, QueueStats, QueueWorker

PERCENTILES = (50, 90, 99)


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregate serving metrics (measured throughput, modeled cost)."""

    n_requests: int
    n_batches: int
    wall_s: float
    requests_per_s: float
    #: modeled request latency percentiles, seconds (p50/p90/p99); a request
    #: experiences the fused-chain latency of the micro-batch carrying it
    modeled_latency_s: Dict[int, float]
    #: mean amortized cost per request (batch fused time / live requests) —
    #: the throughput view of the same launches
    modeled_cost_per_request_s: float
    modeled_energy_per_request_j: float
    avg_batch_fill: float              # live requests / batch capacity
    padded_elements: int               # elements added purely by padding
    queues: Tuple[QueueStats, ...]
    cache: Dict[str, int]
    #: mean per-launch utilization of each mesh axis across the sharded
    #: lanes (batch-weighted); empty when no worker owns a mesh
    mesh_utilization: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    #: completed results dropped by the bounded LRU store (not fetched or
    #: ``keep``-refreshed within the last ``metrics_window`` completions)
    results_evicted: int = 0

    def summary(self) -> str:
        lines = [
            f"requests        {self.n_requests} in {self.n_batches} batches "
            f"(fill {self.avg_batch_fill:.0%}, "
            f"{self.padded_elements} padded elements)",
            f"throughput      {self.requests_per_s:,.0f} req/s measured "
            f"({self.wall_s * 1e3:.1f} ms wall)",
            "modeled latency " + "  ".join(
                f"p{p} {self.modeled_latency_s[p] * 1e3:.3f} ms"
                for p in sorted(self.modeled_latency_s)),
            f"modeled cost    {self.modeled_cost_per_request_s * 1e3:.3f} "
            f"ms/request amortized, "
            f"{self.modeled_energy_per_request_j * 1e6:.2f} uJ/request",
            f"graph cache     {self.cache['hits']} hits / "
            f"{self.cache['misses']} misses / "
            f"{self.cache['evictions']} evictions "
            f"({self.cache['entries']}/{self.cache['capacity']} resident)",
        ]
        if self.mesh_utilization:
            lines.append("mesh util       " + "  ".join(
                f"{axis} {util:.0%}"
                for axis, util in sorted(self.mesh_utilization.items())))
        if self.results_evicted:
            lines.append(f"results         {self.results_evicted} unread "
                         "results evicted (bounded LRU store)")
        for qs in self.queues:
            mesh = ("" if not qs.mesh_axes else "  mesh " + "x".join(
                f"{a}={s}" for a, s in qs.mesh_axes))
            lines.append(
                f"  queue {qs.name:12s} {qs.batches:4d} batches "
                f"{qs.requests:5d} reqs  modeled {qs.modeled_s * 1e3:8.2f} ms "
                f"{qs.energy_j * 1e6:8.1f} uJ  peak in-flight "
                f"{qs.peak_in_flight} ({qs.backpressure_stalls} stalls)"
                + mesh)
        return "\n".join(lines)


class Server:
    """A long-lived serving engine for one APU pipeline.

    ``stages`` carry *per-request* semantics (exactly what
    :meth:`APU.offload` takes); the server lifts them over the batch axis
    internally.  ``workers`` are the lanes to dispatch across: each entry
    is either an :class:`EGPUConfig` preset (wrapped into a
    :class:`QueueWorker`) or a pre-built worker instance — in particular a
    :class:`~repro.serve.sharded.ShardedWorker` spanning a device-mesh
    slice.  Heterogeneous mixes are fine, each lane gets its own cached
    graphs.

    Pipeline contract: kernels must be pad-stable along axis 0 of each
    request array (see :mod:`repro.serve.batching`).
    """

    def __init__(self, stages: Sequence[Stage],
                 workers: Sequence[Union[EGPUConfig, QueueWorker]]
                 = (EGPU_16T,),
                 bucket_sizes: Sequence[int] = (64, 256, 1024),
                 max_batch: int = 4, max_in_flight: int = 2,
                 cache_capacity: int = 32, fill: float | int = 0,
                 crop_outputs: bool = True,
                 metrics_window: int = 100_000):
        self.stages = tuple(stages)
        self.batcher = BucketBatcher(bucket_sizes, max_batch=max_batch,
                                     fill=fill, crop_outputs=crop_outputs)
        self.dispatcher = MultiQueueDispatcher([
            w if isinstance(w, QueueWorker) else
            QueueWorker(w, name=f"{i}:{w.name}", max_in_flight=max_in_flight)
            for i, w in enumerate(workers)])
        self.cache = GraphCache(cache_capacity)
        # Every micro-batch is padded to max_batch, so ONE batched pipeline
        # covers all traffic; its (const-hashing) signature is computed once
        # here, never on the hot path.
        self._bstages = batched_stages(self.stages, max_batch)
        self._bsig = stages_signature(self._bstages)
        # Completed results in LRU order (completion order, refreshed by
        # keep=True reads).  Bounded to the metrics window: results nobody
        # fetched (or keep-refreshed) within the last `metrics_window`
        # completions are EVICTED, so a long-lived server with
        # fire-and-forget clients keeps O(window) memory instead of
        # leaking every unread output forever.
        self._results: "OrderedDict[int, Tuple[Any, ...]]" = OrderedDict()
        self._results_window = max(1, int(metrics_window))
        self._results_evicted = 0
        self._evicted_upto = -1          # highest rid ever evicted unread
        # Bounded metric windows: percentiles/means in report() describe the
        # last `metrics_window` requests, so a long-lived server's metric
        # memory is O(window), matching the O(in-flight) queue contract.
        self._modeled_latency: Deque[float] = deque(maxlen=metrics_window)
        self._modeled_cost: Deque[float] = deque(maxlen=metrics_window)
        self._modeled_energy: Deque[float] = deque(maxlen=metrics_window)
        self._n_done = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- warm-up ------------------------------------------------------------
    def warmup(self, *example_arrays: Any) -> int:
        """Pre-capture the batched graph for every (bucket, worker) pair.

        ``example_arrays`` is one representative request (its trailing dims
        and dtypes define the bucket shapes; values are irrelevant — capture
        traces abstractly).  After ``warmup`` a server sees zero re-captures
        on any traffic that fits the configured buckets.  Returns the number
        of graphs captured.
        """
        arrs = tuple(jnp.asarray(a) for a in example_arrays)
        captured = 0
        for size in self.batcher.bucket_sizes:
            inputs = []
            for a in arrs:
                shape = ((self.batcher.max_batch,) if a.ndim == 0 else
                         (self.batcher.max_batch, size) + a.shape[1:])
                inputs.append(jnp.zeros(shape, a.dtype))
            for worker in self.dispatcher.workers:
                _graph, hit = self.cache.get_or_capture(
                    worker.apu, self._bstages, tuple(inputs),
                    key_prefix=self._bsig)
                captured += 0 if hit else 1
        return captured

    # -- request intake -----------------------------------------------------
    def submit(self, *arrays: Any) -> int:
        """Enqueue one request; full buckets launch immediately.

        Returns the request id; fetch its outputs with :meth:`result` after
        a :meth:`flush` (or once enough same-bucket traffic flushed it
        naturally)."""
        now = time.perf_counter()
        if self._t0 is None:
            self._t0 = now
        req = self.batcher.submit(*arrays, t_submit=now)
        self._launch(self.batcher.pop_full())
        return req.rid

    def flush(self) -> None:
        """Force every pending request through: drain partial buckets, then
        retire all in-flight launches."""
        self._launch(self.batcher.drain())
        self._finalize(self.dispatcher.drain_all())

    # -- results ------------------------------------------------------------
    def result(self, rid: int, keep: bool = False) -> Tuple[Any, ...]:
        """Per-request outputs (cropped back to the request's true extent).

        Pops the stored result by default (pass ``keep=True`` to leave it
        readable again).  The store is a bounded LRU: results neither
        fetched nor ``keep``-refreshed within the last ``metrics_window``
        completions are evicted, so a long-lived server stays O(window)
        even when clients never fetch — an evicted read raises
        :class:`KeyError` with an explicit hint.
        """
        if rid not in self._results:
            evicted = (" (or it was evicted: results not read within the "
                       f"last {self._results_window} completions — "
                       "metrics_window — are dropped)"
                       if rid <= self._evicted_upto else "")
            raise KeyError(
                f"request {rid} has no result (yet, or it was already "
                f"read{evicted}) — flush() the server or submit enough "
                "traffic to fill its bucket")
        if keep:
            # LRU refresh: an actively-polled kept result must not age out
            # behind completions that arrived after its last read
            self._results.move_to_end(rid)
            return self._results[rid]
        return self._results.pop(rid)

    @property
    def n_completed(self) -> int:
        return self._n_done

    # -- internals ----------------------------------------------------------
    def _launch(self, batches: Sequence[MicroBatch]) -> None:
        for batch in batches:
            worker = self.dispatcher.pick()
            graph, _hit = self.cache.get_or_capture(
                worker.apu, self._bstages, batch.inputs,
                key_prefix=self._bsig)
            _ticket, retired = worker.launch(graph, batch)
            self._finalize(retired)

    def _finalize(self, tickets: Sequence[LaunchTicket]) -> None:
        for t in tickets:
            per_request = t.batch.crop(t.outputs)
            n = max(1, t.batch.n_requests)
            for req, outs in zip(t.batch.requests, per_request):
                self._results[req.rid] = outs
                while len(self._results) > self._results_window:
                    old_rid, _ = self._results.popitem(last=False)
                    self._results_evicted += 1
                    self._evicted_upto = max(self._evicted_upto, old_rid)
                if t.fused is not None:
                    # each request *experiences* the whole batch's fused
                    # latency; its amortized cost share (the throughput
                    # view) and energy split across the live requests
                    self._modeled_latency.append(t.fused.total_s)
                    self._modeled_cost.append(t.fused.scaled(1.0 / n).total_s)
                    self._modeled_energy.append(t.energy_j / n)
                self._n_done += 1
            if t.t_done is not None:
                self._t_last = (t.t_done if self._t_last is None
                                else max(self._t_last, t.t_done))

    # -- reporting ----------------------------------------------------------
    def report(self) -> ServeReport:
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None else 0.0)
        lat = np.asarray(self._modeled_latency, np.float64)
        pct = {p: (float(np.percentile(lat, p)) if lat.size else 0.0)
               for p in PERCENTILES}
        cost = (float(np.mean(self._modeled_cost))
                if self._modeled_cost else 0.0)
        energy = (float(np.mean(self._modeled_energy))
                  if self._modeled_energy else 0.0)
        n_batches = self.batcher.n_batches
        fill = (self._n_done / (n_batches * self.batcher.max_batch)
                if n_batches else 0.0)
        queues = self.dispatcher.stats()
        # batch-weighted mean utilization per mesh axis across sharded lanes
        axis_sum: Dict[str, float] = {}
        axis_n: Dict[str, int] = {}
        for qs in queues:
            for axis, util in qs.mesh_utilization:
                axis_sum[axis] = axis_sum.get(axis, 0.0) + util * qs.batches
                axis_n[axis] = axis_n.get(axis, 0) + qs.batches
        mesh_util = {a: axis_sum[a] / axis_n[a]
                     for a in axis_sum if axis_n[a]}
        return ServeReport(
            n_requests=self._n_done,
            n_batches=n_batches,
            wall_s=wall,
            requests_per_s=(self._n_done / wall if wall > 0 else 0.0),
            modeled_latency_s=pct,
            modeled_cost_per_request_s=cost,
            modeled_energy_per_request_j=energy,
            avg_batch_fill=fill,
            padded_elements=self.batcher.padded_elements,
            queues=queues,
            cache=self.cache.stats(),
            mesh_utilization=mesh_util,
            results_evicted=self._results_evicted,
        )
