"""Sharded serving — one dispatcher lane spanning a device mesh (ISSUE 5).

The paper scales the e-GPU by replicating compute units behind one
Tiny-OpenCL scheduler (§IV, §VI); the serving analogue is a
:class:`ShardedWorker` that owns a :class:`jax.sharding.Mesh` *slice*
instead of a single device.  It is a drop-in
:class:`~repro.serve.dispatch.QueueWorker`: the
:class:`~repro.serve.dispatch.MultiQueueDispatcher` routes micro-batches
across a mix of plain and sharded lanes, and every launch of a cached
:class:`~repro.core.runtime.CommandGraph` is lowered with
``NamedSharding``\\ s derived from the :mod:`repro.distributed.sharding`
rule table:

* the micro-batch leading axis (logical ``"batch"``) spans the mesh's
  data-parallel axes — under the default :data:`SERVE_RULES` that is
  ``("pod", "data")``, pruned to the axes the worker's mesh actually has;
* per-stage constant externals (weights) are replicated unless the worker
  is built with ``const_axes=`` naming their logical axes — a
  model-parallel stage arg tagged ``("heads",)`` lands on ``"model"``;
* the divisibility fallback is preserved end to end: a batch capacity (or
  constant dim) not divisible by its mesh-axis product progressively drops
  trailing axes and replicates if nothing divides, so odd bucket shapes
  degrade gracefully instead of failing to lower.

Contracts:

* **pure compiled code under any binding** — the shardings are a
  launch-time property (``graph.launch_prefix(..., in_shardings=...,
  out_shardings=...)``), never part of the capture, so one cached graph
  carries single-device and sharded executables side by side; the
  :class:`~repro.serve.cache.GraphCache` still keys on the worker's
  :attr:`placement` so sharded and plain entries never collide;
* **honest accounting** — ``batched_stages`` scaled ``WorkCounts`` by the
  batch; a launch that actually splits the batch ``shards`` ways splits
  the chain's transfer + compute across the shards while startup +
  scheduling are still paid (concurrently, once per launch) on every mesh
  slice: :func:`shard_breakdown`.  A fallback-to-replication launch
  reports ``shards == 1`` and scales nothing;
* **bit-identical results** — kernels are pure and batch rows independent,
  so a data-parallel binding cannot change functional outputs (pinned by
  ``tests/test_sharded_serve.py`` on the TinyBio pipeline).
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.device import EGPUConfig
from ..core.machine import PhaseBreakdown
from ..core.runtime import Buffer, CommandGraph
from ..distributed.sharding import ShardingRules, SERVE_RULES, spec_for
from ..obs import Tracer
from .batching import MicroBatch
from .dispatch import QueueStats, QueueWorker
from .faults import FaultPlan, apply_spike

#: logical-axis name of the micro-batch leading dimension
BATCH_AXIS = "batch"


def data_mesh(n_devices: Optional[int] = None, axis: str = "data") -> Mesh:
    """A 1-D data-parallel mesh over the first ``n_devices`` local devices
    (all of them by default) — the common ShardedWorker mesh slice on a
    host whose devices aren't already organized into a grid."""
    devices = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices must be in 1..{len(devices)}, got {n_devices}")
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (axis,))


def mesh_signature(mesh: Mesh) -> Tuple[Any, ...]:
    """Hashable identity of a mesh: axis layout + the concrete devices."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def shard_breakdown(fused: PhaseBreakdown, shards: int) -> PhaseBreakdown:
    """The fused chain's modeled breakdown under ``shards``-way data
    parallelism: transfer + compute split across the shards (each mesh
    slice runs ``1/shards`` of the batch), startup + scheduling paid in
    full (every slice dispatches its shard of the chain concurrently —
    replicating the Tiny-OpenCL scheduler does not shrink its startup)."""
    if shards <= 1:
        return fused
    return dataclasses.replace(
        fused, transfer=fused.transfer / shards,
        compute=fused.compute / shards)


class ShardedWorker(QueueWorker):
    """One serving lane spanning a device-mesh slice.

    ``mesh`` is the worker's slice of the device fleet; ``rules`` the
    logical-axis table used to derive shardings (default
    :data:`~repro.distributed.sharding.SERVE_RULES`).  ``const_axes``
    optionally names the logical axes of each *constant* external (a
    tuple per constant, in capture order, e.g. ``(("heads", None),)`` for
    one model-parallel weight matrix); constants without an entry are
    replicated.  Everything else — backpressure, event-segment retirement,
    per-queue accounting — is inherited from :class:`QueueWorker`; the
    launch path binds every cached-graph replay to the mesh and scales
    the modeled totals by the shard count actually applied.
    """

    def __init__(self, config: EGPUConfig, mesh: Mesh,
                 name: Optional[str] = None, max_in_flight: int = 2,
                 explicit_transfers: bool = True,
                 rules: ShardingRules = SERVE_RULES,
                 const_axes: Optional[Sequence[Optional[Sequence[
                     Optional[str]]]]] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer: Optional[Tracer] = None):
        if not isinstance(mesh, Mesh):
            raise TypeError(f"mesh must be a jax.sharding.Mesh, got "
                            f"{type(mesh).__name__}")
        if mesh.devices.size < 1:
            raise ValueError("mesh must hold at least one device")
        self.mesh = mesh
        self.rules = rules
        self.const_axes = (None if const_axes is None else
                           tuple(None if a is None else tuple(a)
                                 for a in const_axes))
        super().__init__(config, name=name, max_in_flight=max_in_flight,
                         explicit_transfers=explicit_transfers,
                         fault_plan=fault_plan, clock=clock, tracer=tracer)
        # Cache identity: sharded captures must never collide with plain
        # single-device ones (or with a different mesh / rule table) in a
        # shared GraphCache.
        self.apu.placement = ("sharded", mesh_signature(mesh), rules.name,
                              self.const_axes)
        #: per-graph derived shardings, keyed weakly so evicted cache
        #: entries do not pin their sharding tuples here
        self._shard_memo: "weakref.WeakKeyDictionary[CommandGraph, Tuple]" = (
            weakref.WeakKeyDictionary())
        # per-axis utilization accumulators (sum of per-launch fractions)
        self._axis_util_sum: Dict[str, float] = {
            str(a): 0.0 for a in mesh.axis_names}
        self._util_launches = 0

    # -- sharding derivation -------------------------------------------------
    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def _axis_sizes(self) -> Dict[str, int]:
        return {str(a): int(s) for a, s in
                zip(self.mesh.axis_names, self.mesh.devices.shape)}

    def _spec_factor(self, spec: P) -> Dict[str, int]:
        """Per-mesh-axis split factor a PartitionSpec applies."""
        sizes = self._axis_sizes()
        used: Dict[str, int] = {}
        for entry in spec:
            if entry is None:
                continue
            for a in ((entry,) if isinstance(entry, str) else entry):
                used[str(a)] = sizes.get(str(a), 1)
        return used

    def _batch_spec(self, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a batch-leading tensor (micro-batch inputs and
        outputs): logical ``"batch"`` on dim 0, with the rule table's
        divisibility fallback against the actual extent."""
        logical = (BATCH_AXIS,) + (None,) * (len(shape) - 1)
        return spec_for(logical, self.rules, self.mesh, tuple(shape))

    def shardings_for(self, graph: CommandGraph) -> Tuple[
            Tuple[NamedSharding, ...], Tuple[NamedSharding, ...], int,
            Dict[str, int]]:
        """(in_shardings, out_shardings, batch shard count, axis factors)
        for ``graph``.

        Derived once per graph (memoized weakly): request externals (the
        leading ``graph.n_request_inputs``) and every output span the data
        axes on their batch dim, constant externals follow ``const_axes``
        or replicate.  ``shards`` is the split factor actually applied to
        the batch axis — 1 when the divisibility fallback replicated it.
        ``axis factors`` is the per-mesh-axis split any tensor of the
        launch achieved (batch inputs AND const externals), so
        model-parallel constants register on their axis too.
        """
        memo = self._shard_memo.get(graph)
        if memo is not None:
            return memo
        n_req = getattr(graph, "n_request_inputs", len(graph.ext_avals))
        in_sh = []
        specs = []
        for i, aval in enumerate(graph.ext_avals):
            if i < n_req:
                spec = self._batch_spec(aval.shape)
            else:
                logical = None
                if self.const_axes is not None:
                    j = i - n_req
                    logical = (self.const_axes[j]
                               if j < len(self.const_axes) else None)
                spec = (spec_for(tuple(logical), self.rules, self.mesh,
                                 tuple(aval.shape))
                        if logical is not None else P())
            specs.append(spec)
            in_sh.append(NamedSharding(self.mesh, spec))
        out_specs = [self._batch_spec(aval.shape) for aval in graph.out_avals]
        out_sh = tuple(NamedSharding(self.mesh, s) for s in out_specs)
        batch_factor = self._spec_factor(
            specs[0] if n_req else (out_specs[0] if out_specs else P()))
        shards = 1
        for f in batch_factor.values():
            shards *= f
        # utilization source: the best split ANY tensor achieved per axis —
        # a model-parallel const registers on "model" even though the batch
        # never touches it, so a healthy MP lane is distinguishable from
        # one whose weights silently fell back to replication
        axis_factor: Dict[str, int] = {}
        for spec in list(specs) + out_specs:
            for a, f in self._spec_factor(spec).items():
                axis_factor[a] = max(axis_factor.get(a, 1), f)
        memo = (tuple(in_sh), out_sh, max(1, shards), axis_factor)
        self._shard_memo[graph] = memo
        return memo

    # -- power pricing (ISSUE 8) ---------------------------------------------
    def estimate(self, graph: CommandGraph
                 ) -> Tuple[Optional[PhaseBreakdown], float]:
        """The dispatcher's pricing view of a launch on this mesh lane:
        the shard-scaled breakdown :meth:`_do_launch` would book (energy
        stays total — the same ops run, just spread over more devices, so
        a sharded lane prices a *higher* window-average power over its
        *shorter* window, exactly the physics a fleet budget must see)."""
        fused, energy = graph.fused_modeled()
        if fused is not None:
            _in, _out, shards, _ = self.shardings_for(graph)
            fused = shard_breakdown(fused, shards)
        return fused, energy

    # -- launch --------------------------------------------------------------
    def _do_launch(self, graph: CommandGraph, batch: MicroBatch
                   ) -> Tuple[Tuple[Buffer, ...],
                              Optional[PhaseBreakdown], float]:
        # fault gate first — an injected failure fires before any real
        # sharded work, exactly like the plain-lane path
        spike_s = self._fault_gate()
        in_sh, out_sh, shards, axis_factor = self.shardings_for(graph)
        outs = graph.launch_prefix(batch.inputs, queue=self.queue,
                                   in_shardings=in_sh, out_shardings=out_sh)
        fused, energy = graph.fused_modeled()
        if fused is not None:
            # transfer + compute split across the mesh slices; startup +
            # scheduling paid once per launch on every slice concurrently.
            # Energy is total work and stays unscaled — the same ops run,
            # just spread over more devices.
            fused = shard_breakdown(fused, shards)
        fused = apply_spike(fused, spike_s)
        # utilization: fraction of each mesh axis this launch exploited —
        # any tensor's split counts (batch over data, consts over model);
        # fallback-to-replication reads as 1/size
        for a, size in self._axis_sizes().items():
            self._axis_util_sum[a] += axis_factor.get(a, 1) / size
        self._util_launches += 1
        return outs, fused, energy

    def stats(self) -> QueueStats:
        base = super().stats()
        sizes = self._axis_sizes()
        util = tuple(
            (a, self._axis_util_sum[a] / self._util_launches)
            for a in sizes) if self._util_launches else ()
        return dataclasses.replace(
            base, shards=self.n_devices,
            mesh_axes=tuple(sizes.items()), mesh_utilization=util)
