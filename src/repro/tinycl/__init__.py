"""repro.tinycl — the Tiny-OpenCL host API, under its own name (host API v2).

The paper's §IV contribution is Tiny-OpenCL: a lightweight but *real*
OpenCL host API — programs, kernel objects, buffer objects, command queues,
events, and explicit host<->e-GPU data-movement commands over the shared
X-HEEP memory.  This façade collects the whole host surface in one
namespace so OpenCL-literate code reads naturally; everything re-exports
from ``repro.core`` (there is exactly one implementation).

OpenCL -> TinyCL mapping::

    clCreateContext                     Context(Device(config))
    clCreateCommandQueue                CommandQueue(ctx, ...)
      CL_QUEUE_OUT_OF_ORDER_EXEC_MODE     out_of_order=True
    clCreateBuffer                      ctx.create_buffer(data, flags,
      CL_MEM_USE_HOST_PTR                 use_host_ptr=True / copy=False)
    clCreateProgramWithBuiltInKernels   Program.build(config)
    clCreateKernel                      program.create_kernel(name, **variant)
    clCreateKernelsInProgram            program.create_kernels()
    clGetKernelArgInfo                  kernel.arg_info
    clSetKernelArg                      kernel.set_arg(i, v) / kernel.set_args
    clEnqueueNDRangeKernel              queue.enqueue_kernel(kernel, ndr)
                                        (queue.enqueue_nd_range for
                                         call-site args)
    clEnqueueWriteBuffer                queue.enqueue_write_buffer(buf, src)
    clEnqueueReadBuffer                 queue.enqueue_read_buffer(buf)
    clEnqueueCopyBuffer                 queue.enqueue_copy_buffer(src, dst)
    clEnqueueMarkerWithWaitList         queue.enqueue_marker(wait_events)
    clEnqueueBarrierWithWaitList        queue.enqueue_barrier(wait_events)
    clFlush / clFinish                  queue.flush() / queue.finish()
    clRetainEvent / clReleaseEvent      event.retain() / event.release()
    clWaitForEvents                     event.wait()

Beyond OpenCL (the paper's modeling + the repo's serving substrate):
``queue.capture()`` records commands into a :class:`CommandGraph` replayed
as one fused XLA computation, and every event carries the analytic machine
model's :class:`PhaseBreakdown` / energy for its device configuration.

Applications extend the kernel registry with the :func:`kernel_family`
decorator (namespaced names recommended)::

    from repro import tinycl

    @tinycl.kernel_family("myapp.rmsnorm")
    def build_rmsnorm(config, *, eps=1e-6):
        return tinycl.Kernel("rmsnorm", executor=..., counts=...)

    kern = tinycl.Program.build(tinycl.EGPU_16T).create_kernel(
        "myapp.rmsnorm")
"""

from ..core.device import (EGPU_4T, EGPU_8T, EGPU_16T, HOST, PRESETS,
                           EGPUConfig, KernelKnobs)
from ..core.machine import PhaseBreakdown, WorkCounts, transfer_time
from ..core.ndrange import NDRange
from ..core.program import (BUILTIN_FAMILIES, REGISTRY, KernelRegistry,
                            Program, kernel_family)
from ..core.runtime import (ArgInfo, Buffer, CommandGraph, CommandQueue,
                            Context, Device, Event, GraphBuffer, Kernel)
from ..core.scheduler import optimal_ndrange

__all__ = [
    "EGPU_4T", "EGPU_8T", "EGPU_16T", "HOST", "PRESETS", "EGPUConfig",
    "KernelKnobs",
    "PhaseBreakdown", "WorkCounts", "transfer_time",
    "NDRange", "optimal_ndrange",
    "BUILTIN_FAMILIES", "REGISTRY", "KernelRegistry", "Program",
    "kernel_family",
    "ArgInfo", "Buffer", "CommandGraph", "CommandQueue", "Context", "Device",
    "Event", "GraphBuffer", "Kernel",
]
