"""repro.train — jit-able train/serve step factories."""

from .step import TrainConfig, init_train_state, make_train_step
from .serve import make_decode_step, make_prefill_step

__all__ = ["TrainConfig", "make_train_step", "init_train_state",
           "make_prefill_step", "make_decode_step"]
