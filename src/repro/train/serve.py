"""Serving steps: batched prefill and single-token decode.

``serve_step`` per the assignment: decode shapes lower ONE new token against
a KV cache of ``seq_len`` (decode_32k / long_500k), prefill shapes lower the
full-sequence prompt pass.  Encoder archs (hubert) expose ``encode`` — a
full forward returning per-frame logits — instead of prefill/decode.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.layers import logits_from_hidden
from ..models.transformer import decode_step, forward, prefill


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      cache_dtype=jnp.bfloat16) -> Callable:
    if cfg.is_encoder:
        def encode(params, inputs):
            hidden, _ = forward(params, inputs, cfg)
            return logits_from_hidden(params["embed"], hidden, cfg)
        return encode

    def prefill_step(params, inputs):
        return prefill(params, inputs, cfg, max_len, cache_dtype)

    return prefill_step


def make_decode_step(cfg: ModelConfig, return_logits: bool = True) -> Callable:
    """One decode step: (params, cache, tokens, pos) -> next tokens.

    ``return_logits=False`` is the serving fast path: the greedy argmax is
    all a decode engine reads, so the step never materializes the
    ``(B, vocab)`` logits as an output — a captured per-step graph stays
    free of a full-vocabulary buffer it would otherwise carry every token
    (pinned by an aval check in ``tests/test_decode_serve.py``).
    """
    if not return_logits:
        def greedy_step(params, cache, tokens, pos):
            logits, new_cache = decode_step(params, cache, tokens, pos, cfg)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        return greedy_step

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(params, cache, tokens, pos, cfg)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    return serve_step


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    max_new: int, max_len: int) -> jax.Array:
    """Host-looped greedy decoding for the examples (prefill + N decodes)."""
    b, s = prompt.shape
    logits, cache = prefill(params, {"tokens": prompt}, cfg, max_len)
    step_fn = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        tok, _, cache = step_fn(params, cache, tok, jnp.int32(s + i))
        out.append(tok)
    return jnp.stack(out, axis=1)
