"""The train step: remat + microbatch grad accumulation + AdamW.

Distributed-optimization structure (DESIGN.md §5):

* **remat** — activation checkpointing policy per cell ("none" | "dots" |
  "full"); "dots" keeps matmul outputs (recompute cheap elementwise),
  "full" recomputes everything per scan group;
* **microbatching** — the global batch is split into ``microbatches`` equal
  slices scanned sequentially with an fp32 (or param-dtype) gradient
  accumulator.  Because each microbatch's backward produces *sharded* grad
  shards, GSPMD schedules the FSDP reduce-scatters of microbatch k while
  microbatch k+1's forward computes — compute/comm overlap without manual
  double-buffering;
* **AdamW** with bf16 moments (repro.optim) — the whole TrainState inherits
  parameter sharding, so optimizer update is fully ZeRO-sharded.

The returned step has signature ``step(state, batch) -> (state, metrics)``
and is pure — the launcher jits it with in/out shardings and donation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.params import init_params
from ..models.transformer import model_spec, train_loss
from ..optim import AdamWConfig, adamw_init, adamw_update

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    total_steps: int = 1000
    remat: str = "dots"              # "none" | "dots" | "full" — applied
    #                                  PER LAYER GROUP inside the model scan
    #                                  (see models.transformer._maybe_remat)
    microbatches: int = 1
    param_dtype: str = "float32"     # "bfloat16" for the 398B cell
    adamw: AdamWConfig = AdamWConfig()

    def apply_to(self, cfg: ModelConfig) -> ModelConfig:
        """Model-level execution knobs (remat) live on the ModelConfig."""
        return dataclasses.replace(cfg, remat=self.remat)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> Dict:
    spec = model_spec(cfg)
    params = init_params(spec, key, dtype=jnp.dtype(tcfg.param_dtype))
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    lr_schedule: Callable) -> Callable:
    cfg = tcfg.apply_to(cfg)
    loss_fn = functools.partial(train_loss, cfg=cfg)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        return grads, metrics

    def step(state: Dict, batch: Dict[str, jax.Array]
             ) -> Tuple[Dict, Dict[str, jax.Array]]:
        params, opt = state["params"], state["opt"]
        k = tcfg.microbatches
        if k <= 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def accum(acc, mb):
                g, m = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, m

            # accumulate in the param dtype: fp32 normally; bf16 for the
            # 398B cell where an fp32 grad buffer alone would blow HBM
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            grads, ms = jax.lax.scan(accum, zeros, micro)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)

        lr = lr_schedule(opt["step"])
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt, lr, tcfg.adamw)
        metrics = dict(metrics, **opt_metrics, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return step
