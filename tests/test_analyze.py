"""repro.analyze (ISSUE 10): capture-time graph sanitizer + invariant linter.

Pins the new contracts:

* clean captures — in-order chains, out-of-order DAGs with barriers,
  transfer overwrites, and one graph per built-in kernel family — verify
  with ZERO findings;
* every seeded hazard class (RAW/WAR/WAW race, use-after-donate,
  flag violation, dependency cycle, dead node, double donation) yields its
  expected named diagnostic;
* ``REPRO_VERIFY=1`` raises :class:`GraphVerifyError` at capture seal and
  at GraphCache admission; verification is memoized and perturbs nothing
  (verify-on/off twins are bit-identical, modeled totals equal);
* the AST linter flags each ROADMAP-rule violation (including the exact
  pre-fix ``hash(name)`` form from models/params.py) and runs clean over
  ``src/repro`` — the CI gate, as a test;
* cross-process param-init determinism: ``init_params`` is invariant
  under PYTHONHASHSEED (the CRC-32 satellite's regression).
"""

import dataclasses
import os
import pathlib
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze import (Finding, GraphVerifyError, lint_paths,
                           lint_source, verify_graph)
from repro.core import (APU, EGPU_16T, Buffer, CommandQueue, Context,
                        Device, Kernel, NDRange, Program, Stage)
from repro.core.program import BUILTIN_FAMILIES
from repro.serve.cache import GraphCache

ROOT = pathlib.Path(__file__).resolve().parent.parent
NDR = NDRange((8,), (8,))


def _ctx():
    return Context(Device(EGPU_16T))


def _scale(name="scale", k=2.0):
    return Kernel(name, executor=lambda x: (x * k,))


def _x(shape=(8,), seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


# ---------------------------------------------------------------------------
# duck-typed hazard graphs (hand-built: the runtime API refuses to record
# these, which is exactly why the sanitizer re-derives everything)
# ---------------------------------------------------------------------------
def _node(name, in_slots=(), out_slots=(), deps=(), kind="kernel",
          overwrites=()):
    return SimpleNamespace(kernel=SimpleNamespace(name=name),
                           in_slots=tuple(in_slots),
                           out_slots=tuple(out_slots), deps=tuple(deps),
                           kind=kind, overwrites=tuple(overwrites))


def _graph(nodes, ext=(), flags=None, outputs=None):
    g = SimpleNamespace(nodes=list(nodes), _ext_slots=list(ext),
                        _slot_flags=dict(flags or {}), _ext_values=[])
    if outputs is not None:
        g._output_slots = lambda: tuple(outputs)
    return g


def _codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------------------
# clean graphs
# ---------------------------------------------------------------------------
def test_in_order_chain_verifies_zero_findings():
    ctx = _ctx()
    q = CommandQueue(ctx)
    buf = ctx.create_buffer(_x())
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_scale(), NDR, (buf,))
        q.enqueue_nd_range(_scale("scale2", 3.0), NDR, ev.outputs)
    assert graph.verify() == ()
    # memoized: the same tuple object comes back, no re-walk
    assert graph.verify() is graph.verify()


def test_out_of_order_independent_nodes_are_clean():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(seed=1)), ctx.create_buffer(_x(seed=2))
    with q.capture() as graph:
        ea = q.enqueue_nd_range(_scale("a"), NDR, (a,))
        eb = q.enqueue_nd_range(_scale("b"), NDR, (b,))
        q.enqueue_nd_range(Kernel("sum", executor=lambda x, y: (x + y,)),
                           NDR, (ea.outputs[0], eb.outputs[0]))
    assert graph.verify() == ()


def test_transfer_overwrite_capture_is_clean_and_carries_metadata():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    dst = ctx.create_buffer(_x())
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_scale(), NDR, (dst,))   # reads old value
        q.enqueue_write_buffer(dst, _x(seed=3))          # WAR/WAW recorded
        q.enqueue_nd_range(Kernel("sum", executor=lambda a, b: (a + b,)),
                           NDR, (ev.outputs[0], dst))    # consumes both
    assert graph.verify() == ()
    write = next(n for n in graph.nodes if n.kind == "write")
    assert write.overwrites == (0,)      # the destination's previous slot


@pytest.mark.parametrize("family", sorted(BUILTIN_FAMILIES))
def test_every_builtin_family_captures_clean(family):
    rng = np.random.default_rng(7)
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    inputs = {
        "gemm": (f32(16, 32), f32(32, 8)),
        "fir": (f32(256), f32(16)),
        "delineate": (f32(256),),
        "stockham_fft": (f32(128),),
        "svm": (f32(8, 12), f32(16, 12), f32(16), jnp.float32(0.1)),
        "mamba_scan": (f32(1, 32, 4), jnp.abs(f32(1, 32, 4)) * 0.1,
                       -jnp.abs(f32(4, 2)), f32(1, 32, 2), f32(1, 32, 2),
                       f32(4)),
        "decode_attention": (f32(1, 2, 8), f32(1, 2, 16, 8),
                             f32(1, 2, 16, 8)),
    }[family]
    kern = Program.build(EGPU_16T).create_kernel(family)
    ctx = _ctx()
    # unprofiled: the sweep checks capture structure, and each family's
    # counts() takes family-specific problem sizes this test doesn't model
    q = CommandQueue(ctx, profile=False)
    bufs = tuple(Buffer(jnp.asarray(x)) for x in inputs)
    with q.capture() as graph:
        q.enqueue_nd_range(kern, NDR, bufs)
    assert graph.verify() == ()


# ---------------------------------------------------------------------------
# seeded negatives: each hazard class produces its named diagnostic
# ---------------------------------------------------------------------------
def test_seeded_raw_race_names_both_nodes():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    buf = ctx.create_buffer(_x())
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_scale(), NDR, (buf,))
        q.enqueue_nd_range(_scale("reader"), NDR, ev.outputs)
    # strip the reader's dataflow edge — the bug a hand-rolled capture
    # path could introduce on an out-of-order queue
    graph.nodes[1] = dataclasses.replace(graph.nodes[1], deps=())
    graph._verify_memo.clear()
    (f,) = graph.verify()
    assert f.code == "raw-race"
    assert "#0:scale" in f.message and "#1:reader" in f.message
    assert f.nodes == (0, 1)


def test_seeded_war_race_on_transfer_overwrite():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    dst = ctx.create_buffer(_x())
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_scale(), NDR, (dst,))
        eu = q.enqueue_nd_range(_scale("use"), NDR, ev.outputs)
        q.enqueue_write_buffer(dst, _x(seed=3))
        q.enqueue_nd_range(Kernel("sum", executor=lambda a, b: (a + b,)),
                           NDR, (eu.outputs[0], dst))
    assert graph.verify() == ()
    # strip the write's WAR/WAW ordering edges
    widx = next(i for i, n in enumerate(graph.nodes) if n.kind == "write")
    graph.nodes[widx] = dataclasses.replace(graph.nodes[widx], deps=())
    graph._verify_memo.clear()
    codes = _codes(graph.verify())
    assert "war-race" in codes


def test_waw_race_dual_producers_and_unordered_overwrite():
    # two producers of one slot
    g = _graph([_node("p1", out_slots=(0,)), _node("p2", out_slots=(0,)),
                _node("r", in_slots=(0,), out_slots=(1,), deps=(0, 1))],
               outputs=(1,))
    assert "waw-race" in _codes(verify_graph(g))
    # overwrite unordered against the previous producer
    g2 = _graph([_node("p", out_slots=(1,), in_slots=(0,)),
                 _node("w", kind="write", in_slots=(2,), out_slots=(3,),
                       overwrites=(1,)),
                 _node("r", in_slots=(3,), out_slots=(4,), deps=(0, 1))],
                ext=[0, 2], outputs=(4,))
    assert "waw-race" in _codes(verify_graph(g2))


def test_use_after_donate_reader_off_the_ordered_path():
    # node "stray" reads donated ext slot 0 but nothing downstream of it is
    # returned — unordered against the realize-then-drain boundary
    g = _graph([_node("stray", in_slots=(0,), out_slots=(2,)),
                _node("main", in_slots=(1,), out_slots=(3,))],
               ext=[0, 1], outputs=(3,))
    codes = _codes(verify_graph(g, donate=(0,)))
    assert "use-after-donate" in codes
    # same graph, nothing donated: a stray concurrent sink is legal
    assert "use-after-donate" not in _codes(verify_graph(g))
    # and a reader ON the ordered path is fine
    g2 = _graph([_node("a", in_slots=(0,), out_slots=(1,)),
                 _node("b", in_slots=(1,), out_slots=(2,), deps=(0,))],
                ext=[0], outputs=(2,))
    assert verify_graph(g2, donate=(0,)) == ()


def test_double_donation_is_flagged():
    g = _graph([_node("a", in_slots=(0, 1), out_slots=(2,))],
               ext=[0, 1], outputs=(2,))
    assert "double-donation" in _codes(verify_graph(g, donate=(0, 0)))
    leaf = jnp.ones((4,))
    g._ext_values = [leaf, leaf]
    assert "double-donation" in _codes(verify_graph(g, donate=(0, 1)))


def test_flag_violations_are_named():
    # kernel reading a write-only slot
    g = _graph([_node("k", in_slots=(0,), out_slots=(1,))],
               ext=[0], flags={0: "w"}, outputs=(1,))
    (f,) = verify_graph(g)
    assert f.code == "flag-violation" and "write-only" in f.message
    # write landing in a read-only buffer
    g2 = _graph([_node("w", kind="write", in_slots=(0,), out_slots=(1,)),
                 _node("k", in_slots=(1,), out_slots=(2,), deps=(0,))],
                ext=[0], flags={1: "r"}, outputs=(2,))
    codes = _codes(verify_graph(g2))
    assert "flag-violation" in codes


def test_dependency_cycle_is_reported():
    g = _graph([_node("a", in_slots=(0,), out_slots=(1,), deps=(1,)),
                _node("b", in_slots=(1,), out_slots=(2,), deps=(0,))],
               ext=[0], outputs=(2,))
    (f,) = verify_graph(g)
    assert f.code == "dependency-cycle"
    assert "#0:a" in f.message and "#1:b" in f.message


def test_dead_node_is_reported():
    # A dependent-free sink on a concurrent queue is a legitimate stream
    # tail (live); dead is work whose only ordering dead-ends in a sync
    # sink nobody else consumes.
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(seed=1)), ctx.create_buffer(_x(seed=2))
    with q.capture() as graph:
        ev = q.enqueue_nd_range(_scale("dead"), NDR, (a,))
        q.enqueue_marker(wait_events=[ev])              # sync dead end
        q.enqueue_nd_range(_scale("live"), NDR, (b,))   # defines the output
    (f,) = graph.verify()
    assert f.code == "dead-node" and "#0:dead" in f.message
    # the concurrent-sink twin (no marker) is clean: both launches are
    # independent stream tails, only enqueue order picks the returned one
    q2 = CommandQueue(ctx, out_of_order=True)
    with q2.capture() as g2:
        q2.enqueue_nd_range(_scale("t0"), NDR, (a,))
        q2.enqueue_nd_range(_scale("t1"), NDR, (b,))
    assert g2.verify() == ()


# ---------------------------------------------------------------------------
# REPRO_VERIFY wiring: loud at capture seal, at cache admission, at
# donating launches — and zero perturbation either way
# ---------------------------------------------------------------------------
def test_env_mode_raises_at_capture_seal(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    buf = ctx.create_buffer(_x())
    with pytest.raises(GraphVerifyError, match="raw-race"):
        with q.capture() as graph:
            ev = q.enqueue_nd_range(_scale(), NDR, (buf,))
            q.enqueue_nd_range(_scale("reader"), NDR, ev.outputs)
            # seed the race inside the capture body: __exit__ verifies
            graph.nodes[1] = dataclasses.replace(graph.nodes[1], deps=())
    # clean captures seal fine under the same env
    q2 = CommandQueue(ctx)
    with q2.capture() as g2:
        q2.enqueue_nd_range(_scale(), NDR, (buf,))
    assert g2.verify() == ()


def test_graph_cache_verifies_every_miss_and_counts():
    apu = APU(EGPU_16T)
    cache = GraphCache(capacity=4)
    stages = [Stage(_scale())]
    x = _x()
    _, hit = cache.get_or_capture(apu, stages, (x,))
    assert not hit
    _, hit = cache.get_or_capture(apu, stages, (x,))
    assert hit
    stats = cache.stats()
    assert stats["verified"] == stats["misses"] == 1
    assert stats["findings"] == 0


def test_verify_on_off_twins_are_bit_identical(monkeypatch):
    from repro.core.machine import WorkCounts

    def run():
        kern = Kernel("cs", executor=lambda x: (x * 2.0,),
                      counts=lambda **kw: WorkCounts(
                          ops=64.0, dcache_bytes=256.0, host_bytes=256.0,
                          working_set=256.0))
        apu = APU(EGPU_16T)
        (out,), report = apu.offload([Stage(kern), Stage(kern)], (_x(),))
        return np.asarray(out.data), report.egpu_fused.total_s

    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    out_off, modeled_off = run()
    monkeypatch.setenv("REPRO_VERIFY", "1")
    out_on, modeled_on = run()
    assert np.array_equal(out_off, out_on)
    assert modeled_off == modeled_on


def test_donating_launch_verifies_under_env(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    ctx = _ctx()
    q = CommandQueue(ctx)
    buf = ctx.create_buffer(_x())
    with q.capture() as graph:
        q.enqueue_nd_range(_scale(), NDR, (buf,))
    (out,) = graph.launch(_x(seed=5), donate=(0,))
    assert out.data.shape == (8,)


# ---------------------------------------------------------------------------
# invariant linter
# ---------------------------------------------------------------------------
def test_linter_flags_the_old_params_hash_form():
    src = ("import jax\n"
           "def init(key, name):\n"
           "    return jax.random.fold_in(key, hash(name) % (2 ** 31))\n")
    (f,) = lint_source(src, "src/repro/models/params.py")
    assert f.rule == "no-builtin-hash" and f.line == 3


def test_linter_wall_clock_rule():
    assert [f.rule for f in lint_source(
        "import time\nt = time.time()\n", "src/repro/launch/x.py")] \
        == ["wall-clock"]
    # perf_counter: banned in modeled-accounting modules only
    assert [f.rule for f in lint_source(
        "import time\nt = time.perf_counter()\n",
        "src/repro/core/machine.py")] == ["wall-clock"]
    assert lint_source("import time\nt = time.perf_counter()\n",
                       "src/repro/serve/x.py") == []
    # referencing (not calling) perf_counter is the injected-clock idiom
    assert lint_source(
        "import time\ndef f(clock=time.perf_counter):\n    return clock()\n",
        "src/repro/core/machine.py") == []


def test_linter_tracer_guard_rule():
    bad = "class A:\n    def f(self):\n        self.tracer.instant('x')\n"
    (f,) = lint_source(bad, "src/repro/serve/x.py")
    assert f.rule == "tracer-guard"
    good = ("class A:\n"
            "    def f(self, rid):\n"
            "        if self.tracer is not None and rid is not None:\n"
            "            self.tracer.instant('x')\n"
            "    def _trace_launch(self):\n"
            "        self._tracer.span('y')\n")
    assert lint_source(good, "src/repro/serve/x.py") == []


def test_linter_registry_kernel_rule():
    bad = "k = Kernel('adhoc', executor=f)\n"
    (f,) = lint_source(bad, "src/repro/serve/x.py")
    assert f.rule == "registry-kernels"
    good = ("@kernel_family('g')\n"
            "def build_kernel(cfg):\n"
            "    return Kernel('g', executor=f)\n")
    assert lint_source(good, "src/repro/kernels/g/ops.py") == []
    # the batching adapter re-wraps an existing kernel: allowlisted
    assert lint_source(bad, "src/repro/serve/batching.py") == []


def test_linter_bench_history_rule():
    bad = ("import json\n"
           "OUT = 'BENCH_serve.json'\n"
           "json.dump({}, open(OUT, 'w'))\n")
    fs = lint_source(bad, "benchmarks/bench_x.py")
    assert fs and all(f.rule == "bench-history" for f in fs)
    assert lint_source(bad, "benchmarks/history.py") == []


def test_linter_is_clean_over_src_repro():
    findings = lint_paths([ROOT / "src" / "repro"])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exits_clean_over_src_repro():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "src/repro"],
        cwd=ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


# ---------------------------------------------------------------------------
# satellite: cross-process param-init determinism (PYTHONHASHSEED twins)
# ---------------------------------------------------------------------------
def test_init_params_invariant_under_pythonhashseed(tmp_path):
    code = (
        "import sys\n"
        "import jax\n"
        "import numpy as np\n"
        "from repro.models.params import ParamSpec, init_params\n"
        "spec = {'w': ParamSpec((4, 4), ('embed', 'mlp')),\n"
        "        'b': ParamSpec((4,), (None,))}\n"
        "p = init_params(spec, jax.random.PRNGKey(0))\n"
        "np.save(sys.argv[1], np.asarray(p['w']))\n")
    outs = []
    for seed in ("0", "4242"):
        out = tmp_path / f"w_{seed}.npy"
        env = {**os.environ, "PYTHONHASHSEED": seed, "PYTHONPATH": "src"}
        subprocess.run([sys.executable, "-c", code, str(out)],
                       cwd=ROOT, check=True, env=env)
        outs.append(np.load(out))
    # builtin hash() would differ between these processes; CRC-32 must not
    assert np.array_equal(outs[0], outs[1])
