"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned archs instantiates its family-preserving REDUCED
config (ModelConfig.reduced(): small widths/depths/experts, same block
structure) and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStructs, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells, input_specs
from repro.data import DataConfig, SyntheticLMData
from repro.models import init_params, model_spec, train_loss
from repro.models.transformer import decode_step, forward, prefill
from repro.optim import adamw_init, constant_schedule
from repro.train.step import TrainConfig, make_train_step

ARCH_IDS = sorted(ARCHS)
B, S = 2, 32


def _reduced(arch):
    cfg = ARCHS[arch].reduced()
    # generous capacity so tiny-batch MoE routing doesn't drop tokens
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    return cfg


def _batch(cfg, key=0):
    data = SyntheticLMData(DataConfig(B, S, cfg.vocab, seed=key), cfg)
    return {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden, aux = forward(params, batch, cfg)
    exp_s = S + (cfg.n_prefix_embed if cfg.frontend == "vision" else 0)
    assert hidden.shape == (B, exp_s, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    assert np.isfinite(np.asarray(aux)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    tcfg = TrainConfig(peak_lr=1e-3, remat="none", microbatches=1)
    step = jax.jit(make_train_step(cfg, tcfg, constant_schedule(1e-3)))
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    before = jax.tree_util.tree_leaves(params)[3]
    after = jax.tree_util.tree_leaves(state["params"])[3]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not ARCHS[a].is_encoder])
def test_prefill_then_decode(arch):
    cfg = _reduced(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab,
                                                         (B, S)), jnp.int32)
    inputs = {"tokens": toks}
    if cfg.frontend == "vision":
        inputs["patches"] = jnp.asarray(
            np.random.default_rng(1).standard_normal(
                (B, cfg.n_prefix_embed, 1152)), jnp.float32)
    logits, cache = prefill(params, inputs, cfg, max_len=S + 8,
                            cache_dtype=jnp.float32)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = S + (cfg.n_prefix_embed if cfg.frontend == "vision" else 0)
    logits2, cache = decode_step(params, cache, nxt, jnp.int32(pos), cfg)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_does_not_change_loss(arch):
    cfg = _reduced(arch)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    base, _ = train_loss(params, batch, cfg)
    rema, _ = train_loss(params, batch,
                         dataclasses.replace(cfg, remat="full"))
    np.testing.assert_allclose(float(base), float(rema), rtol=2e-5)


def test_cell_grid_counts():
    """40 nominal cells; 31 live after the documented skips."""
    assert len(cells(include_skipped=True)) == 40
    live = cells()
    assert len(live) == 31
    # long_500k only for sub-quadratic archs
    for arch, shape, _ in live:
        if shape == "long_500k":
            assert arch in ("jamba-1.5-large-398b", "rwkv6-3b")


@pytest.mark.parametrize("arch,shape", [(a, s) for a, s, _ in cells()])
def test_input_specs_are_abstract(arch, shape):
    specs = input_specs(arch, shape)
    for name, st in specs.items():
        assert isinstance(st, jax.ShapeDtypeStruct), name
    kind = SHAPES[shape].kind
    if kind == "decode":
        assert specs["tokens"].shape == (SHAPES[shape].global_batch,)
    else:
        key = "frames" if ARCHS[arch].frontend == "audio" else "tokens"
        assert specs[key].shape[0] == SHAPES[shape].global_batch


def test_param_counts_match_published():
    published = {
        "jamba-1.5-large-398b": 398e9,
        "deepseek-v2-236b": 236e9,
        "mistral-large-123b": 123e9,
        "rwkv6-3b": 3.1e9,
        "qwen2.5-3b": 3.1e9,
        "minicpm-2b": 2.7e9,
        "paligemma-3b": 2.5e9,     # language tower (vision is stubbed)
        "stablelm-1.6b": 1.6e9,
    }
    for arch, target in published.items():
        got = ARCHS[arch].param_count()
        assert abs(got - target) / target < 0.08, (arch, got, target)
