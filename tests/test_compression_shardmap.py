"""compressed_psum under shard_map: correctness on a real (1-device) mesh
and int8-wire verification on the lowered multipod HLO."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import compressed_psum
from repro.launch.mesh import make_host_mesh


def test_compressed_psum_single_participant_exact():
    """N=1: the mean equals the dequantized local grad (within 1 LSB)."""
    mesh = make_host_mesh()
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)),
                          jnp.float32)}
    e = {"w": jnp.zeros((8, 128), jnp.float32)}

    def body(gg, ee):
        return compressed_psum(gg, ee, "data")

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P()), out_specs=(P(), P()),
                   check_rep=False)
    out, err = fn(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(err["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-5)


def test_compressed_wire_is_int8_in_jaxpr():
    """The gathered collective payload is int8, not f32 (a 1-device mesh
    elides the gather in HLO, so inspect the jaxpr)."""
    mesh = make_host_mesh()
    g = jnp.zeros((1024,), jnp.float32)
    e = jnp.zeros((1024,), jnp.float32)

    def body(gg, ee):
        return compressed_psum({"w": gg}, {"w": ee}, "data")

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P()), check_rep=False)
    jaxpr = str(jax.make_jaxpr(fn)(g, e))
    assert "all_gather" in jaxpr
    # the big gathered operand is int8; only the (1,)-scale gathers are f32
    import re
    ops = re.findall(r"(\w+)\[[^\]]*1024[^\]]*\] = all_gather", jaxpr)
    assert ops and all(o == "i8" for o in ops), jaxpr[:800]
