"""Core (Tiny-OpenCL execution model) unit + hypothesis property tests."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")      # not baked into every image
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (EGPU_4T, EGPU_8T, EGPU_16T,
                        EGPUConfig, KernelKnobs, NDRange, WorkCounts,
                        check_vmem_budget, crop_from_groups, egpu_time,
                        host_time, pad_to_groups, schedule)
from repro.core.scheduler import optimal_ndrange


# ---------------------------------------------------------------------------
# NDRange properties
# ---------------------------------------------------------------------------
@given(g=st.integers(1, 10_000), l=st.integers(1, 512))
def test_ndrange_group_coverage(g, l):
    """Work-groups cover all work-items with less than one group of slack."""
    ndr = NDRange((g,), (l,))
    (ng,) = ndr.num_groups
    assert ng * l >= g
    assert (ng - 1) * l < g
    assert ndr.total_work_items == g


@settings(deadline=None, max_examples=30)
@given(g0=st.integers(1, 500), g1=st.integers(1, 500),
       l0=st.integers(1, 32), l1=st.integers(1, 32))
def test_ndrange_2d_padding_roundtrip(g0, g1, l0, l1):
    ndr = NDRange((g0, g1), (l0, l1))
    x = jnp.arange(g0 * 3, dtype=jnp.float32).reshape(g0, 3)
    padded = pad_to_groups(x, ndr, axis=0)
    assert padded.shape[0] == ndr.padded_size[0]
    np.testing.assert_array_equal(crop_from_groups(padded, ndr, axis=0), x)


@given(items=st.integers(1, 100_000),
       cus=st.integers(1, 4), threads=st.sampled_from([1, 2, 4, 8, 16]),
       warps=st.integers(1, 8))
def test_scheduler_invariants(items, cus, threads, warps):
    """Paper §V-B: every work-item lands on a slot; occupancy in (0, 1];
    iterations = ceil(items / total slots)."""
    cfg = EGPUConfig(compute_units=cus, threads_per_cu=threads,
                     warps_per_cu=warps)
    ndr = NDRange((items,), (threads,))
    s = schedule(ndr, cfg)
    assert s.iterations == math.ceil(items / cfg.total_threads)
    assert 0.0 < s.occupancy <= 1.0
    # scheduling cost is monotone in iterations (the paper's linear model)
    s2 = schedule(NDRange((items + cfg.total_threads,), (threads,)), cfg)
    assert s2.scheduling_cycles >= s.scheduling_cycles


def test_optimal_ndrange_single_iteration():
    """§VIII-B trick: work-items == hardware threads → 1 iteration, so the
    scheduling overhead is the constant ~25 us the paper reports."""
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        ndr = optimal_ndrange(1_000_000, cfg)
        s = schedule(ndr, cfg)
        assert s.iterations == 1
        assert s.occupancy == 1.0


# ---------------------------------------------------------------------------
# Config validation / presets
# ---------------------------------------------------------------------------
def test_presets_match_paper_table_iii():
    assert EGPU_4T.parallel_lanes == 4
    assert EGPU_8T.parallel_lanes == 8
    assert EGPU_16T.parallel_lanes == 16
    for cfg in (EGPU_4T, EGPU_8T, EGPU_16T):
        assert cfg.compute_units == 2
        assert cfg.warps_per_cu == 4
        assert cfg.icache_bytes_per_cu == 2048
        assert cfg.dcache_bytes == 16 * 1024
        assert cfg.dcache_line_bytes == 4 * cfg.threads_per_cu
    assert (EGPU_4T.dcache_banks, EGPU_8T.dcache_banks,
            EGPU_16T.dcache_banks) == (2, 4, 8)


def test_config_validation_rejects_bad():
    with pytest.raises(ValueError):
        EGPUConfig(dcache_bytes=1000).validate()          # not a power of 2
    with pytest.raises(ValueError):
        EGPUConfig(compute_units=0).validate()
    with pytest.raises(ValueError):
        EGPUConfig(dcache_line_bytes=6).validate()


def test_vmem_budget_check():
    knobs = KernelKnobs(vmem_budget_bytes=1 << 20, pipeline_depth=2)
    check_vmem_budget(knobs, 1 << 18)                     # fits
    with pytest.raises(ValueError):
        check_vmem_budget(knobs, 1 << 20)                 # 2x depth blows it


@given(threads=st.sampled_from([1, 2, 4, 8, 16]))
def test_knob_projection_monotone(threads):
    """More e-GPU threads → wider lane tiles; more warps → deeper pipeline."""
    base = EGPUConfig(threads_per_cu=threads).tpu_knobs()
    wider = EGPUConfig(threads_per_cu=threads * 2).tpu_knobs()
    assert wider.lane_tile >= base.lane_tile
    deeper = EGPUConfig(warps_per_cu=8).tpu_knobs()
    assert deeper.pipeline_depth >= EGPUConfig(warps_per_cu=2).tpu_knobs().pipeline_depth


# ---------------------------------------------------------------------------
# Machine model structure
# ---------------------------------------------------------------------------
def _counts(ops=1e6, dc=1e5, host=1e4, ws=1e3, barriers=0, div=0.0):
    return WorkCounts(ops=ops, dcache_bytes=dc, host_bytes=host,
                      working_set=ws, barriers=barriers, divergence=div)


def test_more_threads_never_slower():
    ndr = optimal_ndrange(10_000, EGPU_4T)
    c = _counts()
    times = [egpu_time(cfg, c, optimal_ndrange(10_000, cfg)).total_s
             for cfg in (EGPU_4T, EGPU_8T, EGPU_16T)]
    assert times[0] >= times[1] >= times[2]


def test_divergence_and_barriers_cost():
    ndr = optimal_ndrange(10_000, EGPU_16T)
    base = egpu_time(EGPU_16T, _counts(), ndr).total_s
    div = egpu_time(EGPU_16T, _counts(div=1.0), ndr).total_s
    bar = egpu_time(EGPU_16T, _counts(barriers=100), ndr).total_s
    assert div > base and bar > base


def test_capacity_inflation_when_ws_exceeds_dcache():
    ndr = optimal_ndrange(10_000, EGPU_16T)
    small = egpu_time(EGPU_16T, _counts(ws=1e3), ndr)
    big = egpu_time(EGPU_16T, _counts(ws=1e6), ndr)
    assert big.transfer > small.transfer * 2


def test_host_has_no_offload_overheads():
    t = host_time(_counts())
    assert t.startup == t.scheduling == t.transfer == 0.0
