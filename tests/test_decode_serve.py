"""Greedy decode across the three cache families (repro.train.serve).

The old ``examples/serve_lm.py`` was the only executable coverage of
``greedy_generate`` and the per-family decode caches; when that example was
repurposed for the ``repro.serve`` engine (ISSUE 2), this test inherited
the coverage: a GQA transformer (plain KV cache), the MLA+MoE family
(compressed latent cache) and the attention-free rwkv6 (O(1) state) all
decode through one serving API.

ISSUE 9 layers the continuous-batching :class:`DecodeEngine` on top and
pins its invariants here: slot-based decode is bit-identical to whole-batch
``greedy_generate`` for every cache family — including staggered
mid-generation insertion and slot reuse — off exactly ONE cached decode
graph (plus one prefill graph), whose step outputs carry no ``(B, vocab)``
logits; the :class:`~repro.serve.Server` streaming front and the asyncio
HTTP ingress deliver the same bits.
"""

import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params, model_spec
from repro.obs import Tracer
from repro.serve import DecodeEngine, EngineHTTPServer, Server
from repro.train.serve import greedy_generate

BATCH, PROMPT, NEW = 2, 12, 4

FAMILIES = ["qwen2.5-3b",       # GQA: plain KV cache
            "deepseek-v2-236b",  # MLA latent cache
            "rwkv6-3b"]          # O(1) recurrent state


def _setup(arch, batch, prompt_len, seed=1):
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(seed).integers(0, cfg.vocab,
                                             (batch, prompt_len)),
        jnp.int32)
    return cfg, params, prompts


@pytest.mark.parametrize("arch", FAMILIES)
def test_greedy_generate_cache_family(arch):
    cfg, params, prompts = _setup(arch, BATCH, PROMPT)
    out = greedy_generate(params, cfg, prompts, max_new=NEW,
                          max_len=PROMPT + NEW + 1)
    assert out.shape == (BATCH, NEW)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))
    # greedy decoding is deterministic
    again = greedy_generate(params, cfg, prompts, max_new=NEW,
                            max_len=PROMPT + NEW + 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(again))


# -- ISSUE 9: the continuous-batching decode engine -------------------------

@pytest.mark.parametrize("arch", FAMILIES)
def test_engine_bit_identical_per_family(arch):
    """Engine slots == whole-batch greedy_generate, bit for bit, for every
    cache family — off exactly one prefill + one decode graph."""
    cfg, params, prompts = _setup(arch, BATCH, PROMPT)
    max_len = PROMPT + NEW + 1
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=NEW,
                                     max_len=max_len))
    eng = DecodeEngine(cfg, params, num_slots=BATCH, max_len=max_len)
    state = eng.init_state()
    for i in range(BATCH):
        state = eng.insert(eng.prefill(None, prompts[i]), state, slot=i)
    got = [np.asarray(state.tokens)]          # token 1 comes from prefill
    for _ in range(NEW - 1):
        state, toks = eng.generate(None, state)
        got.append(toks)
    np.testing.assert_array_equal(np.stack(got, axis=1), ref)
    # zero re-capture: ONE prefill graph + ONE decode graph, period
    assert eng.cache.misses == 2
    assert eng.cache.hits == (BATCH - 1) + (NEW - 2)


def test_engine_staggered_insert_and_slot_reuse():
    """A request spliced into a freed slot mid-generation decodes the same
    bits as the whole-batch reference, and never perturbs its neighbor."""
    cfg, params, prompts = _setup("qwen2.5-3b", 3, PROMPT)
    new_long = 6
    max_len = PROMPT + new_long + 1
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=new_long,
                                     max_len=max_len))
    eng = DecodeEngine(cfg, params, num_slots=2, max_len=max_len)
    state = eng.init_state()
    # r0 (short) and r1 (long) start together in slots 0/1
    state = eng.insert(eng.prefill(None, prompts[0]), state, slot=0)
    state = eng.insert(eng.prefill(None, prompts[1]), state, slot=1)
    out = {0: [int(state.tokens[0])], 1: [int(state.tokens[1])]}
    for _ in range(2):
        state, toks = eng.generate(None, state)
        out[0].append(int(toks[0]))
        out[1].append(int(toks[1]))
    # r0 finishes after 3 tokens; its slot is reused by r2 mid-generation
    state = eng.release(state, 0)
    state = eng.insert(eng.prefill(None, prompts[2]), state, slot=0)
    out[2] = [int(state.tokens[0])]
    for _ in range(new_long - 3):
        state, toks = eng.generate(None, state)
        out[2].append(int(toks[0]))
        out[1].append(int(toks[1]))
    assert out[0] == list(ref[0][:3])
    assert out[1] == list(ref[1])            # neighbor never perturbed
    assert out[2] == list(ref[2][:new_long - 2])
    assert eng.cache.misses == 2             # still just two graphs


def test_engine_decode_graph_carries_no_logits():
    """The per-step graph's outputs are tokens + cache only — no
    ``(num_slots, vocab)`` logits ride the hot decode loop."""
    cfg, params, prompts = _setup("qwen2.5-3b", 1, PROMPT)
    eng = DecodeEngine(cfg, params, num_slots=2, max_len=PROMPT + 4)
    state = eng.insert(eng.prefill(None, prompts[0]), eng.init_state(), 0)
    state, _ = eng.generate(None, state)
    assert eng.decode_graph is not None
    for aval in eng.decode_graph.out_avals:
        assert not (len(aval.shape) >= 2
                    and aval.shape[0] == eng.num_slots
                    and aval.shape[-1] == cfg.vocab_padded), (
            f"decode step leaked a logits-shaped output {aval.shape}")
    # roofline comes straight off the captured schedule
    roof = eng.roofline()
    assert roof is not None and roof.bytes_per_step > 0
    assert 0.0 <= roof.mem_bound_fraction <= 1.0


def test_server_engine_streaming_front():
    """submit_decode/stream round-trip: bit-identical results, slot churn
    across more requests than slots, exactly one terminal span per rid."""
    cfg, params, prompts = _setup("qwen2.5-3b", 3, PROMPT)
    max_len = PROMPT + NEW + 1
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=NEW,
                                     max_len=max_len))
    tracer = Tracer()
    eng = DecodeEngine(cfg, params, num_slots=2, max_len=max_len)
    srv = Server((), workers=(), engine=eng, tracer=tracer)
    rids = [srv.submit_decode(prompts[i], max_new=NEW) for i in range(3)]
    # streaming one rid to completion drives the other slots forward too
    assert list(srv.stream(rids[0])) == [int(t) for t in ref[0]]
    srv.flush()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(np.asarray(srv.result(rid)[0]), ref[i])
    rep = srv.report()
    # decode steps produce NEW-1 tokens/request (token 1 is prefill's)
    assert rep.engine_tokens == 3 * (NEW - 1)
    assert rep.engine_steps > 0 and rep.engine_tokens_per_s_modeled > 0
    assert 0.0 < rep.engine_slot_occupancy <= 1.0
    assert "engine" in rep.summary()
    # every accepted rid terminates in exactly one result/shed span
    for rid in rids:
        root = tracer.request_root(rid)
        terms = [s for s in tracer.children(root)
                 if s.name in ("result", "shed")]
        assert len(terms) == 1


def test_http_ingress_smoke():
    """The asyncio front door streams the same bits over chunked HTTP."""
    cfg, params, prompts = _setup("qwen2.5-3b", 2, PROMPT)
    max_len = PROMPT + NEW + 1
    ref = np.asarray(greedy_generate(params, cfg, prompts, max_new=NEW,
                                     max_len=max_len))
    eng = DecodeEngine(cfg, params, num_slots=2, max_len=max_len)
    srv = Server((), workers=(), engine=eng)
    front = EngineHTTPServer(srv)

    async def post(host, port, prompt, max_new):
        reader, writer = await asyncio.open_connection(host, port)
        body = json.dumps({"prompt": [int(t) for t in prompt],
                           "max_new": max_new}).encode()
        writer.write(b"POST /generate HTTP/1.1\r\nHost: t\r\n"
                     + f"Content-Length: {len(body)}\r\n\r\n".encode()
                     + body)
        await writer.drain()
        head = await reader.readuntil(b"\r\n\r\n")
        status = int(head.split(b" ")[1])
        toks = []
        while status == 200:
            n = int((await reader.readuntil(b"\r\n")).strip(), 16)
            if n == 0:
                break
            toks.append(int((await reader.readexactly(n + 2))[:-2]))
        writer.close()
        return status, toks

    async def run():
        host, port = await front.start()
        try:
            results = await asyncio.gather(
                *[post(host, port, prompts[i], NEW) for i in range(2)])
            bad = await post(host, port, [], NEW)    # empty prompt -> 400
            return results, bad
        finally:
            await front.stop()

    results, bad = asyncio.run(run())
    for i, (status, toks) in enumerate(results):
        assert status == 200
        assert toks == [int(t) for t in ref[i]]
    assert bad[0] == 400
