"""Greedy decode across the three cache families (repro.train.serve).

The old ``examples/serve_lm.py`` was the only executable coverage of
``greedy_generate`` and the per-family decode caches; when that example was
repurposed for the ``repro.serve`` engine (ISSUE 2), this test inherited
the coverage: a GQA transformer (plain KV cache), the MLA+MoE family
(compressed latent cache) and the attention-free rwkv6 (O(1) state) all
decode through one serving API.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params, model_spec
from repro.train.serve import greedy_generate

BATCH, PROMPT, NEW = 2, 12, 4


@pytest.mark.parametrize("arch", ["qwen2.5-3b",      # GQA: plain KV cache
                                  "deepseek-v2-236b",  # MLA latent cache
                                  "rwkv6-3b"])         # O(1) recurrent state
def test_greedy_generate_cache_family(arch):
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (BATCH, PROMPT)),
        jnp.int32)
    out = greedy_generate(params, cfg, prompts, max_new=NEW,
                          max_len=PROMPT + NEW + 1)
    assert out.shape == (BATCH, NEW)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_padded)))
    # greedy decoding is deterministic
    again = greedy_generate(params, cfg, prompts, max_new=NEW,
                            max_len=PROMPT + NEW + 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(again))
