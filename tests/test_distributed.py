"""Sharding-rule, elastic-reshard and hlo-cost analyzer tests.

These run on the single CPU device (rules resolve against small meshes
via jax.make_mesh with 1 device, or pure spec logic with mesh=None).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.elastic import replicate, reshard_arrays
from repro.distributed.sharding import (SERVE_RULES, TRAIN_FSDP_RULES,
                                        TRAIN_RULES, spec_for,
                                        train_rules_for)
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Duck-typed mesh for spec logic tests (axis sizes only)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self._shape = tuple(axes.values())

    @property
    def devices(self):
        import numpy as _np
        return _np.empty(self._shape, object)


POD = FakeMesh(data=16, model=16)
MULTI = FakeMesh(pod=2, data=16, model=16)


# ---------------------------------------------------------------------------
# spec_for
# ---------------------------------------------------------------------------
def test_basic_2d_weight_spec():
    s = spec_for(("embed", "mlp"), TRAIN_RULES, POD, (8192, 28672))
    assert s == P("data", "model")


def test_divisibility_fallback_replicates():
    # 36 heads do not divide 16 → replicated head dim
    s = spec_for(("batch", "kv_heads", None, None), TRAIN_RULES, POD,
                 (256, 36, 4096, 64))
    assert s == P(("pod", "data") if "pod" in POD.axis_names else "data")


def test_progressive_fallback_drops_trailing_axes():
    # batch 256 over (data, model, pod)=512 → drop pod, keep 256
    s = spec_for(("batch",), TRAIN_FSDP_RULES, MULTI, (256,))
    assert s == P(("data", "model"))
    # batch 128 → can't do 256 → drops to (data,)=16... 128 % 32 == 0
    # (spec_for collapses a singleton axis tuple to the bare axis name —
    # P("data") and P(("data",)) describe the same sharding)
    s2 = spec_for(("batch",), TRAIN_FSDP_RULES, MULTI, (128,))
    assert s2 == P("data")


def test_axis_dedup_within_spec():
    # batch takes (data, model); vocab ("model") must not reuse "model"
    s = spec_for(("batch", "vocab"), TRAIN_FSDP_RULES, POD, (256, 102400))
    assert s == P(("data", "model"))


def test_pod_axis_pruned_on_single_pod_mesh():
    s = spec_for(("batch", None), TRAIN_RULES, POD, (256, 4096))
    assert s == P("data")


def test_rules_selector():
    assert train_rules_for(int(1e9)) is TRAIN_FSDP_RULES
    assert train_rules_for(int(1e11)) is TRAIN_RULES


def test_serve_rules_shard_kv_seq():
    s = spec_for(("batch", None, "kv_seq", None), SERVE_RULES, POD,
                 (128, 8, 32768, 128))
    assert s == P("data", None, "model")


# ---------------------------------------------------------------------------
# Elastic resharding (1-device → 1-device is the degenerate exact case)
# ---------------------------------------------------------------------------
def test_reshard_roundtrip():
    mesh = make_host_mesh()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = reshard_arrays(tree, sh)
    np.testing.assert_array_equal(out["w"], tree["w"])
    rep = replicate(tree, mesh)
    np.testing.assert_array_equal(rep["w"], tree["w"])


# ---------------------------------------------------------------------------
# HLO cost analyzer (the dry-run profiler)
# ---------------------------------------------------------------------------
def test_scan_flops_multiplied_by_trip_count():
    def one(x, w):
        return jnp.dot(x, w)

    def scanned(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w1 = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    wN = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    f1 = analyze_hlo(jax.jit(one).lower(x, w1).compile().as_text())
    fN = analyze_hlo(jax.jit(scanned).lower(x, wN).compile().as_text())
    assert fN["flops"] / f1["flops"] == pytest.approx(7.0, rel=0.01)


def test_dot_flops_exact():
    def f(a, b):
        return jnp.dot(a, b)
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    got = analyze_hlo(jax.jit(f).lower(a, b).compile().as_text())["flops"]
    assert got == pytest.approx(2 * 64 * 128 * 32, rel=0.05)


def test_dus_not_counted_as_full_buffer():
    """A scan DUS-accumulating into a big stack must cost slice bytes."""
    def f(xs):
        buf = jnp.zeros((64, 128, 128), jnp.float32)

        def body(b, i):
            return jax.lax.dynamic_update_slice(
                b, xs[i][None], (i, 0, 0)), None
        buf, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return buf

    xs = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)
    got = analyze_hlo(jax.jit(f).lower(xs).compile().as_text())
    full_buffer = 64 * 128 * 128 * 4
    # 64 iterations x O(slice) — far below 64 x full buffer
    assert got["bytes_accessed"] < 10 * full_buffer


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return ci, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    got = analyze_hlo(jax.jit(f).lower(x, ws).compile().as_text())
    # 15 matmuls total
    assert got["flops"] >= 15 * 2 * 128**3 * 0.95
    assert got["transcendentals"] >= 15 * 128 * 128 * 0.95


def test_analyzer_parses_all_dryrun_artifacts():
    import glob
    import json
    files = glob.glob("artifacts/dryrun/*.json")
    if not files:
        pytest.skip("no dry-run artifacts present")
    for f in files[:10]:
        rec = json.load(open(f))
        assert rec["cost"]["flops"] > 0
        assert rec["cost"]["unknown_trip_counts"] == 0, f
