"""Integration tests: TinyBio end-to-end, train loop, failure/restart."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.tinybio import TINYBIO_WORKLOAD, run_tinybio, synth_signal
from repro.configs import ARCHS
from repro.core import EGPU_4T, EGPU_16T
from repro.train.step import TrainConfig
from repro.launch.train import train_loop


# ---------------------------------------------------------------------------
# TinyBio end-to-end on the APU
# ---------------------------------------------------------------------------
def test_tinybio_pipeline_functional():
    decisions, report = run_tinybio(EGPU_16T)
    assert decisions.shape == (TINYBIO_WORKLOAD["n_windows"],)
    assert np.isfinite(np.asarray(decisions)).all()
    # the modeled comparison carries all four stages
    assert len(report.stages) == 4
    assert report.overall_speedup > 3.0
    assert report.overall_energy_reduction > 1.4


def test_tinybio_speedup_grows_with_config():
    _, r4 = run_tinybio(EGPU_4T)
    _, r16 = run_tinybio(EGPU_16T)
    assert r16.overall_speedup > r4.overall_speedup


def test_tinybio_results_identical_across_configs():
    """Functional outputs must not depend on the hardware config."""
    d4, _ = run_tinybio(EGPU_4T)
    d16, _ = run_tinybio(EGPU_16T)
    np.testing.assert_allclose(np.asarray(d4), np.asarray(d16),
                               rtol=1e-4, atol=1e-4)


def test_synth_signal_has_breathing_peaks():
    from repro.kernels.delineate.ops import delineate
    from repro.kernels.fir.ops import fir
    sig = jnp.asarray(synth_signal(4096))
    h = jnp.ones(16) / 16.0
    flt = fir(sig, h)
    # thresholded delineation: only real breathing peaks (amplitude ~1)
    flags = np.asarray(delineate(flt, 0.3))
    # ~0.25 Hz breathing (+0.08 Hz drift) at 32 Hz → ~30-45 crests in
    # 128 s; residual noise can split a flat crest into 2 local maxima
    n_peaks = (flags > 0).sum()
    assert 20 <= n_peaks <= 100, n_peaks


# ---------------------------------------------------------------------------
# Train loop (reduced config) — loss must actually decrease
# ---------------------------------------------------------------------------
def test_train_loss_decreases():
    cfg = ARCHS["stablelm-1.6b"].reduced()
    tcfg = TrainConfig(peak_lr=3e-3, total_steps=60, remat="none")
    _, losses = train_loop(cfg, tcfg, steps=60, global_batch=16, seq_len=64,
                           seed=0)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_microbatched_grads_match_full_batch():
    from repro.data import DataConfig, SyntheticLMData
    from repro.models import init_params, model_spec
    from repro.optim import adamw_init, constant_schedule
    from repro.train.step import make_train_step

    cfg = ARCHS["qwen2.5-3b"].reduced()
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    data = SyntheticLMData(DataConfig(8, 32, cfg.vocab, seed=0), cfg)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    s1 = make_train_step(cfg, TrainConfig(microbatches=1, remat="none"),
                         constant_schedule(1e-3))
    s4 = make_train_step(cfg, TrainConfig(microbatches=4, remat="none"),
                         constant_schedule(1e-3))
    n1, m1 = s1(jax.tree_util.tree_map(jnp.copy, state), batch)
    n4, m4 = s4(jax.tree_util.tree_map(jnp.copy, state), batch)
    # same data, same params → same (averaged) grad norm and updated params
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=1e-3)
    # Adam's rsqrt(v)+eps amplifies fp-reordering noise (~1e-7 on grads)
    # to ~1e-3 relative on near-zero params — compare accordingly
    w1 = jax.tree_util.tree_leaves(n1["params"])[5]
    w4 = jax.tree_util.tree_leaves(n4["params"])[5]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w4),
                               rtol=1e-2, atol=1e-4)


# ---------------------------------------------------------------------------
# Fault tolerance: kill at step k, restart, converge identically
# ---------------------------------------------------------------------------
def test_checkpoint_restart_continuity(tmp_path):
    cfg = ARCHS["stablelm-1.6b"].reduced()
    tcfg = TrainConfig(peak_lr=1e-3, total_steps=30, remat="none")
    kw = dict(steps=24, global_batch=4, seq_len=32, seed=1,
              ckpt_dir=str(tmp_path / "ck"), ckpt_every=8)

    # uninterrupted run
    _, gold = train_loop(cfg, tcfg, steps=24, global_batch=4, seq_len=32,
                         seed=1)

    # interrupted at 16 (after the step-16 checkpoint), then resumed
    with pytest.raises(SystemExit):
        train_loop(cfg, tcfg, simulate_failure=16, **kw)
    _, resumed = train_loop(cfg, tcfg, **kw)

    # the resumed tail reproduces the uninterrupted tail (deterministic
    # data replay + checkpointed state)
    np.testing.assert_allclose(resumed[-4:], gold[-4:], rtol=5e-3, atol=5e-3)


def test_trainer_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "minicpm-2b",
         "--smoke", "--steps", "3", "--batch", "2", "--seq", "32"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done" in out.stdout
