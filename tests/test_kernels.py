"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

Every Pallas kernel targets TPU (pl.pallas_call + BlockSpec) and validates
here in interpret mode; the XLA fallbacks are swept too via impl flags.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.fir.ops import fir
from repro.kernels.fir.ref import fir_ref
from repro.kernels.stockham_fft.ops import fft, power_spectrum
from repro.kernels.stockham_fft.ref import stockham_fft_ref
from repro.kernels.delineate.ops import delineate
from repro.kernels.delineate.ref import delineate_ref
from repro.kernels.svm.ops import svm_decision
from repro.kernels.svm.ref import svm_decision_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.decode_attention.ops import (combine_partials,
                                                decode_attention,
                                                decode_attention_partial_ref,
                                                decode_attention_ref)
from repro.kernels.rwkv6_scan.ops import rwkv6_scan, rwkv6_scan_ref
from repro.kernels.mamba_scan.ops import mamba_scan, mamba_scan_ref

RNG = np.random.default_rng(0)


def rand(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# GeMM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (100, 70, 50), (128, 128, 128),
                                   (257, 129, 65), (512, 256, 384)])
def test_gemm_shapes(m, k, n):
    a, b = rand(m, k), rand(k, n)
    np.testing.assert_allclose(gemm(a, b), gemm_ref(a, b),
                               rtol=2e-4, atol=2e-4)


def test_gemm_int32_fixed_point():
    a = jnp.asarray(RNG.integers(-100, 100, (64, 32)), jnp.int32)
    b = jnp.asarray(RNG.integers(-100, 100, (32, 48)), jnp.int32)
    np.testing.assert_array_equal(gemm(a, b), gemm_ref(a, b))


# ---------------------------------------------------------------------------
# FIR
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,taps", [(64, 8), (1000, 31), (4096, 128)])
def test_fir(n, taps):
    x, h = rand(n), rand(taps)
    np.testing.assert_allclose(fir(x, h), fir_ref(x, h), rtol=2e-4, atol=2e-4)


def test_fir_matches_numpy_convolve():
    x, h = rand(512), rand(17)
    ref = np.convolve(np.asarray(x), np.asarray(h))[:512]
    np.testing.assert_allclose(fir(x, h), ref, rtol=1e-4, atol=1e-4)


def test_fir_int_fixed_point():
    x = jnp.asarray(RNG.integers(-2000, 2000, 256), jnp.int32)
    h = jnp.asarray(RNG.integers(-300, 300, 16), jnp.int32)
    np.testing.assert_array_equal(fir(x, h), fir_ref(x, h))


# ---------------------------------------------------------------------------
# Stockham FFT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [64, 256, 1024, 4096])
def test_fft_vs_numpy(n):
    x = rand(n)
    re, im = fft(x)
    ref = np.fft.fft(np.asarray(x))
    np.testing.assert_allclose(re, ref.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(im, ref.imag, rtol=1e-3, atol=1e-3)


def test_fft_matches_ref_and_batched():
    x = rand(8, 512)
    re, im = fft(x, jnp.zeros_like(x))
    rr, ri = stockham_fft_ref(x[0], jnp.zeros(512))
    np.testing.assert_allclose(re[0], rr, rtol=1e-3, atol=1e-3)
    ps = power_spectrum(x[0])
    np.testing.assert_allclose(
        ps, np.abs(np.fft.fft(np.asarray(x[0]))) ** 2, rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# Delineation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [50, 512, 4097])
def test_delineate(n):
    x = rand(n)
    np.testing.assert_array_equal(delineate(x), delineate_ref(x))


def test_delineate_finds_known_extrema():
    t = np.linspace(0, 6 * np.pi, 600).astype(np.float32)
    x = jnp.asarray(np.sin(t))
    flags = np.asarray(delineate(x))
    peaks = np.where(flags > 0)[0]
    troughs = np.where(flags < 0)[0]
    assert len(peaks) == 3 and len(troughs) == 3
    # peaks of sin at pi/2 + 2k pi
    np.testing.assert_allclose(t[peaks], [np.pi / 2, np.pi * 2.5, np.pi * 4.5],
                               atol=0.05)


# ---------------------------------------------------------------------------
# SVM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,m,d,gamma", [(1, 16, 8, 0.5), (5, 40, 12, 0.3),
                                         (16, 256, 32, None)])
def test_svm(q, m, d, gamma):
    x, sv = rand(q, d), rand(m, d)
    alpha = rand(m, scale=0.1)
    out = svm_decision(x, sv, alpha, 0.25, gamma)
    ref = svm_decision_ref(x, sv, alpha, 0.25, gamma)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kvh,s,d", [(1, 4, 4, 128, 32), (2, 4, 2, 256, 64),
                                         (1, 8, 1, 512, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_xla(b, h, kvh, s, d, causal):
    q, k, v = rand(b, h, s, d), rand(b, kvh, s, d), rand(b, kvh, s, d)
    out = flash_attention(q, k, v, causal=causal, impl="xla")
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_pallas_interpret():
    q, k, v = rand(1, 4, 256, 64), rand(1, 2, 256, 64), rand(1, 2, 256, 64)
    out = flash_attention(q, k, v, causal=True, impl="pallas", bq=128, bk=128)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_q_offset_decode_suffix():
    """q as a suffix of the sequence (chunked prefill)."""
    q, k, v = rand(1, 4, 64, 32), rand(1, 4, 256, 32), rand(1, 4, 256, 32)
    out = flash_attention(q, k, v, causal=True, q_offset=192, impl="xla")
    ref = mha_ref(q, k, v, causal=True, q_offset=192)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_mla_asymmetric_dv():
    """MLA uses Dk=192 vs Dv=128."""
    q, k, v = rand(1, 4, 128, 96), rand(1, 4, 128, 96), rand(1, 4, 128, 64)
    out = flash_attention(q, k, v, causal=True, impl="xla")
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Decode attention (flash-decoding)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kvh,t,d", [(2, 4, 2, 512, 64), (1, 8, 8, 128, 32)])
def test_decode_attention(b, h, kvh, t, d):
    q = rand(b, h, d)
    k, v = rand(b, kvh, t, d), rand(b, kvh, t, d)
    out = decode_attention(q, k, v)
    ref = decode_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_decoding_combine_identity():
    """Seq-sharded partial-softmax combine == full softmax (exact)."""
    q = rand(2, 4, 32)
    k, v = rand(2, 4, 256, 32), rand(2, 4, 256, 32)
    full = decode_attention_ref(q, k, v)
    parts = [decode_attention_partial_ref(q, k[:, :, i*64:(i+1)*64],
                                          v[:, :, i*64:(i+1)*64])
             for i in range(4)]
    merged, _, _ = combine_partials(parts)
    np.testing.assert_allclose(merged.astype(full.dtype), full,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV-6 scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,t,d", [(1, 2, 16, 8), (2, 4, 64, 16)])
def test_rwkv6_scan(b, h, t, d):
    r, k, v = rand(b, h, t, d, scale=0.3), rand(b, h, t, d, scale=0.3), \
        rand(b, h, t, d, scale=0.3)
    w = jnp.asarray(RNG.random((b, h, t, d)).astype(np.float32) * 0.5 + 0.3)
    u = rand(h, d, scale=0.3)
    y, s = rwkv6_scan(r, k, v, w, u, impl="xla")
    yr, sr = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s, sr, rtol=2e-3, atol=2e-3)


def test_rwkv6_chunked_equals_sequential():
    """State chaining across chunks is exact."""
    b, h, t, d = 1, 2, 64, 16
    r, k, v = rand(b, h, t, d, scale=0.3), rand(b, h, t, d, scale=0.3), \
        rand(b, h, t, d, scale=0.3)
    w = jnp.asarray(RNG.random((b, h, t, d)).astype(np.float32) * 0.5 + 0.3)
    u = rand(h, d, scale=0.3)
    y_full, s_full = rwkv6_scan_ref(r, k, v, w, u)
    y1, s1 = rwkv6_scan_ref(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                            w[:, :, :32], u)
    y2, s2 = rwkv6_scan_ref(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                            w[:, :, 32:], u, state0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 2), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Mamba scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,dm,n", [(1, 32, 16, 8), (2, 64, 32, 16)])
def test_mamba_scan(b, t, dm, n):
    x, delta = rand(b, t, dm, scale=0.5), \
        jnp.abs(rand(b, t, dm, scale=0.3)) + 0.1
    a = -jnp.abs(rand(dm, n)) - 0.1
    bb, cc = rand(b, t, n, scale=0.5), rand(b, t, n, scale=0.5)
    d = rand(dm, scale=0.5)
    y, s = mamba_scan(x, delta, a, bb, cc, d, impl="xla")
    yr, sr = mamba_scan_ref(x, delta, a, bb, cc, d)
    np.testing.assert_allclose(y, yr, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s, sr, rtol=2e-3, atol=2e-3)


def test_mamba_chunked_equals_sequential():
    b, t, dm, n = 1, 64, 16, 8
    x, delta = rand(b, t, dm, scale=0.5), \
        jnp.abs(rand(b, t, dm, scale=0.3)) + 0.1
    a = -jnp.abs(rand(dm, n)) - 0.1
    bb, cc = rand(b, t, n, scale=0.5), rand(b, t, n, scale=0.5)
    d = rand(dm, scale=0.5)
    y_full, s_full = mamba_scan_ref(x, delta, a, bb, cc, d)
    y1, s1 = mamba_scan_ref(x[:, :32], delta[:, :32], a, bb[:, :32],
                            cc[:, :32], d)
    y2, s2 = mamba_scan_ref(x[:, 32:], delta[:, 32:], a, bb[:, 32:],
                            cc[:, 32:], d, state0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)
