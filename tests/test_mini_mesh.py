"""End-to-end sharding correctness on a real (8-fake-device) mesh.

Runs in a SUBPROCESS (device count must be set before jax initializes, and
the main test process must keep its single CPU device): a reduced GQA model
is trained one step and served (prefill + decode) under the production
sharding rules on a (data=2, model=4) mesh, and every result is compared
against the plain unsharded single-device execution.  This is the numeric
proof that the TRAIN/SERVE rules + constraints don't change the math —
the multi-pod dry-run proves compilability, this proves equivalence.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.sharding import (SERVE_RULES, TRAIN_FSDP_RULES,
                                        activate, param_shardings, spec_for)
from repro.models import init_params, model_spec
from repro.models.transformer import cache_axes, decode_step, prefill
from repro.optim import adamw_init, constant_schedule
from repro.train.step import TrainConfig, make_train_step

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((2, 4), ("data", "model"))

def reduced(arch):
    cfg = ARCHS[arch].reduced()
    kw = {"dtype": "float32"}
    if cfg.n_experts:
        kw["capacity_factor"] = 8.0        # no drops → sharded == unsharded
    return dataclasses.replace(cfg, **kw)

# ---- sharded train step == unsharded, across three families -------------
for arch in ("qwen2.5-3b", "deepseek-v2-236b", "rwkv6-3b"):
    cfg = reduced(arch)
    spec_tree = model_spec(cfg)
    params = init_params(spec_tree, jax.random.PRNGKey(0))
    data = SyntheticLMData(DataConfig(8, 32, cfg.vocab, seed=0), cfg)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    tcfg = TrainConfig(remat="none", microbatches=1)
    step_ref = jax.jit(make_train_step(cfg, tcfg, constant_schedule(1e-3)))
    state0 = {"params": params, "opt": adamw_init(params)}
    ref_state, ref_metrics = step_ref(
        jax.tree_util.tree_map(jnp.copy, state0), batch)

    rules = TRAIN_FSDP_RULES
    p_sh = param_shardings(spec_tree, rules, mesh)
    state_sh = {"params": p_sh,
                "opt": {"m": p_sh, "v": p_sh,
                        "step": NamedSharding(mesh, P())}}
    with activate(rules, mesh):
        batch_sh = {k: NamedSharding(mesh, spec_for(
            ("batch", None), rules, mesh, tuple(v.shape)))
            for k, v in batch.items()}
    state_placed = jax.device_put(state0, state_sh)
    batch_placed = {k: jax.device_put(v, batch_sh[k])
                    for k, v in batch.items()}

    def wrapped(state, b, cfg=cfg, rules=rules, tcfg=tcfg):
        with activate(rules, mesh):
            return make_train_step(cfg, tcfg, constant_schedule(1e-3))(
                state, b)

    step_sh = jax.jit(wrapped, in_shardings=(state_sh, batch_sh))
    with mesh:
        sh_state, sh_metrics = step_sh(state_placed, batch_placed)

    np.testing.assert_allclose(float(sh_metrics["loss"]),
                               float(ref_metrics["loss"]), rtol=5e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(sh_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
    print(f"TRAIN-EQUIV-OK {arch}")
print("TRAIN-EQUIV-OK")

# ---- sharded serving: prefill + decode under SERVE_RULES -----------------
cfg = reduced("qwen2.5-3b")
spec_tree = model_spec(cfg)
params = init_params(spec_tree, jax.random.PRNGKey(0))
toks = jnp.asarray(np.random.default_rng(1).integers(
    0, cfg.vocab, (8, 16)), jnp.int32)
ref_logits, ref_cache = prefill(params, {"tokens": toks}, cfg, max_len=20,
                                cache_dtype=jnp.float32)
ref_l2, _ = decode_step(params, ref_cache,
                        jnp.argmax(ref_logits, -1).astype(jnp.int32),
                        jnp.int32(16), cfg)

def serve_wrapped(p, t):
    with activate(SERVE_RULES, mesh):
        logits, cache = prefill(p, {"tokens": t}, cfg, max_len=20,
                                cache_dtype=jnp.float32)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        l2, _ = decode_step(p, cache, nxt, jnp.int32(16), cfg)
        return logits, l2

p_sh_serve = param_shardings(spec_tree, SERVE_RULES, mesh)
with activate(SERVE_RULES, mesh):
    t_sh = NamedSharding(mesh, spec_for(("batch", None), SERVE_RULES, mesh,
                                        (8, 16)))
serve = jax.jit(serve_wrapped, in_shardings=(p_sh_serve, t_sh))
with mesh:
    sh_logits, sh_l2 = serve(jax.device_put(params, p_sh_serve),
                             jax.device_put(toks, t_sh))
np.testing.assert_allclose(np.asarray(sh_logits), np.asarray(ref_logits),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(sh_l2), np.asarray(ref_l2),
                           rtol=2e-4, atol=2e-4)
print("SERVE-EQUIV-OK")
"""


@pytest.mark.timeout(560)
def test_sharded_equals_unsharded_on_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    assert "TRAIN-EQUIV-OK" in out.stdout
    assert "SERVE-EQUIV-OK" in out.stdout
