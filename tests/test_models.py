"""Model-zoo behaviour tests: serving caches agree with full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rotary, cross_entropy,
                                 logits_from_hidden)
from repro.models.params import abstract_params, init_params, param_bytes
from repro.models.transformer import (cache_axes, cache_struct, decode_step,
                                      forward, model_spec, prefill)

RNG = np.random.default_rng(0)


def tiny(name="tiny", **kw):
    base = dict(name=name, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, head_dim=16, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense-gqa": tiny(qkv_bias=True),
    "dense-partial-rope": tiny(rotary_pct=0.25, norm="layernorm"),
    "mla-moe": tiny(n_layers=3, n_kv_heads=4, attn_kind="mla",
                    block_pattern=("mla",), mlp_pattern=("moe",),
                    first_layer_dense=True, d_ff_dense=128,
                    q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                    qk_rope_head_dim=8, v_head_dim=16, n_experts=8,
                    n_shared_experts=1, top_k=2, d_ff_expert=32,
                    capacity_factor=4.0),
    "hybrid-jamba": tiny(n_layers=4, block_pattern=("mamba", "attn"),
                         mlp_pattern=("dense", "moe"), n_experts=4,
                         top_k=2, d_ff_expert=32, capacity_factor=4.0,
                         mamba_d_state=8, mamba_dt_rank=8),
    "rwkv": tiny(block_pattern=("rwkv",), mlp_pattern=("none",),
                 rwkv_head_dim=16),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_prefill_decode_match_forward(family):
    """prefill(S) then decode(S) logits == full forward at S-1 and S."""
    cfg = FAMILIES[family]
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    h, _ = forward(params, {"tokens": toks}, cfg)
    full = logits_from_hidden(params["embed"], h, cfg)
    pl, cache = prefill(params, {"tokens": toks[:, :S]}, cfg, max_len=S + 4,
                        cache_dtype=jnp.float32)
    np.testing.assert_allclose(pl, full[:, S - 1], rtol=3e-3, atol=3e-3)
    dl, cache = decode_step(params, cache, toks[:, S], jnp.int32(S), cfg)
    np.testing.assert_allclose(dl, full[:, S], rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_multi_step_decode_stays_consistent(family):
    """Three consecutive decode steps match running forward each time."""
    cfg = FAMILIES[family]
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    B, S, EXTRA = 1, 8, 3
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + EXTRA)), jnp.int32)
    _, cache = prefill(params, {"tokens": toks[:, :S]}, cfg,
                       max_len=S + EXTRA + 1, cache_dtype=jnp.float32)
    for i in range(EXTRA):
        dl, cache = decode_step(params, cache, toks[:, S + i],
                                jnp.int32(S + i), cfg)
        h, _ = forward(params, {"tokens": toks[:, :S + i + 1]}, cfg)
        full = logits_from_hidden(params["embed"], h, cfg)
        np.testing.assert_allclose(dl, full[:, S + i], rtol=5e-3, atol=5e-3)


def test_mla_cache_is_compressed():
    """The MLA cache stores (kv_lora + rope) per token, NOT 2*H*hd."""
    cfg = FAMILIES["mla-moe"]
    cs = cache_struct(cfg, batch=2, max_len=16)
    flat = jax.tree_util.tree_leaves(cs)
    per_token = sum(np.prod(s.shape) for s in flat) / (2 * 16)
    full_kv = 2 * cfg.n_heads * cfg.head_dim * cfg.n_layers
    latent = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * cfg.n_layers
    assert per_token <= latent * 1.1
    assert per_token < full_kv / 4          # the paper-claimed big reduction


def test_cache_axes_structure_matches_struct():
    for family in ("hybrid-jamba", "mla-moe", "rwkv"):
        cfg = FAMILIES[family]
        cs = cache_struct(cfg, batch=2, max_len=16)
        axes = cache_axes(cfg)
        is_ax = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        n_struct = len(jax.tree_util.tree_leaves(cs))
        n_axes = len(jax.tree_util.tree_leaves(axes, is_leaf=is_ax))
        assert n_struct == n_axes


def test_rotary_properties():
    """Rotation preserves norms and relative-position structure."""
    x = jnp.asarray(RNG.standard_normal((1, 2, 8, 32)), jnp.float32)
    pos = jnp.arange(8)
    y = apply_rotary(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(RNG.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1, 1, 32)), jnp.float32)
    def score(p0, p1):
        qq = apply_rotary(q, jnp.asarray([p0]), 1e4)
        kk = apply_rotary(k, jnp.asarray([p1]), 1e4)
        return float(jnp.sum(qq * kk))
    assert abs(score(0, 5) - score(7, 12)) < 1e-4


def test_partial_rotary_leaves_tail_alone():
    x = jnp.asarray(RNG.standard_normal((1, 1, 4, 32)), jnp.float32)
    y = apply_rotary(x, jnp.arange(4), 1e4, rotary_pct=0.25)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))


def test_cross_entropy_masking():
    logits = jnp.asarray(RNG.standard_normal((2, 4, 16)), jnp.float32)
    labels = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0], [1, 1, 1, 1]], jnp.float32)
    full = cross_entropy(logits, labels)
    masked = cross_entropy(logits, labels, mask)
    assert np.isfinite(float(full)) and np.isfinite(float(masked))
    half = cross_entropy(logits[:, :2], labels[:, :2])
    # masked mean over first-two + all of row 1 != plain mean
    assert float(masked) != pytest.approx(float(full))


def test_abstract_params_never_allocate():
    cfg = FAMILIES["mla-moe"]
    ap = abstract_params(model_spec(cfg))
    for leaf in jax.tree_util.tree_leaves(ap):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert param_bytes(model_spec(cfg)) > 0


def test_moe_capacity_drops_are_graceful():
    """With capacity_factor=0.1 most tokens drop; output stays finite."""
    cfg = dataclasses.replace(FAMILIES["mla-moe"], capacity_factor=0.1)
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    h, aux = forward(params, {"tokens": toks}, cfg)
    assert np.isfinite(np.asarray(h, np.float32)).all()
