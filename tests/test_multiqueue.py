"""Event-dependency DAGs, out-of-order queues, and launch-time queue
binding (ISSUE 3).

Pins the new execution-model contracts: explicit ``wait_events`` edges and
marker/barrier analogues, out-of-order capture producing dependency DAGs
whose fused modeled latency is the critical path (concurrent branches
overlap), multi-queue captures (host + e-GPU nodes in one graph), and the
shared-cache accounting fix — launches of a cached ``CommandGraph`` bind
their events and modeled totals to the *launching* queue, so same-config
workers sharing one cache entry keep exact per-queue histories.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EGPU_16T, HOST, CommandQueue, Context, Device,
                        Event, Kernel, NDRange, PhaseBreakdown, Stage,
                        fuse_breakdowns)
from repro.kernels.gemm.ref import counts as gemm_counts
from repro.kernels.gemm.ref import gemm_ref
from repro.serve import BucketBatcher, GraphCache, QueueWorker

NDR = NDRange((8, 8), (4, 4))


def _ctx():
    return Context(Device(EGPU_16T))


def _mm_kernel(name="mm"):
    return Kernel(name=name, executor=gemm_ref,
                  counts=lambda **kw: gemm_counts(m=8, n=8, k=8))


def _x(seed=0, shape=(8, 8)):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


# ---------------------------------------------------------------------------
# Out-of-order queues: dependency edges under capture
# ---------------------------------------------------------------------------
def test_out_of_order_independent_launches_are_unordered():
    """No wait_events + no dataflow link = no edge: the two launches are
    concurrent, and the fused critical path is a max, not a sum."""
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, b))
    assert g.node_deps() == ((), ())
    fused, _ = g.fused_modeled()
    chain = fuse_breakdowns(g.modeled_breakdowns())
    assert fused.total_s < chain.total_s
    # both launches still execute (recorded order) and produce real results
    o = g.launch()
    assert len(o) == 1                   # graph outputs = last node's


def test_in_order_capture_keeps_implicit_chain():
    """The default queue chains launches even without dataflow between
    them — classic in-order OpenCL semantics."""
    ctx = _ctx()
    q = CommandQueue(ctx)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, b))
    assert g.node_deps() == ((), (0,))
    fused, _ = g.fused_modeled()
    chain = fuse_breakdowns(g.modeled_breakdowns())
    assert fused.total_s == chain.total_s


def test_wait_events_add_edges_beyond_dataflow():
    """An explicit wait list orders nodes that share no buffers."""
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        e0 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, b), wait_events=[e0])
    assert g.node_deps() == ((), (0,))


def test_diamond_dag_critical_path_and_eager_identity():
    """Acceptance: a diamond (A -> B, A -> C, {B,C} -> D) captured on an
    out-of-order queue models critical-path latency strictly below the
    chain-sum while launching bit-identical to eager execution."""
    ctx = _ctx()
    x = _x(3)
    q = CommandQueue(ctx, out_of_order=True)
    with q.capture() as g:
        a = ctx.create_buffer(x)
        e0 = q.enqueue_nd_range(_mm_kernel("A"), NDR, (a, a))
        e1 = q.enqueue_nd_range(_mm_kernel("B"), NDR, e0.outputs + (a,),
                                wait_events=[e0])
        e2 = q.enqueue_nd_range(_mm_kernel("C"), NDR, e0.outputs + (a,),
                                wait_events=[e0])
        q.enqueue_nd_range(_mm_kernel("D"), NDR,
                           (e1.outputs[0], e2.outputs[0]),
                           wait_events=[e1, e2])
    assert g.node_deps() == ((), (0,), (0,), (1, 2))
    fused, _ = g.fused_modeled()
    chain = fuse_breakdowns(g.modeled_breakdowns())
    assert fused.total_s < chain.total_s         # one branch overlaps
    # work phases on the path: A + one branch + D (3 of 4 equal-cost nodes)
    per = g.nodes[0].modeled
    assert fused.compute == pytest.approx(3 * per.compute)
    assert chain.compute == pytest.approx(4 * per.compute)
    # bit-identical to eager dispatch of the same dataflow
    qe = CommandQueue(ctx, out_of_order=True, profile=False)
    ae = ctx.create_buffer(x)
    f0 = qe.enqueue_nd_range(_mm_kernel("A"), NDR, (ae, ae))
    f1 = qe.enqueue_nd_range(_mm_kernel("B"), NDR, f0.outputs + (ae,),
                             wait_events=[f0])
    f2 = qe.enqueue_nd_range(_mm_kernel("C"), NDR, f0.outputs + (ae,),
                             wait_events=[f0])
    f3 = qe.enqueue_nd_range(_mm_kernel("D"), NDR,
                             (f1.outputs[0], f2.outputs[0]),
                             wait_events=[f1, f2])
    (eager,) = f3.wait()
    (fused_out,) = g.launch()
    assert np.array_equal(np.asarray(fused_out.data), np.asarray(eager.data))


# ---------------------------------------------------------------------------
# Markers and barriers
# ---------------------------------------------------------------------------
def test_marker_aggregates_dependencies_under_capture():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        e0 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (b, b))
        m = q.enqueue_marker(wait_events=[e0, e1])
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, b), wait_events=[m])
    # the marker is a zero-cost node fanning both edges in; the final
    # kernel reaches them transitively through it
    assert g.node_deps() == ((), (), (0, 1), (2,))


def test_barrier_orders_out_of_order_capture():
    """Launches after a barrier implicitly depend on everything before it,
    even on an out-of-order queue; launches after the barrier stay
    unordered among themselves."""
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, b))
        q.enqueue_barrier()
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, b))
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, a))
    # the barrier node aggregates everything before it; both later
    # launches order through it (and not through each other)
    assert g.node_deps() == ((), (), (0, 1), (2,), (2,))


def test_empty_wait_list_means_all_previous():
    """OpenCL: a marker/barrier with an EMPTY wait list waits on all
    previously enqueued commands, exactly like passing none at all — an
    empty-list barrier must not erase the ordering frontier."""
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True)
    a, b = ctx.create_buffer(_x(1)), ctx.create_buffer(_x(2))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_barrier(wait_events=[])
        q.enqueue_nd_range(_mm_kernel(), NDR, (b, b))
    assert g.node_deps() == ((), (0,), (1,))
    # eager: empty-list marker still aggregates the queue's history
    qe = CommandQueue(ctx, profile=False, out_of_order=True)
    e0 = qe.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    m = qe.enqueue_marker(wait_events=[])
    assert e0 in m.deps
    qe.finish()


def test_in_order_capture_barrier_carries_cross_queue_edges():
    """A barrier's wait list can point at nodes of a JOINED queue the
    in-order chain doesn't cover — the edge must survive into the DAG."""
    ctx = _ctx()
    host_q = CommandQueue(Context(Device(HOST)))
    q = CommandQueue(ctx)                # in-order
    a = ctx.create_buffer(_x(1))
    with q.capture() as g:
        with g.join(host_q):
            host_ev = host_q.enqueue_nd_range(_mm_kernel("host"), NDR, (a, a))
        q.enqueue_barrier(wait_events=[host_ev])
        q.enqueue_nd_range(_mm_kernel("egpu"), NDR, (a, a))   # no dataflow
    # the barrier node carries the cross-queue edge; the e-GPU kernel
    # orders after the host node THROUGH it (was silently dropped)
    assert g.node_deps() == ((), (0,), (1,))
    # the in-order chain carries it transitively to later nodes too
    with q.capture() as g2:
        with g2.join(host_q):
            hev = host_q.enqueue_nd_range(_mm_kernel("host"), NDR, (a, a))
        q.enqueue_barrier(wait_events=[hev])
        q.enqueue_nd_range(_mm_kernel("e1"), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel("e2"), NDR, (a, a))
    assert g2.node_deps() == ((), (0,), (1,), (2,))


def test_consecutive_sync_commands_accumulate_frontier():
    """A marker (or second barrier) between a barrier and the next launch
    must not erase the barrier's cross-queue edges — sync commands merge
    their constraints, they never cancel earlier ones."""
    ctx = _ctx()
    host_q = CommandQueue(Context(Device(HOST)))
    q = CommandQueue(ctx)                # in-order
    a = ctx.create_buffer(_x(1))
    with q.capture() as g:
        with g.join(host_q):
            host_ev = host_q.enqueue_nd_range(_mm_kernel("host"), NDR, (a, a))
        q.enqueue_barrier(wait_events=[host_ev])
        q.enqueue_marker()               # all-so-far: includes the barrier
        q.enqueue_nd_range(_mm_kernel("egpu"), NDR, (a, a))
    # host -> barrier -> marker -> kernel: the cross-queue edge survives
    # the interposed marker via transitivity
    assert g.node_deps() == ((), (0,), (1,), (2,))
    # out-of-order: both barriers' constraints reach later launches (the
    # second barrier chains to the first via the queue's barrier point)
    q2 = CommandQueue(ctx, out_of_order=True)
    b = ctx.create_buffer(_x(2))
    with q2.capture() as g2:
        with g2.join(host_q):
            hev = host_q.enqueue_nd_range(_mm_kernel("host"), NDR, (a, a))
        q2.enqueue_barrier(wait_events=[hev])
        e1 = q2.enqueue_nd_range(_mm_kernel("e1"), NDR, (a, a))
        q2.enqueue_barrier(wait_events=[e1])
        q2.enqueue_nd_range(_mm_kernel("e2"), NDR, (b, b))
    assert g2.node_deps() == ((), (0,), (1,), (1, 2), (3,))


def test_trailing_barrier_does_not_eat_graph_outputs():
    ctx = _ctx()
    q = CommandQueue(ctx)
    a = ctx.create_buffer(_x(1))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_barrier()              # zero-cost node, no outputs
    (out,) = g.launch()                  # outputs: last KERNEL node's
    assert out.shape == (8, 8)
    # a capture holding only sync commands has nothing to launch
    with q.capture() as g2:
        q.enqueue_marker()
    with pytest.raises(RuntimeError):
        g2.launch()


def test_marker_and_barrier_eager_semantics():
    ctx = _ctx()
    q = CommandQueue(ctx, out_of_order=True, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    e0 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    m = q.enqueue_marker()               # waits everything enqueued so far
    assert e0 in m.deps
    bar = q.enqueue_barrier()
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert bar in e1.deps                # out-of-order: barrier edge only
    m.wait()
    assert e0.done                       # marker realized its dependencies
    q.finish()
    assert e1.done


# ---------------------------------------------------------------------------
# Multi-queue capture: host + e-GPU nodes in one graph
# ---------------------------------------------------------------------------
def test_join_captures_host_and_egpu_nodes_in_one_graph():
    ctx = _ctx()
    host_q = CommandQueue(Context(Device(HOST)))
    q = CommandQueue(ctx)
    x = _x(4)
    with q.capture() as g:
        a = ctx.create_buffer(x)
        e0 = q.enqueue_nd_range(_mm_kernel("egpu_mm"), NDR, (a, a))
        with g.join(host_q):
            host_q.enqueue_nd_range(_mm_kernel("host_mm"), NDR,
                                    e0.outputs + (a,), wait_events=[e0])
    assert len(g.nodes) == 2 and g.node_deps() == ((), (0,))
    assert q in g.queues and host_q in g.queues
    # each node costed on ITS queue's device: the e-GPU node pays
    # Tiny-OpenCL startup + scheduling, the scalar host does not
    assert g.nodes[0].modeled.scheduling > 0.0
    assert g.nodes[1].modeled.scheduling == 0.0
    fused, _ = g.fused_modeled()         # DAG mode fuses across devices
    assert fused.total_s > 0.0
    (out,) = g.launch()
    np.testing.assert_allclose(np.asarray(out.data),
                               np.asarray(gemm_ref(gemm_ref(x, x), x)),
                               atol=1e-4)
    # joining outside an active capture is rejected
    with pytest.raises(RuntimeError):
        with g.join(host_q):
            pass


def test_join_of_already_capturing_queue_keeps_capture_alive():
    """A redundant join (the capture's own queue, or a nested join) must
    not end that queue's capture when the inner block closes."""
    ctx = _ctx()
    q = CommandQueue(ctx)
    a = ctx.create_buffer(_x(6))
    with q.capture() as g:
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        with g.join(q):                  # q is already capturing g
            q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        # capture must still be live: this enqueue is RECORDED, not run
        ev = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        assert getattr(ev, "_graph", None) is g
    assert len(g.nodes) == 3 and q.events == ()
    assert len(g.launch()) == 1


# ---------------------------------------------------------------------------
# Launch-time queue binding
# ---------------------------------------------------------------------------
def test_launch_binds_events_to_caller_queue():
    ctx = _ctx()
    q = CommandQueue(ctx)
    a = ctx.create_buffer(_x(5))
    with q.capture() as g:
        e = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
        q.enqueue_nd_range(_mm_kernel(), NDR, e.outputs + (a,))
    mine = CommandQueue(ctx)
    outs = g.launch(queue=mine)
    mine.finish()
    assert len(mine.events) == 2
    assert q.events == ()                # capture queue untouched
    # launch outputs carry the producing event, so a later eager consumer
    # gets the same dataflow ordering edge as enqueue outputs
    assert all(getattr(b, "_event", None) is mine.events[-1] for b in outs)
    assert mine.total_modeled_s() == pytest.approx(g.total_modeled_s())
    # default launch still lands on the capture (home) queue
    g.launch()
    q.finish()
    assert len(q.events) == 2


def test_shared_cache_two_workers_exact_per_queue_accounting():
    """Acceptance: two same-config workers share ONE cached graph;
    interleaved launches book events and modeled totals on each worker's
    own queue exactly — nothing ever lands on a sibling or on the cached
    graph's capture queue."""
    rng = np.random.default_rng(17)
    d = 8
    w = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)
    kern = Kernel("mlp", executor=lambda x, w: jnp.maximum(gemm_ref(x, w), 0.0),
                  counts=lambda **kw: gemm_counts(m=d, n=d, k=d))
    stages = [Stage(kern, consts=(w,), n_inputs=1) for _ in range(2)]

    cache = GraphCache(capacity=4)
    w1 = QueueWorker(EGPU_16T, name="w1", max_in_flight=8)
    w2 = QueueWorker(EGPU_16T, name="w2", max_in_flight=8)

    def make_batch(seed):
        b = BucketBatcher((d,), max_batch=1)
        b.submit(jnp.asarray(rng.standard_normal((d, d)), jnp.float32))
        (mb,) = b.drain()
        return mb

    batches = [make_batch(i) for i in range(5)]
    g1, hit1 = cache.get_or_capture(w1.apu, stages, batches[0].inputs)
    g2, hit2 = cache.get_or_capture(w2.apu, stages, batches[0].inputs)
    assert g1 is g2 and not hit1 and hit2      # genuinely shared entry

    plan = [w1, w2, w1, w2, w1]                # interleaved: 3 vs 2
    for worker, mb in zip(plan, batches):
        worker.launch(g1, mb)
    w1.drain()
    w2.drain()

    n_nodes = len(g1.nodes)
    per_launch_s = g1.total_modeled_s()
    per_launch_j = g1.total_energy_j()
    # each queue's history/totals contain exactly its OWN launches
    assert w1.queue.released_count == 3 * n_nodes
    assert w2.queue.released_count == 2 * n_nodes
    assert w1.queue.total_modeled_s() == pytest.approx(3 * per_launch_s)
    assert w2.queue.total_modeled_s() == pytest.approx(2 * per_launch_s)
    assert w1.queue.total_energy_j() == pytest.approx(3 * per_launch_j)
    assert w2.queue.total_energy_j() == pytest.approx(2 * per_launch_j)
    # the shared graph's capture queue never saw a launch
    assert g1.queue.events == () and g1.queue.released_count == 0
    assert g1.queue.total_modeled_s() == 0.0
    # worker roll-ups agree with their queues' launch counts
    assert (w1.n_batches, w2.n_batches) == (3, 2)


# ---------------------------------------------------------------------------
# fuse_breakdowns: DAG mode semantics and validation
# ---------------------------------------------------------------------------
def _pb(compute, freq=300e6, startup=10.0, sched=20.0, transfer=5.0):
    return PhaseBreakdown(startup=startup, scheduling=sched,
                          transfer=transfer, compute=compute, freq_hz=freq)


def test_fuse_dag_linear_chain_matches_chain_mode():
    stages = [_pb(100.0), _pb(200.0), _pb(300.0)]
    chain = fuse_breakdowns(stages)
    dag = fuse_breakdowns(stages, deps=[(), (0,), (1,)])
    assert dag == chain                  # exact: same dataclass fields


def test_fuse_dag_parallel_branches_take_max():
    stages = [_pb(100.0), _pb(400.0)]
    dag = fuse_breakdowns(stages, deps=[(), ()])
    # unordered: the critical path is the heavier branch alone
    assert dag.compute == 400.0 and dag.transfer == 5.0
    assert dag.startup == 10.0 and dag.scheduling == 20.0


def test_fuse_dag_mixed_frequencies_normalize():
    # 1 us @ 300 MHz feeding 1 us @ 150 MHz = 2 us end to end
    a = _pb(300.0, freq=300e6, startup=0.0, sched=0.0, transfer=0.0)
    b = _pb(150.0, freq=150e6, startup=0.0, sched=0.0, transfer=0.0)
    dag = fuse_breakdowns([a, b], deps=[(), (0,)])
    assert dag.total_s == pytest.approx(2e-6)
    assert dag.freq_hz == 300e6          # normalized to the fastest clock
    # chain mode normalizes per stage too (ISSUE 8: stages priced at
    # different DVFS operating points fuse instead of raising) and agrees
    # with the linear DAG exactly
    chain = fuse_breakdowns([a, b])
    assert chain == dag


def test_fuse_dag_none_stages_are_zero_cost_passthrough():
    stages = [_pb(100.0), None, _pb(200.0)]
    dag = fuse_breakdowns(stages, deps=[(), (0,), (1,)])
    assert dag.compute == 300.0          # the unmodeled node adds nothing


def test_fuse_dag_validation():
    stages = [_pb(100.0), _pb(200.0)]
    with pytest.raises(ValueError):
        fuse_breakdowns(stages, deps=[()])           # misaligned
    with pytest.raises(ValueError):
        fuse_breakdowns(stages, deps=[(), (1,)])     # self/forward dep
    with pytest.raises(ValueError):
        fuse_breakdowns([None, None], deps=[(), ()])  # nothing modeled


# ---------------------------------------------------------------------------
# Satellite fixes: drain watermark, released-event wait, wait-list checks
# ---------------------------------------------------------------------------
def test_drain_starts_at_watermark(monkeypatch):
    """Repeated partial drains must wait each event ONCE — O(new work),
    not O(history)."""
    ctx = _ctx()
    q = CommandQueue(ctx)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    for _ in range(4):
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    waited = []
    orig = Event.wait
    monkeypatch.setattr(Event, "wait",
                        lambda self: (waited.append(self), orig(self))[1])
    q.drain(2)
    assert len(waited) == 2
    waited.clear()
    q.drain(4)                           # must wait ONLY events 2 and 3
    assert len(waited) == 2
    waited.clear()
    q.drain(4)                           # idempotent on a drained prefix
    assert waited == []


def test_wait_on_released_event_raises():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    ev = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    q.finish()                           # unprofiled: auto-release sweep
    assert ev.released
    with pytest.raises(RuntimeError):
        ev.wait()                        # use-after-release is loud


def test_wait_events_validation():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    done = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    q.finish()                           # releases `done`
    with pytest.raises(RuntimeError):
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a), wait_events=[done])
    with pytest.raises(TypeError):
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a), wait_events=["ev"])
    # a capture-time event cannot order an eager launch
    q2 = CommandQueue(ctx, profile=False)
    with q2.capture():
        cap_ev = q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    with pytest.raises(RuntimeError):
        q.enqueue_nd_range(_mm_kernel(), NDR, (a, a), wait_events=[cap_ev])
    # ...and an eager event cannot order a captured node
    live = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    with pytest.raises(RuntimeError):
        with q2.capture():
            q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a),
                                wait_events=[live])


def test_eager_marker_keeps_in_order_chain_and_rejects_capture_events():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    e0 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    other = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    # explicit wait list on an in-order queue: the marker is still chained
    # after everything previously enqueued (clEnqueueMarkerWithWaitList)
    m = q.enqueue_marker(wait_events=[other])
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    e1.wait()
    assert e0.done                       # the chain ran through the marker
    # a capture-time event cannot order an eager marker/barrier
    q2 = CommandQueue(ctx, profile=False)
    with q2.capture():
        cap_ev = q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    with pytest.raises(RuntimeError):
        q.enqueue_marker(wait_events=[cap_ev])
    with pytest.raises(RuntimeError):
        q.enqueue_barrier(wait_events=[cap_ev])
    q.finish()


def test_in_order_eager_event_chains_implicitly():
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    e2 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert e1 in e2.deps                 # implicit in-order edge
    e2.wait()
    assert e1.done and e2.deps == ()     # realized: chain refs dropped
    # out-of-order: no implicit edge
    q2 = CommandQueue(ctx, profile=False, out_of_order=True)
    f1 = q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    f2 = q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    assert f1 not in f2.deps
    q2.finish()


def test_eager_dataflow_is_an_ordering_edge():
    """Consuming another launch's output buffer is a dependency edge even
    on an out-of-order queue — wait() realizes the producer transitively,
    mirroring what capture records via slot producers."""
    ctx = _ctx()
    q = CommandQueue(ctx, profile=False, out_of_order=True)
    a = ctx.create_buffer(jnp.ones((8, 8), jnp.float32))
    e0 = q.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    e1 = q.enqueue_nd_range(_mm_kernel(), NDR, e0.outputs + (a,))
    assert e0 in e1.deps
    e1.wait()
    assert e0.done
    # cross-queue dataflow too (in-order chains don't cover a foreign queue)
    q2 = CommandQueue(ctx, profile=False)
    f0 = q2.enqueue_nd_range(_mm_kernel(), NDR, (a, a))
    g0 = q.enqueue_nd_range(_mm_kernel(), NDR, f0.outputs + (a,))
    assert f0 in g0.deps
    q.finish()
    q2.finish()


# ---------------------------------------------------------------------------
# Satellite fix: MicroBatch.crop per-output true lengths
# ---------------------------------------------------------------------------
def test_crop_uses_per_output_lengths():
    """Multi-input pipelines with differing extents: output j crops to
    input j's true length, not lengths[0]."""
    b = BucketBatcher((8,), max_batch=1)
    b.submit(jnp.arange(5, dtype=jnp.float32),
             jnp.arange(7, dtype=jnp.float32))   # both pad to bucket 8
    (mb,) = b.drain()
    assert mb.requests[0].lengths == (5, 7)
    (o0, o1) = mb.crop([mb.inputs[0] * 2, mb.inputs[1] * 3])[0]
    assert o0.shape == (5,)
    assert o1.shape == (7,)              # was wrongly cropped to 5
    np.testing.assert_array_equal(np.asarray(o1),
                                  3 * np.arange(7, dtype=np.float32))


def test_crop_per_output_padded_extent_detection():
    """Arrays landing in DIFFERENT buckets: the padded-extent check is
    per output too, so a secondary output matching ITS OWN bucket size is
    cropped correctly."""
    b = BucketBatcher((8, 16), max_batch=1)
    b.submit(jnp.arange(5, dtype=jnp.float32),     # -> bucket 8
             jnp.arange(12, dtype=jnp.float32))    # -> bucket 16
    (mb,) = b.drain()
    (o0, o1) = mb.crop([mb.inputs[0] * 2, mb.inputs[1] * 3])[0]
    assert o0.shape == (5,)
    assert o1.shape == (12,)             # was returned whole (16,) before
